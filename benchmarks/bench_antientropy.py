"""Anti-entropy under unreliable networks: bytes + time to convergence.

Two axes:

1. Algorithm 1 (basic, periodic full-state fallback) vs Algorithm 2
   (causal delta-intervals with acks) across loss rates — the paper's
   claim that delta-intervals keep payloads small under loss/dup/reorder.

2. The shipping-policy axis on the unified propagation runtime: the same
   seeded workload runs under every policy in ``POLICY_SPECS`` (ship-all,
   state-every-k, avoid-back-propagation, remove-redundant, bp+rr) across
   loss / duplication / partition scenarios. Invariants asserted here
   (and unit-tested in tests/test_propagation.py): every policy converges
   to the same state, and BP+RR ships strictly fewer payload bytes than
   ship-all.

Every replica gossips through the binary δ-wire codec, so byte reports
are **measured encoded-frame lengths** (``len(frame)``), not structural
atom estimates.
"""

from __future__ import annotations

import random
import time
from typing import List, Tuple

from repro.core import (AWORSet, BasicNode, CausalNode, GCounter, NetConfig,
                        POLICY_SPECS, Simulator, make_policy,
                        run_to_convergence)
from repro.wire import WireCodec

WIRE = WireCodec()


def _workload(nodes, sim, rng, n_ops=60):
    for _ in range(n_ops):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.add_delta(i, rng.choice(
            [f"e{k}" for k in range(20)])))
        sim.run_for(0.4)


def _payload_atoms(sim) -> int:
    return sim.stats.payload_atoms()


def algo_rows() -> List[Tuple[str, float, str]]:
    rows = []
    for loss in (0.0, 0.2, 0.4):
        for algo in ("alg1_basic", "alg2_causal"):
            sim = Simulator(NetConfig(loss=loss, dup=0.15, seed=11))
            ids = [f"n{k}" for k in range(4)]
            if algo == "alg1_basic":
                nodes = [sim.add_node(BasicNode(
                    i, AWORSet.bottom(), [j for j in ids if j != i],
                    transitive=True, ship_state_every=5, wire=WIRE))
                    for i in ids]
            else:
                nodes = [sim.add_node(CausalNode(
                    i, AWORSet.bottom(), [j for j in ids if j != i],
                    rng=random.Random(13), wire=WIRE)) for i in ids]
            rng = random.Random(17)
            t0 = time.perf_counter()
            _workload(nodes, sim, rng)
            t_conv = run_to_convergence(sim, nodes, interval=1.0,
                                        max_time=60_000)
            wall_us = (time.perf_counter() - t0) * 1e6
            payload = _payload_atoms(sim)
            rows.append((
                f"antientropy_{algo}_loss={loss}", wall_us,
                f"frame_bytes={payload} sim_t_conv={t_conv:.0f} "
                f"msgs={sim.stats.sent} dropped={sim.stats.dropped}"))
    return rows


def _counter_workload(nodes, sim, rng, n_ops=60, crash_at=None):
    """GCounter increments with an optional mid-workload crash (ops on a
    down node are skipped, like the elastic-training drivers do)."""
    for k in range(n_ops):
        n = rng.choice(nodes)
        if n.alive:
            n.operation(lambda X, i=n.id: X.inc_delta(i))
        sim.run_for(0.4)
        if crash_at is not None and k == crash_at:
            sim.crash(nodes[0].id, downtime=4.0)


def policy_rows() -> List[Tuple[str, float, str]]:
    """Bytes-shipped per shipping policy, same workload, same topology."""
    scenarios = [
        ("clean", dict(loss=0.0, dup=0.0)),
        ("loss=0.2", dict(loss=0.2, dup=0.15)),
        ("loss=0.4", dict(loss=0.4, dup=0.15)),
        ("partition", dict(loss=0.1, dup=0.1)),
        ("crash", dict(loss=0.1, dup=0.1)),   # GCounter + mid-run crash:
        # the recovery full-state fallback gets buffered at receivers and
        # re-gossiped — the case RemoveRedundant's part-wise trim targets
    ]
    rows = []
    for label, net in scenarios:
        payload_by = {}
        final_by = {}
        for spec in POLICY_SPECS:
            sim = Simulator(NetConfig(seed=11, **net))
            ids = [f"n{k}" for k in range(4)]
            if label == "partition":
                sim.add_partition(4.0, 18.0, ids[:2], ids[2:])
            bottom = (GCounter.bottom() if label == "crash"
                      else AWORSet.bottom())
            nodes = [sim.add_node(CausalNode(
                i, bottom, [j for j in ids if j != i],
                rng=random.Random(13), policy=make_policy(spec),
                wire=WIRE)) for i in ids]
            rng = random.Random(17)
            t0 = time.perf_counter()
            if label == "crash":
                _counter_workload(nodes, sim, rng, crash_at=30)
            else:
                _workload(nodes, sim, rng)
            t_conv = run_to_convergence(sim, nodes, interval=1.0,
                                        max_time=60_000)
            wall_us = (time.perf_counter() - t0) * 1e6
            payload_by[spec] = _payload_atoms(sim)
            final_by[spec] = nodes[0].X
            rows.append((
                f"antientropy_policy={spec}_{label}", wall_us,
                f"frame_bytes={payload_by[spec]} "
                f"sim_t_conv={t_conv:.0f} msgs={sim.stats.sent}"))
        # identical workload ⇒ identical converged state under every policy
        states = list(final_by.values())
        assert all(s == states[0] for s in states[1:]), \
            f"{label}: policies diverged"
        assert payload_by["bp+rr"] < payload_by["all"], (
            f"{label}: bp+rr shipped {payload_by['bp+rr']} frame bytes, "
            f"ship-all {payload_by['all']} — BP+RR must be strictly "
            f"smaller")
        rows.append((
            f"antientropy_policy_savings_{label}",
            payload_by["all"] - payload_by["bp+rr"],
            f"bp+rr={payload_by['bp+rr']} vs ship-all={payload_by['all']} "
            f"frame bytes ({payload_by['bp+rr'] / payload_by['all']:.2f}x)"))
    return rows


def run() -> List[Tuple[str, float, str]]:
    return algo_rows() + policy_rows()
