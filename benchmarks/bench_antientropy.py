"""Anti-entropy under unreliable networks: bytes + time to convergence for
Algorithm 1 (basic, with periodic full-state fallback) vs Algorithm 2
(causal delta-intervals with acks), across loss rates. The paper's claim:
delta-intervals keep payloads small while tolerating loss/dup/reorder."""

from __future__ import annotations

import random
import time
from typing import List, Tuple

from repro.core import (AWORSet, BasicNode, CausalNode, GCounter, NetConfig,
                        Simulator, run_to_convergence)


def _workload(nodes, sim, rng, n_ops=60):
    for _ in range(n_ops):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.add_delta(i, rng.choice(
            [f"e{k}" for k in range(20)])))
        sim.run_for(0.4)


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for loss in (0.0, 0.2, 0.4):
        for algo in ("alg1_basic", "alg2_causal"):
            sim = Simulator(NetConfig(loss=loss, dup=0.15, seed=11))
            ids = [f"n{k}" for k in range(4)]
            if algo == "alg1_basic":
                nodes = [sim.add_node(BasicNode(
                    i, AWORSet.bottom(), [j for j in ids if j != i],
                    transitive=True, ship_state_every=5)) for i in ids]
            else:
                nodes = [sim.add_node(CausalNode(
                    i, AWORSet.bottom(), [j for j in ids if j != i],
                    rng=random.Random(13))) for i in ids]
            rng = random.Random(17)
            t0 = time.perf_counter()
            _workload(nodes, sim, rng)
            t_conv = run_to_convergence(sim, nodes, interval=1.0,
                                        max_time=60_000)
            wall_us = (time.perf_counter() - t0) * 1e6
            payload = sum(v for k, v in sim.stats.bytes_by_kind.items()
                          if k in ("delta", "state"))
            rows.append((
                f"antientropy_{algo}_loss={loss}", wall_us,
                f"payload_atoms={payload} sim_t_conv={t_conv:.0f} "
                f"msgs={sim.stats.sent} dropped={sim.stats.dropped}"))
    return rows
