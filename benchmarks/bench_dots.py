"""Columnar dot-store fast path: join throughput and per-dot reconnect.

Three claims measured and asserted (regressions fail the suite):

1. **Columnar causal joins are ≥10× the frozenset path at 1M dots** —
   and bit-identical to it. The object-path join (dots.py: frozensets +
   per-dot ``contains``) is the paper-shaped oracle; the columnar path
   (dotcols.py: sorted-merge / searchsorted over packed int64 columns)
   must produce exactly the same store and context, an order of
   magnitude faster.

2. **Per-dot digest reconnect ships a few % of full state.** A replica
   holding a ~1M-dot ORMap that missed a sparse spray of writes and
   removals pulls exactly the missing/removed dots through the digest
   request/response engine path (request carries the per-dot causal
   summary, the responder filters at encode time); total pull bytes
   must be ≤5% of the ONE full-state frame the push fallback would
   ship — and land in exactly the responder's state.

3. **The contiguous-append fast path in ``CausalContext.add_dots``**
   beats the generic dict+set+normalize path on the per-op δ-mutator
   workload (each replica appending its own next dot).

States at this scale are built directly as packed columns — driving a
million Python mutator calls would benchmark the test harness, not the
join. The columnar/object equivalence at small sizes is property-tested
in tests/test_dotcols*.py; here the oracle check runs once at 1M dots.
"""

from __future__ import annotations

import random
import time
from typing import List, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# 1M-dot causal join: columnar vs the frozenset oracle
# ---------------------------------------------------------------------------

def _join_inputs(per_rid: int):
    """Two divergent DotSet states over rids a..d with realistic overlap:
    shared live dots, dots only one side has seen, and dots the other
    side has observed-and-removed (covered by its context but absent
    from its store)."""
    from repro.core.dotcols import CausalContextCols, DotSetCols, SEQ_BITS

    rids = ("a", "b", "c", "d")

    def packed(rid_idx: int, lo: int, hi: int) -> np.ndarray:
        return ((np.int64(rid_idx) << SEQ_BITS)
                | np.arange(lo, hi + 1, dtype=np.int64))

    n = per_rid
    # A owns a+b fully; has seen c up to n//2 (and removed all of it)
    sa = DotSetCols(rids, np.concatenate(
        [packed(0, 1, n), packed(1, 1, n)]))
    ca = CausalContextCols(rids, np.array([n, n, n // 2, 0], np.int64),
                           np.zeros(0, np.int64))
    # B owns c+d fully; has seen a up to n//4 (still live at B) and
    # b up to n//5 (removed at B)
    sb = DotSetCols(rids, np.concatenate(
        [packed(0, 1, n // 4), packed(2, 1, n), packed(3, 1, n)]))
    cb = CausalContextCols(rids, np.array([n // 4, n // 5, n, n], np.int64),
                           np.zeros(0, np.int64))
    return sa, ca, sb, cb


def join_rows() -> List[Tuple[str, float, str]]:
    from repro.core.dotcols import causal_join_cols
    from repro.core.dots import causal_join

    import gc

    per_rid = 250_000                       # 4 rids ⇒ 1M dots total
    sa, ca, sb, cb = _join_inputs(per_rid)
    total = sa.packed.size + sb.packed.size

    # object-path inputs built OUTSIDE the timed region — the oracle
    # timing measures the frozenset join, not the representation change
    oa, ob = sa.to_obj(), sb.to_obj()
    coa, cob = ca.to_obj(), cb.to_obj()
    gc.collect()
    t0 = time.perf_counter()
    so, co = causal_join(oa, coa, ob, cob)
    obj_us = (time.perf_counter() - t0) * 1e6

    gc.collect()                            # don't bill the object-path
    col_us = float("inf")                   # garbage to the fast path
    for _ in range(5):
        t0 = time.perf_counter()
        sc, cc = causal_join_cols(sa, ca, sb, cb)
        col_us = min(col_us, (time.perf_counter() - t0) * 1e6)

    assert sc.to_obj() == so and cc.to_obj() == co, \
        "columnar 1M-dot join diverged from the frozenset oracle"
    speedup = obj_us / col_us
    assert speedup >= 10.0, (
        f"columnar join is only {speedup:.1f}x the frozenset path "
        f"({col_us:.0f}us vs {obj_us:.0f}us at {total} dots; claim: >=10x)")
    return [
        ("dots_join_1M_columnar", col_us,
         f"{speedup:.0f}x over frozenset path ({obj_us / 1e6:.2f}s), "
         f"bit-identical result, {total} input dots"),
        ("dots_join_1M_frozenset", obj_us,
         "object-path oracle for the same join"),
    ]


# ---------------------------------------------------------------------------
# Per-dot digest reconnect on a ~1M-dot ORMap (engine path)
# ---------------------------------------------------------------------------

def _big_ormap(n_keys: int, per_key: int, *, missing_tail: int,
               removed_head: int):
    """Requester/responder pair of ~(n_keys × per_key)-dot ORMaps of
    AWORSets, one rid per key. Every 10th key: the requester missed the
    last ``missing_tail`` writes. Every 10th key offset 5: the responder
    removed the first ``removed_head`` elements (requester still holds
    them live). All other keys agree."""
    from repro.core.crdts import ORMap
    from repro.core.dotcols import (CausalContextCols, DotMapCols,
                                    SEQ_BITS, SHAPE_FUN)

    rids = tuple(f"r{j:04d}" for j in range(n_keys))
    keys = tuple(f"k{j:04d}" for j in range(n_keys))

    def build(missed: bool):
        cols, vals, counts = [], [], []
        vv = np.full(n_keys, per_key, np.int64)
        for j in range(n_keys):
            lo, hi = 1, per_key
            if missed and j % 10 == 0:
                hi = per_key - missing_tail     # writes not yet seen
                vv[j] = hi
            if not missed and j % 10 == 5:
                lo = removed_head + 1           # responder removed these
            seqs = np.arange(lo, hi + 1, dtype=np.int64)
            cols.append((np.int64(j) << SEQ_BITS) | seqs)
            vals.append(seqs)                   # element == its seq
            counts.append(seqs.size)
        packed = np.concatenate(cols)
        v = np.empty(packed.size, object)
        v[:] = np.concatenate(vals)
        offsets = np.zeros(n_keys + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        store = DotMapCols(rids, keys, bytes([SHAPE_FUN]) * n_keys,
                           offsets, packed, v)
        ctx = CausalContextCols(rids, vv.copy(), np.zeros(0, np.int64))
        return ORMap(store, ctx)

    return build(missed=True), build(missed=False)


def reconnect_rows() -> List[Tuple[str, float, str]]:
    from repro.core import (LatticeStore, NetConfig, Simulator,
                            StoreReplica, make_policy)
    from repro.wire import WireCodec, encode_frame, encode_value

    req_map, resp_map = _big_ormap(2000, 500, missing_tail=10,
                                   removed_head=5)
    total = resp_map.store.packed.size

    wire = WireCodec()
    sim = Simulator(NetConfig(loss=0.0, seed=21))
    stale = sim.add_node(StoreReplica(
        "stale", ["peer"], causal=True, wire=wire,
        policy=make_policy("digest-sync"), rng=random.Random(3)))
    peer = sim.add_node(StoreReplica(
        "peer", ["stale"], causal=True, wire=wire,
        policy=make_policy("digest-sync"), rng=random.Random(3)))
    stale.X = LatticeStore.of({"map": req_map})
    peer.X = LatticeStore.of({"map": resp_map})

    t0 = time.perf_counter()
    stale.on_periodic()                 # digest out → per-dot resp back
    sim.run_for(5.0)
    wall_us = (time.perf_counter() - t0) * 1e6
    assert stale.X == peer.X, "per-dot digest catch-up did not converge"

    catchup = sim.stats.pull_bytes()
    req_b = sim.stats.bytes_by_kind.get("digest", 0)
    full = len(encode_frame("state", encode_value(peer.X)))
    ratio = catchup / full
    assert 0 < catchup <= 0.05 * full, (
        f"per-dot reconnect cost {catchup}B = {ratio:.2%} of the {full}B "
        f"full-state frame (claim: <=5%)")
    return [
        ("dots_reconnect_1M_bytes", catchup,
         f"digest req {req_b}B + resp {catchup - req_b}B = {ratio:.2%} "
         f"of full state ({total}-dot ORMap, {wall_us:.0f}us wall)"),
        ("dots_reconnect_full_state_bytes", full,
         "the ONE full-state frame the push fallback would ship"),
    ]


# ---------------------------------------------------------------------------
# add_dots contiguous-append fast path vs the generic normalize path
# ---------------------------------------------------------------------------

def add_dots_rows() -> List[Tuple[str, float, str]]:
    from repro.core.dots import CausalContext, _normalize

    # per-op appenders plus a realistic cloud: non-causal anti-entropy
    # left gapped dots from OTHER replicas (the fast path must not copy
    # or re-normalize them just to extend a local prefix)
    base = CausalContext.from_vv({f"r{i}": 1000 for i in range(64)})
    base = base.add_dots(tuple(("gossip", 2 * k) for k in range(1, 513)))
    assert len(base.cloud) == 512
    batches = [tuple((f"r{i}", 1001 + k) for k in range(4))
               for i in range(64)]
    reps = 40

    t0 = time.perf_counter()
    for _ in range(reps):
        for b in batches:
            fast = base.add_dots(b)
    fast_us = (time.perf_counter() - t0) * 1e6 / (reps * len(batches))

    def slow(cc, ds):                   # the pre-fast-path behavior
        vv = dict(cc.vv)
        cloud = set(cc.cloud)
        for d in ds:
            if d[1] > vv.get(d[0], 0):
                cloud.add(d)
        return _normalize(vv, cloud)

    t0 = time.perf_counter()
    for _ in range(reps):
        for b in batches:
            ref = slow(base, b)
    slow_us = (time.perf_counter() - t0) * 1e6 / (reps * len(batches))

    assert fast == slow(base, batches[-1]), "fast path diverged"
    speedup = slow_us / fast_us
    assert speedup > 1.0, (
        f"contiguous-append fast path is not faster: {fast_us:.1f}us vs "
        f"{slow_us:.1f}us")
    return [
        ("dots_add_dots_append", fast_us,
         f"{speedup:.1f}x over dict+set+normalize ({slow_us:.1f}us), "
         "64-replica context + 512-dot cloud, 4-dot batches"),
    ]


def run() -> List[Tuple[str, float, str]]:
    return join_rows() + reconnect_rows() + add_dots_rows()


if __name__ == "__main__":  # pragma: no cover - manual entry point
    for name, val, derived in run():
        print(f"{name},{val:.1f},{derived}")
