"""Kernel microbenchmarks (CPU proxies).

The Pallas kernels target TPU; on this CPU-only container we time the jnp
oracle (the XLA path the dry-run lowers) and run the Pallas kernel once in
interpret mode for a correctness pulse. Real-hardware numbers belong to a
TPU run of the same entry points."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _time(f, *args, iters=5) -> float:
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[Tuple[str, float, str]]:
    rows = []
    rng = np.random.default_rng(0)

    # attention fwd: b=1 h=8 kv=2 s=1024 hd=128
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 128)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 128)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 1024, 128)).astype(np.float32))
    us = _time(jax.jit(ops.attention_ref), q, k, v)
    flops = 2 * 2 * 8 * 1024 * 1024 * 128
    rows.append(("attention_xla_b1h8s1024", us,
                 f"{flops / (us / 1e6) / 1e9:.1f} GFLOP/s CPU"))
    out_i = ops.flash_attention(q[:, :, :256], k[:, :, :256], v[:, :, :256],
                                block_q=128, block_k=128, interpret=True)
    ref_i = ops.attention_ref(q[:, :, :256], k[:, :, :256], v[:, :, :256])
    ok = bool(jnp.allclose(out_i, ref_i, rtol=2e-5, atol=2e-5))
    rows.append(("flash_attention_pallas_interpret_s256", float("nan"),
                 f"allclose_vs_ref={ok}"))

    # decode against a 32k cache: b=4 h=8 kv=2 hd=128
    C = 32768
    kc = jnp.asarray(rng.normal(size=(4, 2, C, 128)).astype(np.float32))
    vc = jnp.asarray(rng.normal(size=(4, 2, C, 128)).astype(np.float32))
    qd = jnp.asarray(rng.normal(size=(4, 8, 1, 128)).astype(np.float32))
    kpos = jnp.broadcast_to(jnp.arange(C)[None], (4, C)).astype(jnp.int32)
    qpos = jnp.full((4, 1), C, jnp.int32)
    us = _time(jax.jit(ops.decode_ref), qd, kc, vc, qpos, kpos)
    bytes_moved = 2 * 4 * 2 * C * 128 * 4
    rows.append(("decode_xla_b4_cache32k", us,
                 f"{bytes_moved / (us / 1e6) / 1e9:.1f} GB/s CPU"))
    return rows
