"""Key-lifecycle benchmarks: the store *shrinks* again.

Claims measured and asserted (regressions fail the suite):

1. **Resident bytes return to ~baseline after sessions expire.** A fleet
   of gateways serves N tensor-valued session keys under a TTL; once the
   sessions see their last write and the acked reaper runs, the resident
   store bytes across the whole fleet must be ≤ 15% of the peak — what
   tombstone GC is *for*. Asserted in object mode and wire (binary
   frame) mode.

2. **A partitioned straggler rejoining with pre-reap deltas converges to
   the reaped state.** The straggler holds (and replays) deltas written
   before the reap; after the partition heals, every write-set member
   still shows the tombstone, the replayed delta is ⊥-absorbed, and the
   straggler's own copy drains. No resurrection, in both modes.

3. **Read-replica hot-key reads converge without joining the write
   set.** A subscriber outside a hot key's write replica set serves the
   key's latest value pulled via digest-sync, never buffers/forwards
   the key, and never appears in its reap quorum.
"""

from __future__ import annotations

import random
from typing import List, Tuple

import numpy as np


def _nbytes(store) -> int:
    """Resident bytes of a store: tensor payload bytes (dense or sparse)
    plus a nominal 24B per lifecycle entry (key + epoch + expiry)."""
    total = 0
    for _, val in store.entries:
        chunks = getattr(val, "chunks", None)
        if chunks is None:
            total += 64                      # nominal opaque value
            continue
        for _, ct in chunks:
            if getattr(ct, "is_sparse", False):
                total += (ct.idx.nbytes + np.asarray(ct.vals).nbytes
                          + np.asarray(ct.vers).nbytes)
            else:
                total += (np.asarray(ct.values).nbytes
                          + np.asarray(ct.versions).nbytes)
    total += 24 * len(store.life)
    return total


def _fleet(wire, seed=0, ttl=6.0, n_gw=3, loss=0.1):
    from repro.core import (Compose, NetConfig, Simulator, StoreReplica,
                            make_policy)
    from repro.lifecycle import ReaperProtocol
    from repro.sync import KeyOwnership, ShardByKey

    ids = [f"gw{k}" for k in range(n_gw)]
    ownership = KeyOwnership(ids, replication=2)
    sim = Simulator(NetConfig(loss=loss, seed=seed))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=Compose(make_policy("bp+rr+digest-sync:4"),
                       ShardByKey(ownership)),
        rng=random.Random(seed + k), ownership=ownership, wire=wire,
        ttl=ttl)) for k, i in enumerate(ids)]
    for node in nodes:
        ReaperProtocol(node, ownership, grace=1.0, retry=2.0)
        sim.every(1.0, node.on_periodic)
        sim.every(6.0, node.gc_deltas)
    return sim, nodes, ownership


def expiry_rows(wire=None, tag="object") -> List[Tuple[str, float, str]]:
    from repro.core.tensor_lattice import TensorState

    sim, nodes, ownership = _fleet(wire, seed=3)
    by_id = {n.id: n for n in nodes}
    rng = np.random.default_rng(0)
    n_sessions, n_chunks, chunk = 24, 4, 64
    keys = [f"sess{i:03d}" for i in range(n_sessions)]
    for i, key in enumerate(keys):
        node = nodes[i % len(nodes)]
        node.update(key, TensorState, "write_delta", i % 3, "kv",
                    rng.normal(size=(n_chunks * chunk,)).astype(np.float32),
                    None, chunk)
        sim.run_for(0.5)
    sim.run_for(6.0)                 # replicate out; sessions now idle
    peak = sum(_nbytes(n.X) for n in nodes)

    def all_reaped() -> bool:
        tombs = {i: by_id[i].X.tombstoned_keys() for i in by_id}
        return all(key in tombs[w]
                   for key in keys for w in ownership.owners(key))

    t0 = sim.time
    while sim.time - t0 < 600.0:     # expiry passes; reaper drains
        sim.run_for(5.0)
        if all_reaped():
            break
    sim.run_for(10.0)                # let foreign eviction finish
    resident = sum(_nbytes(n.X) for n in nodes)
    ratio = resident / max(peak, 1)
    assert all_reaped(), f"[{tag}] sessions past their TTL were not reaped"
    assert ratio <= 0.15, (
        f"[{tag}] resident bytes after reap are {resident}B = "
        f"{ratio:.1%} of the {peak}B peak (claim: ≤15%)")
    return [
        (f"lifecycle_{tag}_peak_bytes", peak,
         f"{n_sessions} tensor sessions over {len(nodes)} gateways"),
        (f"lifecycle_{tag}_post_reap_bytes", resident,
         f"{ratio:.1%} of peak after TTL + acked reap (claim ≤15%)"),
    ]


def straggler_rows(wire=None, tag="object") -> List[Tuple[str, float, str]]:
    from repro.core import MVRegister

    sim, nodes, ownership = _fleet(wire, seed=11, loss=0.0)
    by_id = {n.id: n for n in nodes}
    owners = ownership.owners("ghost")
    straggler_id = [i for i in by_id if i not in owners][0]
    straggler = by_id[straggler_id]
    straggler.update("ghost", MVRegister, "write_delta", straggler_id, "v")
    sim.run_for(3.0)                 # the write reaches the owners
    pre_reap = straggler.X.restrict(["ghost"])
    assert pre_reap.keys() == {"ghost"}
    sim.add_partition(sim.time, sim.time + 40.0, [straggler_id],
                      [i for i in by_id if i != straggler_id])
    sim.run_for(45.0)                # owners reap behind the partition
    assert all(by_id[w].X.tombstoned("ghost") for w in owners)
    # heal: the straggler rejoins and replays its pre-reap delta straight
    # at every owner (the arbitrarily-late retransmission the network
    # model allows)
    rounds = 0
    for w in owners:
        msg = ("handoff", pre_reap)
        by_id[w].on_receive(straggler_id,
                            wire.encode_msg(msg) if wire else msg)
    while rounds < 60:
        sim.run_for(1.0)
        rounds += 1
        if ("ghost" not in straggler.X.all_keys()
                or straggler.X.tombstoned("ghost")):
            break
    assert all(by_id[w].X.tombstoned("ghost") for w in owners), \
        f"[{tag}] straggler replay resurrected a reaped key"
    assert ("ghost" not in straggler.X.all_keys()
            or straggler.X.tombstoned("ghost")), \
        f"[{tag}] straggler did not converge to the reaped state"
    return [(f"lifecycle_{tag}_straggler_rounds", rounds,
             "rounds after heal until the straggler reached the reaped "
             "state (replays absorbed)")]


def read_replica_rows() -> List[Tuple[str, float, str]]:
    from repro.core import LatticeStore, MVRegister
    from repro.wire import WireCodec

    sim, nodes, ownership = _fleet(WireCodec(), seed=41, n_gw=4, loss=0.0)
    by_id = {n.id: n for n in nodes}
    owners = ownership.owners("hot")
    reader_id = [i for i in by_id if i not in owners][0]
    reader = by_id[reader_id]
    ownership.subscribe(reader_id, "hot")
    writer = by_id[owners[0]]
    rounds = 0
    for t in range(10):
        writer.update("hot", MVRegister, "write_delta", writer.id, f"v{t}")
        sim.run_for(1.0)
        rounds += 1
    while reader.X.get("hot", MVRegister).read() != frozenset({"v9"}):
        sim.run_for(1.0)
        rounds += 1
        assert rounds < 60, "read replica never converged on the hot key"
    assert reader_id not in ownership.owners("hot")
    assert all("hot" not in e.delta.all_keys()
               for e in reader.entries.values()
               if isinstance(e.delta, LatticeStore)), \
        "read replica buffered the hot key (joined the write gossip)"
    return [("lifecycle_read_replica_rounds", rounds,
             "writes+rounds until a digest-sync subscriber outside the "
             "write set served the latest hot-key value")]


def run() -> List[Tuple[str, float, str]]:
    from repro.wire import WireCodec

    rows = []
    rows += expiry_rows(None, "object")
    rows += expiry_rows(WireCodec(), "wire")
    rows += straggler_rows(None, "object")
    rows += straggler_rows(WireCodec(), "wire")
    rows += read_replica_rows()
    return rows


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val},{derived}")
