"""Paper §9 — bit-message complexity tables.

Three claims, one table each:

  counter : δ ships Õ(α) recently-updated entries vs Õ(|I|) full map
  OR-Set  : δ ships O(s) recent updates vs O(S) full state
  MVR     : optimized scalar-dot MVR is Õ(|I|) vs classic per-value
            version-vector MVR's Õ(|I|²) worst-case state/message size

Sizes are structural atom counts (the paper's Õ ignores log factors in
ints/ids). ``ClassicMVRegister`` (per-value version vectors) is implemented
here as the comparison baseline the paper argues against.

``protocol_bytes_table`` additionally carries the shipping-policy axis:
the delta protocol runs under ship-all and under BP+RR (unified
propagation runtime), so the end-to-end table shows classical full-state
≫ deltas ≫ deltas+BP+RR.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.core import (AWORSet, CausalNode, FullStateNode, GCounter,
                        MVRegister, NetConfig, Simulator, converged,
                        make_policy, run_to_convergence, structural_size)


# ---------------------------------------------------------------------------
# Classic MVR baseline (per-value version vectors — what Fig. 4 replaces)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ClassicMVRegister:
    """State: set of (value, version-vector) pairs; join keeps maximal
    elements under vv dominance. Worst case |I| siblings × |I|-entry vvs."""

    entries: Tuple[Tuple[object, Tuple[Tuple[str, int], ...]], ...] = ()

    @staticmethod
    def bottom() -> "ClassicMVRegister":
        return ClassicMVRegister()

    def _vvs(self):
        return [dict(vv) for _, vv in self.entries]

    def write_full(self, i: str, v: object) -> "ClassicMVRegister":
        # new vv dominates all current siblings
        merged: Dict[str, int] = {}
        for vv in self._vvs():
            for r, n in vv.items():
                merged[r] = max(merged.get(r, 0), n)
        merged[i] = merged.get(i, 0) + 1
        return ClassicMVRegister(((v, tuple(sorted(merged.items()))),))

    def read(self):
        return frozenset(v for v, _ in self.entries)

    def join(self, other: "ClassicMVRegister") -> "ClassicMVRegister":
        def dominates(a: Dict[str, int], b: Dict[str, int]) -> bool:
            return all(a.get(r, 0) >= n for r, n in b.items()) and a != b

        cand = list(self.entries) + [e for e in other.entries
                                     if e not in self.entries]
        keep = []
        for v, vv in cand:
            dvv = dict(vv)
            if not any(dominates(dict(vv2), dvv)
                       for v2, vv2 in cand if (v2, vv2) != (v, vv)):
                keep.append((v, vv))
        return ClassicMVRegister(tuple(sorted(keep, key=repr)))


# ---------------------------------------------------------------------------
# Tables
# ---------------------------------------------------------------------------

def counter_table() -> List[Tuple[str, float, str]]:
    """Avg per-message payload size: full-state vs δ, growing |I|."""
    rows = []
    for n_reps in (4, 16, 64, 256):
        # build a converged counter with n_reps replicas' entries
        X = GCounter.bottom()
        for k in range(n_reps):
            X = X.join(X.inc_delta(f"r{k}"))
        full_size = structural_size(X)
        delta_size = structural_size(X.inc_delta("r0"))
        rows.append((f"counter_full_state_I={n_reps}", full_size,
                     f"entries={n_reps}"))
        rows.append((f"counter_delta_I={n_reps}", delta_size,
                     f"ratio={full_size / delta_size:.1f}x"))
    return rows


def orset_table() -> List[Tuple[str, float, str]]:
    rows = []
    for S in (100, 1_000, 10_000):
        X = AWORSet.bottom()
        for k in range(S):
            X = X.join(X.add_delta("r0", f"e{k}"))
        full_size = structural_size(X)
        # a burst of u = 10 fresh updates shipped as one delta-group
        delta = AWORSet.bottom()
        Y = X
        for k in range(10):
            d = Y.add_delta("r0", f"new{k}")
            Y = Y.join(d)
            delta = delta.join(d)
        d_size = structural_size(delta)
        rows.append((f"orset_full_state_S={S}", full_size, f"elems={S}"))
        rows.append((f"orset_delta_u=10_S={S}", d_size,
                     f"ratio={full_size / d_size:.1f}x"))
    return rows


def mvr_table() -> List[Tuple[str, float, str]]:
    rows = []
    for I in (4, 16, 64):
        # worst case (paper §9): |I| writers that have OBSERVED each other
        # (their vvs cover all of 𝕀) write concurrently — classic keeps |I|
        # siblings × |I|-entry version vectors.
        opt_base = MVRegister.bottom()
        cls_base = ClassicMVRegister.bottom()
        for k in range(I):  # a first, fully-synced round of writes
            opt_base = opt_base.join(opt_base.write_delta(f"r{k}", -1))
            cls_base = cls_base.join(cls_base.write_full(f"r{k}", -1))
        opt = MVRegister.bottom()
        cls = ClassicMVRegister.bottom()
        for k in range(I):  # concurrent writes from the common base
            opt = opt.join(opt_base.write_delta(f"r{k}", k))
            cls = cls.join(cls_base.write_full(f"r{k}", k))
        assert opt.read() == cls.read() == frozenset(range(I))
        so, sc = structural_size(opt), structural_size(cls)
        rows.append((f"mvr_optimized_I={I}", so, "O(I) scalar dots"))
        rows.append((f"mvr_classic_vv_I={I}", sc,
                     f"O(I^2); ratio={sc / so:.1f}x"))
    return rows


def protocol_bytes_table() -> List[Tuple[str, float, str]]:
    """End-to-end §9: total protocol bytes to propagate 20 fresh updates on
    a grown OR-Set — classical full-state shipping vs Algorithm 2 deltas.
    Every replica gossips through the binary δ-wire codec, so the byte
    column is **measured encoded-frame lengths**, not structural atoms."""
    from repro.wire import WireCodec

    wire = WireCodec()
    rows = []
    for S in (200, 2_000):
        for proto in ("full-state", "delta", "delta+bp+rr"):
            sim = Simulator(NetConfig(loss=0.1, seed=5))
            ids = [f"n{k}" for k in range(3)]
            if proto == "full-state":
                mk = lambda i: FullStateNode(i, AWORSet.bottom(),
                                             [j for j in ids if j != i],
                                             wire=wire)
            else:
                policy = (make_policy("bp+rr") if proto == "delta+bp+rr"
                          else None)
                mk = lambda i, p=policy: CausalNode(
                    i, AWORSet.bottom(), [j for j in ids if j != i],
                    rng=random.Random(7), policy=p, wire=wire)
            nodes = [sim.add_node(mk(i)) for i in ids]
            # pre-grow the set on node 0 then sync everyone
            for k in range(S):
                if proto == "full-state":
                    nodes[0].operation(lambda X, k=k: X.add_full("n0", f"e{k}"))
                else:
                    nodes[0].operation(lambda X, k=k: X.add_delta("n0", f"e{k}"))
            run_to_convergence(sim, nodes, interval=1.0, max_time=30_000)
            sim.run_for(30.0)
            for n in nodes:
                if isinstance(n, CausalNode):
                    n.gc_deltas()
            sim.stats.bytes_by_kind.clear()
            # now 20 fresh updates
            t0 = time.perf_counter()
            for k in range(20):
                if proto == "full-state":
                    nodes[k % 3].operation(
                        lambda X, k=k: X.add_full(f"n{k % 3}", f"f{k}"))
                else:
                    nodes[k % 3].operation(
                        lambda X, k=k: X.add_delta(f"n{k % 3}", f"f{k}"))
                sim.run_for(2.0)
            run_to_convergence(sim, nodes, interval=1.0, max_time=30_000)
            dt = (time.perf_counter() - t0) * 1e6
            payload = sim.stats.payload_atoms()
            rows.append((f"protocol_{proto}_S={S}", payload,
                         f"measured frame bytes to propagate 20 updates "
                         f"(wall {dt:.0f}us)"))
    return rows


def run() -> List[Tuple[str, float, str]]:
    return (counter_table() + orset_table() + mvr_table()
            + protocol_bytes_table())
