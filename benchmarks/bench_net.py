"""Real-network benchmark: load-generated gossip over loopback sockets.

Three scenarios, each asserting the production claim it measures:

* **UDP load generator** — a 3-node in-process cluster on real loopback
  UDP sockets with 10% injected datagram loss; a load generator drives
  sustained multi-client write traffic and samples marker keys to
  measure *convergence latency* (write → visible on every node).
  Reports throughput and p50/p99 latency; asserts every marker
  converged under loss (δ-drops are repaired by acks + digest-sync,
  never fatal).

* **TCP kill/restart** — a 3-node TCP cluster; one member is killed
  mid-run (durable state snapshotted, sockets aborted), the survivors
  keep writing, and the member restarts on the same port. The dialers
  reconnect and digest-sync pulls exactly what it missed: asserted to
  cost a small fraction of re-shipping the survivors' full state.

* **3-process cluster** — the real thing: three ``serve.py --listen
  --peers`` OS processes on loopback UDP with injected loss, each
  writing its share of the session keys, observed purely from the
  outside via ``--status-file`` heartbeats until their semantic
  fingerprints agree. This is the row the CI ``net-smoke`` job runs.
  A second, zone-annotated variant (``gwN@host:port@zN``) runs the same
  cluster under hierarchical gossip and asserts the heartbeats report
  each member's zone and per-link-class byte counters.

Byte numbers are ``LinkStats`` — the same per-payload-kind counters the
simulator's ``NetStats`` reports, so these rows compare directly with
``bench_wire``'s sim rows.
"""

from __future__ import annotations

import asyncio
import json
import os
import socket
import subprocess
import sys
import tempfile
import time
from typing import List, Tuple

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _percentile(xs: List[float], p: float) -> float:
    ys = sorted(xs)
    if not ys:
        return float("nan")
    i = min(len(ys) - 1, max(0, int(round(p * (len(ys) - 1)))))
    return ys[i]


# ---------------------------------------------------------------------------
# UDP load generator: throughput + convergence latency under loss
# ---------------------------------------------------------------------------

async def _udp_loadgen(n_writes: int = 240, keyspace: int = 48,
                       marker_every: int = 8, loss: float = 0.10,
                       traced: bool = True
                       ) -> Tuple[float, float, float, float, dict, dict]:
    from repro.core import MVRegister
    from repro.net import start_cluster, stop_cluster, wait_converged
    from repro.obs import (Tracer, global_registry, marker_lag_histogram,
                           report)

    tracers: dict = {}

    def tracer_factory(node_id):
        tracers[node_id] = Tracer(node=node_id)
        return tracers[node_id]

    nodes = await start_cluster(3, transport="udp", tick=0.05,
                                loss=loss, seed=11,
                                tracer_factory=(tracer_factory if traced
                                                else None))
    lat: List[float] = []
    pending: dict = {}

    def sweep() -> None:
        for mk, t0 in list(pending.items()):
            if all(n.replica.get(mk, MVRegister) is not None
                   for n in nodes):
                lat.append(time.monotonic() - t0)
                del pending[mk]

    t_start = time.monotonic()
    for i in range(n_writes):
        node = nodes[i % len(nodes)]          # multi-client ingress
        node.update(f"k{i % keyspace}", MVRegister, "write_delta",
                    node.id, i)
        if i % marker_every == 0:
            mk = f"m{i}"
            node.update(mk, MVRegister, "write_delta", node.id, i)
            pending[mk] = time.monotonic()
        sweep()
        await asyncio.sleep(0.002)            # sustained, not bursty
    write_wall = time.monotonic() - t_start
    # drain: every marker must land everywhere despite the lossy mesh
    deadline = time.monotonic() + 30.0
    while pending and time.monotonic() < deadline:
        sweep()
        await asyncio.sleep(0.02)
    assert not pending, (f"{len(pending)} markers never converged under "
                         f"{loss:.0%} UDP loss")
    await wait_converged(nodes, timeout=30.0)
    await asyncio.sleep(0.2)                  # let trailing acks land
    stats = nodes[0].stats.summary()
    losses = sum(getattr(n.transport, "injected_losses", 0) for n in nodes)
    stats["injected_losses"] = losses
    ids = [n.id for n in nodes]
    queue_drops = sum(n.stats.queue_drops for n in nodes)
    await stop_cluster(nodes)
    thr = n_writes / write_wall

    obs = {}
    if traced:
        # the marker lags ARE per-key replication lag: publish them on
        # the process-wide registry (run.py --json snapshots it per
        # suite), alongside the suite's shed-frame total
        reg = global_registry()
        child = marker_lag_histogram(reg, node="bench_net")
        for v in lat:
            child.observe(v)
        reg.counter("repro_net_queue_drops_total",
                    "frames shed by bounded send queues",
                    ("node",)).labels("bench_net").set_total(queue_drops)
        # the analyzer closes the loop: a converged cluster's trace must
        # be anomaly-free, and the redundancy ratio quantifies what the
        # shipping policy paid over the minimum
        rep = report(list(tracers.values()), expect_converged=ids)
        assert rep["anomalies"].get("ship_without_join", 0) == 0, \
            rep["anomaly_list"]
        assert rep["anomaly_list"] == [], rep["anomaly_list"]
        assert rep["unconverged_keys"] == {}, rep["unconverged_keys"]
        reg.gauge("repro_bench_redundancy_ratio",
                  "shipped bytes / state-changing joined bytes",
                  ("suite",)).labels("net").set(rep["redundancy"]["ratio"])
        obs = {"redundancy_ratio": rep["redundancy"]["ratio"],
               "mean_rounds": rep["mean_rounds"],
               "mean_lag_s": rep["mean_lag_s"]}
    return thr, _percentile(lat, 0.50), _percentile(lat, 0.99), \
        write_wall, stats, obs


# ---------------------------------------------------------------------------
# TCP kill/restart: reconnect catches up via digest-sync
# ---------------------------------------------------------------------------

async def _tcp_kill_restart(pre_keys: int = 160, post_keys: int = 8
                            ) -> Tuple[float, int, int, float]:
    from repro.core import MVRegister
    from repro.net import (GossipNode, default_replica_factory,
                           start_cluster, stop_cluster, wait_converged)
    from repro.wire import encode_frame, encode_value

    # pure pull: the restarted member trades one digest per round and
    # receives exactly the rows it lacks — the cleanest reconnect story
    # (the hybrid's push path would re-ship a barely-filtered causal
    # interval before the first pull round even fires)
    policy = "digest-sync"
    nodes = await start_cluster(3, transport="tcp", tick=0.05,
                                policy=policy, seed=23)
    for s in range(pre_keys):
        n = nodes[s % 3]
        for status in ("queued", "done"):
            n.update(f"sess{s}", MVRegister, "write_delta", n.id, status)
    await wait_converged(nodes, timeout=30.0)

    victim = nodes[2]
    durable = victim.replica.durable_snapshot()   # what a crash keeps
    addr = victim.addr
    await victim.stop(abort=True)                 # kill: sockets torn down

    survivors = nodes[:2]
    for s in range(pre_keys, pre_keys + post_keys):
        n = survivors[s % 2]
        for status in ("queued", "done"):
            n.update(f"sess{s}", MVRegister, "write_delta", n.id, status)
    await wait_converged(survivors, timeout=30.0)

    # restart on the same port with the durable snapshot — peers'
    # dialers reconnect, digest-sync pulls the gap
    reborn = GossipNode(victim.id, addr, transport="tcp", policy=policy,
                        peers={p.id: p.addr for p in survivors}, tick=0.05)
    replica = default_replica_factory(policy)(victim.id,
                                              sorted(p.id for p in
                                                     survivors))
    replica.recover(durable)
    reborn.adopt_replica(replica)
    t0 = time.monotonic()
    await reborn.start()
    allnodes = [*survivors, reborn]
    await wait_converged(allnodes, timeout=30.0)
    catchup_s = time.monotonic() - t0

    catchup_bytes = reborn.stats.recv_state_bytes()
    full_bytes = len(encode_frame("state",
                                  encode_value(survivors[0].X)))
    await stop_cluster(allnodes)
    return catchup_s, catchup_bytes, full_bytes, \
        catchup_bytes / max(full_bytes, 1)


# ---------------------------------------------------------------------------
# 3 OS processes via serve.py --listen/--peers (the CI net-smoke row)
# ---------------------------------------------------------------------------

def _free_ports(n: int) -> List[int]:
    socks = [socket.socket() for _ in range(n)]
    for s in socks:
        s.bind(("127.0.0.1", 0))
    ports = [s.getsockname()[1] for s in socks]
    for s in socks:
        s.close()
    return ports


def _process_cluster(sessions: int = 24, loss: float = 0.10,
                     timeout: float = 150.0,
                     zones: bool = False) -> Tuple[float, dict]:
    ports = _free_ports(3)
    members = [f"gw{i}@127.0.0.1:{ports[i]}" + (f"@z{i}" if zones else "")
               for i in range(3)]
    env = {**os.environ,
           "PYTHONPATH": REPO_SRC + (os.pathsep + os.environ["PYTHONPATH"]
                                     if os.environ.get("PYTHONPATH")
                                     else "")}
    procs = []
    with tempfile.TemporaryDirectory(prefix="bench_net_") as tmp:
        status = [os.path.join(tmp, f"status{i}.json") for i in range(3)]
        for i in range(3):
            peers = ",".join(m for j, m in enumerate(members) if j != i)
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.serve",
                 "--listen", members[i], "--peers", peers,
                 "--sessions", str(sessions),
                 "--ship-policy", "bp+rr+digest-sync:4",
                 "--transport", "udp", "--udp-loss", str(loss),
                 "--tick", "0.1", "--run-for", str(timeout),
                 "--status-file", status[i], "--seed", str(i)],
                env=env, stdout=subprocess.PIPE,
                stderr=subprocess.PIPE, text=True))
        t0 = time.monotonic()
        agreed = None
        try:
            while time.monotonic() - t0 < timeout:
                time.sleep(0.5)
                for p in procs:
                    if p.poll() not in (None, 0):
                        _out, err = p.communicate()
                        raise AssertionError(
                            f"cluster member died: {err[-800:]}")
                try:
                    st = [json.load(open(f)) for f in status]
                except (FileNotFoundError, json.JSONDecodeError):
                    continue
                fps = {s["fingerprint"] for s in st}
                if (len(fps) == 1
                        and all(s["all_done"] and s["keys"] == sessions
                                for s in st)):
                    agreed = st
                    break
        finally:
            for p in procs:
                p.terminate()
            for p in procs:
                try:
                    p.communicate(timeout=30)
                except subprocess.TimeoutExpired:  # pragma: no cover
                    p.kill()
        assert agreed is not None, (
            f"3-process cluster did not agree within {timeout}s")
        wall = time.monotonic() - t0
        if zones:
            # heartbeats must carry the zone + per-link-class counters
            assert [s["zone"] for s in agreed] == ["z0", "z1", "z2"]
            for s in agreed:
                assert s["bytes_by_class"], s
            return wall, {"bytes_by_class": agreed[0]["bytes_by_class"],
                          "zones": [s["zone"] for s in agreed]}
        bytes_by_kind = agreed[0]["bytes_by_kind"]
        return wall, bytes_by_kind


# ---------------------------------------------------------------------------


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []

    thr, p50, p99, wall, stats, obs = asyncio.run(_udp_loadgen())
    assert p99 < 10.0, f"p99 convergence latency {p99:.2f}s under loss"
    rows.append(("net_udp_loadgen", wall * 1e6 / 240,
                 f"thr={thr:.0f}w/s p50={p50*1e3:.0f}ms "
                 f"p99={p99*1e3:.0f}ms loss=0.10 "
                 f"lost_datagrams={stats['injected_losses']} "
                 f"queue_drops={stats['queue_drops']} "
                 f"redundancy={obs['redundancy_ratio']:.2f} "
                 f"rounds={obs['mean_rounds']:.1f} all markers "
                 f"converged, trace anomaly-free"))

    catchup_s, catchup_b, full_b, ratio = asyncio.run(_tcp_kill_restart())
    assert ratio <= 0.25, (
        f"restart catch-up cost {ratio:.1%} of full state — digest-sync "
        f"should make a reconnect cheap ({catchup_b}B vs {full_b}B)")
    rows.append(("net_tcp_kill_restart", catchup_s * 1e6,
                 f"catchup_bytes={catchup_b} full_state_frame={full_b} "
                 f"ratio={ratio:.1%} (assert <=25%) reconnected+converged "
                 f"in {catchup_s:.2f}s"))

    wall, by_kind = _process_cluster()
    payload = {k: v for k, v in sorted(by_kind.items())}
    rows.append(("net_3proc_serve_cluster", wall * 1e6,
                 f"3 serve.py procs (udp loss=0.10) fingerprint-agreed "
                 f"in {wall:.1f}s bytes_by_kind={payload}"))

    wall, zoned = _process_cluster(sessions=12, zones=True)
    by_class = dict(sorted(zoned["bytes_by_class"].items()))
    rows.append(("net_3proc_zoned_cluster", wall * 1e6,
                 f"3 serve.py procs in 3 zones (udp loss=0.10, "
                 f"hierarchical gossip) fingerprint-agreed in {wall:.1f}s "
                 f"zones={zoned['zones']} bytes_by_class={by_class}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
