"""Observability benchmark: tracing overhead, live scrape, trace rollup.

Three rows, each asserting the claim it measures:

* **tracing overhead** — the ``bench_net`` UDP load generator runs
  untraced and then fully traced (a per-node :class:`repro.obs.Tracer`
  on every engine path + the analyzer pass); asserted: traced
  throughput stays within 10% of untraced. The trace bus must be cheap
  enough to leave on.

* **scrape cluster** — three real ``serve.py --listen --peers
  --metrics`` OS processes on loopback UDP; each serves its registry on
  an HTTP sidecar advertised through the ``--status-file`` heartbeat.
  The bench scrapes every member from the *outside* and asserts the
  replication-lag histogram and the byte-rate gauges are present and
  finite — the CI ``obs-smoke`` contract.

* **trace analysis** — the traced load generator's merged trace rolled
  up by :mod:`repro.obs.analyze`: reports the redundancy ratio (shipped
  bytes vs bytes that changed receiver state) and convergence rounds
  per write; asserts a converged cluster's trace carries zero
  ``ship_without_join`` anomalies.
"""

from __future__ import annotations

import asyncio
import json
import math
import os
import subprocess
import sys
import time
from typing import List, Tuple

from .bench_net import REPO_SRC, _free_ports, _udp_loadgen


# ---------------------------------------------------------------------------
# tracing overhead: traced loadgen within 10% of untraced
# ---------------------------------------------------------------------------

def _trace_overhead() -> Tuple[float, dict]:
    t0 = time.perf_counter()
    thr_plain, *_rest = asyncio.run(_udp_loadgen(traced=False))
    thr_traced, _p50, _p99, _wall, _stats, obs = asyncio.run(
        _udp_loadgen(traced=True))
    wall = time.perf_counter() - t0
    ratio = thr_traced / thr_plain
    assert ratio >= 0.90, (
        f"tracing cost more than 10% of throughput: {thr_traced:.0f} vs "
        f"{thr_plain:.0f} w/s ({ratio:.1%})")
    return wall, {"thr_plain": thr_plain, "thr_traced": thr_traced,
                  "ratio": ratio, "obs": obs}


# ---------------------------------------------------------------------------
# 3-process serve.py --metrics cluster, scraped from the outside
# ---------------------------------------------------------------------------

def _scrape_cluster(sessions: int = 12, timeout: float = 150.0
                    ) -> Tuple[float, dict]:
    from repro.obs import parse_prometheus, scrape

    ports = _free_ports(3)
    members = [f"gw{i}@127.0.0.1:{ports[i]}" for i in range(3)]
    env = {**os.environ,
           "PYTHONPATH": REPO_SRC + (os.pathsep + os.environ["PYTHONPATH"]
                                     if os.environ.get("PYTHONPATH")
                                     else "")}
    import tempfile
    procs = []
    with tempfile.TemporaryDirectory(prefix="bench_obs_") as tmp:
        status = [os.path.join(tmp, f"status{i}.json") for i in range(3)]
        for i in range(3):
            peers = ",".join(m for j, m in enumerate(members) if j != i)
            procs.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.serve",
                 "--listen", members[i], "--peers", peers,
                 "--sessions", str(sessions),
                 "--ship-policy", "bp+rr+digest-sync:4",
                 "--transport", "udp", "--tick", "0.1",
                 "--run-for", str(timeout),
                 "--status-file", status[i], "--metrics",
                 "--seed", str(i)],
                env=env, stdout=subprocess.PIPE,
                stderr=subprocess.PIPE, text=True))
        t0 = time.monotonic()
        agreed = None
        scraped = {}
        try:
            while time.monotonic() - t0 < timeout:
                time.sleep(0.5)
                for p in procs:
                    if p.poll() not in (None, 0):
                        _out, err = p.communicate()
                        raise AssertionError(
                            f"cluster member died: {err[-800:]}")
                try:
                    st = [json.load(open(f)) for f in status]
                except (FileNotFoundError, json.JSONDecodeError):
                    continue
                fps = {s["fingerprint"] for s in st}
                if (len(fps) == 1
                        and all(s["all_done"] and s["keys"] == sessions
                                for s in st)):
                    agreed = st
                    break
            assert agreed is not None, (
                f"3-process cluster did not agree within {timeout}s")
            # scrape each member's advertised sidecar while it still runs
            for s in agreed:
                addr = s["metrics_addr"]
                assert addr, f"{s['id']}: no metrics sidecar advertised"
                parsed = parse_prometheus(scrape(addr))
                nid = s["id"]
                for fam in ("repro_ack_lag_seconds_count",
                            "repro_net_bytes_sent_per_second",
                            "repro_replica_delta_buffer_depth",
                            "repro_net_frames_sent_total"):
                    assert fam in parsed, (nid, fam, sorted(parsed)[:30])
                    vals = list(parsed[fam].values())
                    assert all(math.isfinite(v) for v in vals), (nid, fam)
                rate = list(
                    parsed["repro_net_bytes_sent_per_second"].values())
                lag_n = sum(
                    parsed["repro_ack_lag_seconds_count"].values())
                scraped[nid] = {"byte_rate": rate[0], "acked_writes": lag_n}
                # the heartbeat itself carries the same snapshot
                assert "repro_replica_delta_buffer_depth" in s["metrics"]
        finally:
            for p in procs:
                p.terminate()
            for p in procs:
                try:
                    p.communicate(timeout=30)
                except subprocess.TimeoutExpired:  # pragma: no cover
                    p.kill()
        wall = time.monotonic() - t0
        lags = sum(v["acked_writes"] for v in scraped.values())
        return wall, {"scraped": sorted(scraped), "acked_writes": lags}


# ---------------------------------------------------------------------------


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []

    wall, d = _trace_overhead()
    rows.append(("obs_trace_overhead", wall * 1e6 / 480,
                 f"traced={d['thr_traced']:.0f}w/s "
                 f"untraced={d['thr_plain']:.0f}w/s "
                 f"ratio={d['ratio']:.2f} (assert >=0.90)"))
    obs = d["obs"]
    rows.append(("obs_analyze_loadgen", float("nan"),
                 f"redundancy={obs['redundancy_ratio']:.2f} "
                 f"mean_rounds={obs['mean_rounds']:.1f} "
                 f"mean_lag={obs['mean_lag_s']*1e3:.0f}ms "
                 f"(real socket run, zero ship-without-join anomalies)"))

    wall, d = _scrape_cluster()
    rows.append(("obs_scrape_cluster", wall * 1e6,
                 f"3 serve.py --metrics procs scraped via sidecar HTTP: "
                 f"lag+byte-rate gauges present&finite on "
                 f"{d['scraped']}, {d['acked_writes']} acked writes "
                 f"observed"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
