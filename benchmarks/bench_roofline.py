"""Roofline table from the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
one row per (arch × shape × mesh): the three terms in seconds, the
dominant bound, and MODEL_FLOPS/HLO_FLOPs. This bench does not compile
anything — run the dry-run first."""

from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        return [("roofline_table", float("nan"),
                 "no dry-run artifacts; run repro.launch.dryrun first")]
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        if d.get("status") != "ok":
            continue
        r = d["roofline"]
        name = f"roofline_{d['arch']}_{d['shape']}_{d['mesh']}"
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append((name, bound_s * 1e6,
                     f"bound={r['bound']} c={r['compute_s']:.2e} "
                     f"m={r['memory_s']:.2e} n={r['collective_s']:.2e} "
                     f"useful={r['useful_frac']:.2f} "
                     f"roofline={r['roofline_frac']:.2f}"))
    return rows
