"""Keyed LatticeStore benchmarks: batched join throughput + sharded bytes.

Two claims measured (and asserted — regressions fail the suite):

1. **objects/sec joined**: joining a store of N independent ``TensorState``
   objects against a same-shaped delta store, via the batched
   ``kernels.delta_join`` path (chunks from all N objects stacked into one
   launch) vs the per-key Python loop (one ``TensorState.join`` — one jit
   dispatch — per key). At N ≥ 1024 the batched path must be ≥ 5× faster:
   the loop pays per-object dispatch overhead, the batch pays it once.

2. **bytes shipped per anti-entropy round scale with *touched* keys, not
   store size** (under ``bp+rr``): a 3-replica causal mesh converges on a
   pre-populated store, then a workload touches T of the S keys; the
   phase-2 payload is ~flat in S for fixed T and grows with T. The
   replicas gossip binary δ-wire frames, so the byte column is measured
   encoded-frame lengths.
"""

from __future__ import annotations

import random
import time
from typing import List, Tuple

import numpy as np


def _mk_tensor_store(n_obj: int, n_tensors: int, n_chunks: int, chunk: int,
                     seed: int, version: int):
    """A store of N ``TensorState`` objects (each holding ``n_tensors``
    chunked tensors) with host-resident (numpy) chunk data — what wire
    ingestion and previous batched joins produce on the CPU path."""
    from repro.core import LatticeStore
    from repro.core.tensor_lattice import ChunkedTensor, TensorState
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n_obj):
        ts = {f"t{t}": ChunkedTensor(
                  rng.normal(size=(n_chunks, chunk)).astype(np.float32),
                  np.full((n_chunks,), version, dtype=np.int32))
              for t in range(n_tensors)}
        out[f"obj{i:05d}"] = TensorState.of(ts)
    return LatticeStore.of(out)


def _block(store) -> None:
    for _, ts in store.entries:
        for _, ct in ts.chunks:
            for arr in (ct.values, ct.versions):
                ready = getattr(arr, "block_until_ready", None)
                if ready is not None:
                    ready()


def _time_join(a, b, batched: bool, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = a.join(b, batched=batched)
        _block(out)
        best = min(best, time.perf_counter() - t0)
    return best


def batched_join_rows(n_obj: int = 1024, n_tensors: int = 4,
                      n_chunks: int = 2,
                      chunk: int = 128) -> List[Tuple[str, float, str]]:
    a = _mk_tensor_store(n_obj, n_tensors, n_chunks, chunk, seed=0,
                         version=1)
    b = _mk_tensor_store(n_obj, n_tensors, n_chunks, chunk, seed=1,
                         version=2)
    _block(a.join(b, batched=False))   # warm the per-key dispatch cache
    _block(a.join(b, batched=True))    # warm launch + columnar caches

    t_loop = _time_join(a, b, batched=False)
    t_batched = _time_join(a, b, batched=True)
    speedup = t_loop / t_batched
    assert speedup >= 5.0, (
        f"batched store join only {speedup:.1f}x faster than the per-key "
        f"loop at {n_obj} objects (claim: ≥5x)")
    return [
        (f"store_join_loop_{n_obj}", t_loop * 1e6,
         f"objs_per_s={n_obj / t_loop:.0f}"),
        (f"store_join_batched_{n_obj}", t_batched * 1e6,
         f"objs_per_s={n_obj / t_batched:.0f};speedup={speedup:.1f}x"),
    ]


def _fresh_tensors(store):
    """A value-identical store whose ChunkedTensor objects are fresh —
    drops every attached cache/memo, modelling the pre-resident round
    that rebuilt its columns and digests from scratch."""
    from repro.core import LatticeStore
    from repro.core.tensor_lattice import ChunkedTensor, TensorState
    entries = []
    for key, val in store.entries:
        chunks = tuple((n, ChunkedTensor(ct.values, ct.versions))
                       for n, ct in val.chunks)
        entries.append((key, TensorState(chunks, val.lamport)))
    return LatticeStore(tuple(entries), store.life)


def _sparse_delta(store, touched: int, n_chunks: int, chunk: int,
                  seed: int, version: int):
    from repro.core import LatticeStore
    from repro.core.tensor_lattice import TensorState, sparse_chunks
    rng = np.random.default_rng(seed)
    keys = [k for k, _ in store.entries][:touched]
    out = {}
    for key in keys:
        idx = np.array([rng.integers(0, n_chunks)], np.int32)
        out[key] = TensorState.of({"t0": sparse_chunks(
            n_chunks, idx, rng.normal(size=(1, chunk)).astype(np.float32),
            np.full((1,), version, np.int32))})
    return LatticeStore.of(out)


def _resident_round(store, delta, budget):
    """One device-resident anti-entropy round: scatter-ingest the delta,
    summarize, budget-select — the fused O(1)-launch pipeline."""
    from repro.core import digest_select_store
    from repro.core.digest import store_digest
    out = store.join(delta)
    store_digest(out)
    digest_select_store(out, budget)
    return out


def _legacy_round(store, delta, budget):
    """The same round through the host-staged path on a cache-free store:
    per-key gather/merge/scatter joins, per-tensor version densification,
    per-tensor digest launches for the budget ranking."""
    from repro.core import digest_select_store
    from repro.core.digest import store_digest
    out = _fresh_tensors(store).join(delta)
    store_digest(out)
    digest_select_store(out, budget)
    return out


def resident_round_rows(n_obj: int = 10_000, n_chunks: int = 2,
                        chunk: int = 128,
                        touched: int = 64) -> List[Tuple[str, float, str]]:
    """Device-resident round vs the host-staged round at ≥10k keys.

    Asserts the tentpole's acceptance criteria: the resident round is
    ≥2x faster (CPU proxy: the XLA-oracle dispatch of the same fused
    kernels), runs O(1) kernel launches per round (size-independent:
    identical count at 2x the store), and stages only ~the delta's bytes
    host→device in steady state (also size-independent)."""
    from repro.kernels import ops, resident

    per_chunk = chunk * 4 + 12
    # a tight budget (256 kept chunks) so the round's ranking cost — the
    # thing the resident columns eliminate — is what's measured, not the
    # O(selected) python materialization both paths share
    budget = 256 * per_chunk

    def setup(n):
        a = _mk_tensor_store(n, 1, n_chunks, chunk, seed=0, version=1)
        d = _sparse_delta(a, touched, n_chunks, chunk, seed=2, version=5)
        return a, d

    def measure(n):
        a, d = setup(n)
        r = _fresh_tensors(a)
        assert resident.ensure(r) is not None
        # warm both paths (jit traces, stacked caches)
        _legacy_round(a, d, budget)
        _resident_round(r, d, budget)
        t_legacy = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            _legacy_round(a, d, budget)
            t_legacy = min(t_legacy, time.perf_counter() - t0)
        t_res = float("inf")
        cost = None
        for _ in range(3):
            snap = ops.counters.snapshot()
            t0 = time.perf_counter()
            out = _resident_round(r, d, budget)
            t_res = min(t_res, time.perf_counter() - t0)
            cost = ops.counters.since(snap)
            assert resident.resident_of(out) is not None
        return t_legacy, t_res, cost

    t_legacy, t_res, cost = measure(n_obj)
    _, _, cost2x = measure(n_obj * 2)

    # O(1) launches per round, independent of store size: one scatter
    # ingest + one ranking epilogue (+ nothing per key)
    assert cost["launches"] <= 3, cost
    assert cost2x["launches"] == cost["launches"], (cost, cost2x)
    # steady-state staging ≈ the delta itself (idx + padded rows), flat
    # across store sizes — the columns never leave the device
    delta_bytes = touched * (chunk * 4 + 4)
    pad_bucket = 2 * touched * (chunk * 4 + 4) + 2 * touched * 4
    assert cost["h2d_bytes"] <= delta_bytes + pad_bucket, cost
    assert cost2x["h2d_bytes"] == cost["h2d_bytes"], (cost, cost2x)
    speedup = t_legacy / t_res
    assert speedup >= 2.0, (
        f"resident round only {speedup:.1f}x faster than the host-staged "
        f"round at {n_obj} keys (claim: ≥2x)")
    return [
        (f"store_round_host_{n_obj}", t_legacy * 1e6,
         f"rounds_per_s={1 / t_legacy:.1f}"),
        (f"store_round_resident_{n_obj}", t_res * 1e6,
         f"rounds_per_s={1 / t_res:.1f};speedup={speedup:.1f}x;"
         f"launches_per_round={cost['launches']};"
         f"h2d_bytes_per_round={cost['h2d_bytes']}"),
    ]


def _phase2_bytes(store_size: int, touched: int, seed: int = 5) -> int:
    """Measured frame bytes shipped while propagating ops on ``touched``
    of the ``store_size`` keys, after the store has already converged."""
    from repro.core import (GCounter, NetConfig, Simulator, StoreReplica,
                            converged, make_policy, run_to_convergence)
    from repro.wire import WireCodec
    wire = WireCodec()
    sim = Simulator(NetConfig(loss=0.05, dup=0.05, seed=seed))
    ids = [f"n{k}" for k in range(3)]
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=make_policy("bp+rr"), rng=random.Random(seed + 1),
        wire=wire)) for i in ids]
    rng = random.Random(seed + 2)
    for s in range(store_size):
        n = nodes[s % len(nodes)]
        n.update(f"k{s:04d}", GCounter, "inc_delta", n.id)
        if s % 16 == 15:
            sim.run_for(0.3)
    run_to_convergence(sim, nodes, interval=1.0, max_time=120_000)
    base = sim.stats.payload_atoms()
    for t in range(touched):
        n = rng.choice(nodes)
        n.update(f"k{t % store_size:04d}", GCounter, "inc_delta", n.id)
        sim.run_for(0.3)
    run_to_convergence(sim, nodes, interval=1.0, max_time=120_000)
    assert converged(nodes)
    return sim.stats.payload_atoms() - base


def sharded_bytes_rows() -> List[Tuple[str, float, str]]:
    rows = []
    # fixed touched-key count, growing store: bytes must stay ~flat
    fixed_t = {}
    for size in (64, 512):
        t0 = time.perf_counter()
        atoms = _phase2_bytes(size, touched=8)
        fixed_t[size] = atoms
        rows.append((f"store_bytes_S{size}_T8",
                     (time.perf_counter() - t0) * 1e6,
                     f"frame_bytes={atoms}"))
    assert fixed_t[512] <= 2.5 * fixed_t[64], (
        f"bytes grew with store size at fixed touched keys: {fixed_t}")
    # fixed store, growing touched-key count: bytes must grow
    by_t = {}
    for touched in (4, 64):
        t0 = time.perf_counter()
        atoms = _phase2_bytes(256, touched=touched)
        by_t[touched] = atoms
        rows.append((f"store_bytes_S256_T{touched}",
                     (time.perf_counter() - t0) * 1e6,
                     f"frame_bytes={atoms}"))
    assert by_t[4] < by_t[64], (
        f"bytes did not grow with touched keys: {by_t}")
    return rows


def run() -> List[Tuple[str, float, str]]:
    return (batched_join_rows() + resident_round_rows()
            + sharded_bytes_rows())


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
