"""Tensor-lattice delta sync: wire bytes per round for full-state shipping
vs packed chunk deltas vs top-k+error-feedback — the framework-scale
version of §9 — plus delta_join/chunk_digest throughput (jnp/XLA path; the
Pallas kernel is the TPU build of the same op, validated in interpret
mode in tests)."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tensor_lattice import (TensorState, chunk_tensor,
                                       pack_delta, packed_size_bytes)
from repro.kernels import ops
from repro.sync.compression import (TopKCompressor, dense_nbytes,
                                    sparse_nbytes)

CHUNK = 4096


def _model_state(n_params: int, seed=0):
    rng = np.random.default_rng(seed)
    state = TensorState.bottom()
    w = rng.normal(size=(n_params,)).astype(np.float32)
    ct = chunk_tensor(w, CHUNK)
    state = TensorState.of({"w": ct})
    return state, w


def delta_ship_table() -> List[Tuple[str, float, str]]:
    rows = []
    n_params = 10_000_000
    state, w = _model_state(n_params)
    dense_bytes = n_params * 4

    # (a) full-state shipping (classical state-based CRDT)
    rows.append(("tensor_full_state_10M", dense_bytes, "bytes/round"))

    # (b) chunk deltas — MoE-like round touching 2% of chunks
    n_chunks = state.as_dict()["w"].values.shape[0]
    touched = np.arange(0, n_chunks, 50)
    vals = np.random.default_rng(1).normal(
        size=(len(touched), CHUNK)).astype(np.float32)
    delta = state.write_delta(0, "w", vals, chunk_idx=touched)
    wire = pack_delta(delta)
    rows.append(("tensor_chunk_delta_2pct", packed_size_bytes(wire),
                 f"ratio={dense_bytes / packed_size_bytes(wire):.1f}x"))

    # (c) dense round + top-k(1%) + error feedback
    comp = TopKCompressor(rate=0.01)
    upd = {"w": jnp.asarray(np.random.default_rng(2).normal(
        size=(n_params,)).astype(np.float32))}
    sp = comp.compress(upd)
    rows.append(("tensor_topk1pct_delta", sparse_nbytes(sp),
                 f"ratio={dense_bytes / sparse_nbytes(sp):.1f}x"))
    return rows


def join_throughput_table() -> List[Tuple[str, float, str]]:
    rows = []
    for n_chunks, chunk in ((4096, 4096), (16384, 1024)):
        rng = np.random.default_rng(3)
        av = jnp.asarray(rng.normal(size=(n_chunks, chunk)).astype(np.float32))
        bv = jnp.asarray(rng.normal(size=(n_chunks, chunk)).astype(np.float32))
        avers = jnp.asarray(rng.integers(0, 50, n_chunks).astype(np.int32))
        bvers = jnp.asarray(rng.integers(0, 50, n_chunks).astype(np.int32))

        f = jax.jit(ops.delta_join_ref)
        out = f(av, avers, bv, bvers)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        iters = 20
        for _ in range(iters):
            out = f(av, avers, bv, bvers)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        gb = 3 * n_chunks * chunk * 4 / 1e9  # 2 reads + 1 write
        rows.append((f"delta_join_{n_chunks}x{chunk}", us,
                     f"{gb / (us / 1e6):.1f} GB/s effective (CPU proxy)"))

        g = jax.jit(ops.chunk_digest_ref)
        jax.block_until_ready(g(av))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = g(av)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append((f"chunk_digest_{n_chunks}x{chunk}", us,
                     f"{n_chunks * chunk * 4 / 1e9 / (us / 1e6):.1f} GB/s"))
    return rows


def run() -> List[Tuple[str, float, str]]:
    return delta_ship_table() + join_throughput_table()
