"""Tensor-lattice delta sync: wire bytes per round for full-state shipping
vs packed chunk deltas vs top-k+error-feedback — the framework-scale
version of §9 — plus delta_join/chunk_digest throughput (jnp/XLA path; the
Pallas kernel is the TPU build of the same op, validated in interpret
mode in tests).

Byte rows are **measured encoded-frame lengths** (`len(frame)` of the
binary δ-wire encoding), not structural estimates; the sparse-ingest row
times joining a decoded delta through the O(shipped-chunks) gather/
scatter path against the legacy dense zero-padded materialization."""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tensor_lattice import (TensorState, chunk_tensor,
                                       pack_delta, unpack_delta)
from repro.kernels import ops
from repro.sync.compression import TopKCompressor, topk_frame
from repro.wire import encode_frame, encode_value

CHUNK = 4096


def _frame_len(value, kind: str = "delta") -> int:
    """Measured wire size of a lattice value as one encoded frame."""
    return len(encode_frame(kind, encode_value(value)))


def _block_state(state: TensorState) -> TensorState:
    """Force any async jax work in a TensorState to finish (fair timing)."""
    for _, ct in state.chunks:
        for arr in ((ct.vals, ct.vers) if ct.is_sparse
                    else (ct.values, ct.versions)):
            ready = getattr(arr, "block_until_ready", None)
            if ready is not None:
                ready()
    return state


def _time_join(a: TensorState, b: TensorState, repeats: int = 3):
    best = float("inf")
    result = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = _block_state(a.join(b))
        best = min(best, time.perf_counter() - t0)
    return best, result


def _model_state(n_params: int, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(n_params,)).astype(np.float32)
    # version 1: a fully-written resident state (version 0 would be ⊥
    # everywhere and encode to an empty frame)
    ct = chunk_tensor(w, CHUNK, version=1)
    state = TensorState.of({"w": ct})
    return state, w


def delta_ship_table() -> List[Tuple[str, float, str]]:
    rows = []
    n_params = 10_000_000
    state, w = _model_state(n_params)

    # (a) full-state shipping (classical state-based CRDT), measured
    dense_bytes = _frame_len(state, kind="state")
    rows.append(("tensor_full_state_10M", dense_bytes,
                 "frame bytes/round (measured)"))

    # (b) chunk deltas — MoE-like round touching 2% of chunks
    n_chunks = state.as_dict()["w"].values.shape[0]
    touched = np.arange(0, n_chunks, 50)
    vals = np.random.default_rng(1).normal(
        size=(len(touched), CHUNK)).astype(np.float32)
    delta = state.write_delta(0, "w", vals, chunk_idx=touched)
    delta_bytes = _frame_len(delta)
    rows.append(("tensor_chunk_delta_2pct", delta_bytes,
                 f"ratio={dense_bytes / delta_bytes:.1f}x (measured frames)"))

    # (b') ingest cost: sparse decode + gather/scatter join vs the legacy
    # densify round-trip (materialize full-size zero arrays, full-width
    # LWW merge) — both paths start from the same packed wire message
    wire = pack_delta(delta)
    _block_state(state.join(unpack_delta(wire, sparse=False)))  # warm jit
    t0 = time.perf_counter()
    sparse_joined = _block_state(state.join(unpack_delta(wire)))
    t_sparse = time.perf_counter() - t0
    t0 = time.perf_counter()
    dense_joined = _block_state(state.join(unpack_delta(wire, sparse=False)))
    t_dense = time.perf_counter() - t0
    assert sparse_joined == dense_joined, "sparse ingest diverged"
    rows.append(("tensor_sparse_ingest", t_sparse * 1e6,
                 f"densify_path={t_dense * 1e6:.0f}us "
                 f"({t_dense / max(t_sparse, 1e-9):.1f}x slower)"))

    # (b'') delta-group aggregation: joining two sparse deltas is an
    # O(rows) index union; the dense representation pays a full-width
    # merge over every chunk — the buffer-interval hot path in the engine
    d2 = state.join(delta).write_delta(
        0, "w", np.ones((len(touched), CHUNK), np.float32),
        chunk_idx=touched + 1)
    sp1, sp2 = unpack_delta(pack_delta(delta)), unpack_delta(pack_delta(d2))
    dn1 = unpack_delta(pack_delta(delta), sparse=False)
    dn2 = unpack_delta(pack_delta(d2), sparse=False)
    _block_state(dn1.join(dn2))                                 # warm jit
    t_sp, sp_group = _time_join(sp1, sp2)
    t_dn, dn_group = _time_join(dn1, dn2)
    assert sp_group == dn_group, "sparse delta-group join diverged"
    rows.append(("tensor_delta_group_sparse_join", t_sp * 1e6,
                 f"dense_path={t_dn * 1e6:.0f}us "
                 f"({t_dn / max(t_sp, 1e-9):.1f}x slower)"))

    # (c) dense round + top-k(1%) + error feedback, framed
    comp = TopKCompressor(rate=0.01)
    upd = {"w": jnp.asarray(np.random.default_rng(2).normal(
        size=(n_params,)).astype(np.float32))}
    sp = comp.compress(upd)
    topk_bytes = len(topk_frame(sp))
    rows.append(("tensor_topk1pct_delta", topk_bytes,
                 f"ratio={dense_bytes / topk_bytes:.1f}x (measured frame)"))
    return rows


def join_throughput_table() -> List[Tuple[str, float, str]]:
    rows = []
    for n_chunks, chunk in ((4096, 4096), (16384, 1024)):
        rng = np.random.default_rng(3)
        av = jnp.asarray(rng.normal(size=(n_chunks, chunk)).astype(np.float32))
        bv = jnp.asarray(rng.normal(size=(n_chunks, chunk)).astype(np.float32))
        avers = jnp.asarray(rng.integers(0, 50, n_chunks).astype(np.int32))
        bvers = jnp.asarray(rng.integers(0, 50, n_chunks).astype(np.int32))

        f = jax.jit(ops.delta_join_ref)
        out = f(av, avers, bv, bvers)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        iters = 20
        for _ in range(iters):
            out = f(av, avers, bv, bvers)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        gb = 3 * n_chunks * chunk * 4 / 1e9  # 2 reads + 1 write
        rows.append((f"delta_join_{n_chunks}x{chunk}", us,
                     f"{gb / (us / 1e6):.1f} GB/s effective (CPU proxy)"))

        g = jax.jit(ops.chunk_digest_ref)
        jax.block_until_ready(g(av))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = g(av)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append((f"chunk_digest_{n_chunks}x{chunk}", us,
                     f"{n_chunks * chunk * 4 / 1e9 / (us / 1e6):.1f} GB/s"))
    return rows


def run() -> List[Tuple[str, float, str]]:
    return delta_ship_table() + join_throughput_table()
