"""Topology benchmark: hierarchical gossip vs the flat mesh, 3 zones.

Three scenarios, each asserting the claim it measures:

* **sim WAN bytes** — a 3-zone × 9-worker simulated cluster runs the
  identical seeded write schedule under the flat full-mesh policy
  (``bp+rr``) and under ``HierarchicalGossip`` (intra-zone push, elected
  per-zone relays batching cross-zone repair as digest-sync exchanges
  every 4th round). Both must converge to the exact write total;
  asserted: the hierarchy ships **strictly fewer cross-zone (WAN-class)
  bytes** — and strictly lower byte·cost under the default per-class
  tariffs — than the flat mesh at equal workload.

* **sim partition heal** — the hierarchical cluster takes writes on
  both sides of a zone partition (one zone fully cut off for a window);
  asserted: after the window closes the cluster converges and no write
  from either side is lost (Def. 6: relayed digest routing is
  join-equivalent, so repair order doesn't matter).

* **socket WAN bytes** — the same flat-vs-hierarchy comparison over
  real loopback UDP sockets (in-process ``GossipNode`` cluster, 6 nodes
  × 3 zones, zone-annotated peer maps): both converge on the same
  schedule, and per-link-class ``LinkStats`` must again show the
  hierarchy strictly beating the flat mesh on cross-zone bytes.

Byte classes come from ``repro.topology.link_class`` (same zone →
intra, same region → inter, else wan); bare ``z0``-style zones are
their own region, so every cross-zone byte here is WAN-class.
"""

from __future__ import annotations

import asyncio
import random
import time
from typing import List, Tuple

from repro.core import (GCounter, MVRegister, NetConfig, Simulator,
                        StoreReplica, converged, hierarchical_policy,
                        make_policy, run_to_convergence)
from repro.topology import DEFAULT_PROFILES, Topology

N_WORKERS = 9
N_ZONES = 3
N_WRITES = 60
N_KEYS = 8


# ---------------------------------------------------------------------------
# sim: flat vs hierarchical on the identical seeded workload
# ---------------------------------------------------------------------------

def _sim_cluster(hier: bool, seed: int):
    ids = [f"w{k}" for k in range(N_WORKERS)]
    topo = Topology.zoned(ids, N_ZONES, profiles=DEFAULT_PROFILES)
    sim = Simulator(NetConfig(seed=seed), topology=topo)
    make = ((lambda: hierarchical_policy(topo, inter_every=4)) if hier
            else (lambda: make_policy("bp+rr")))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True, policy=make(),
        rng=random.Random(seed + 1))) for i in ids]
    return topo, sim, ids, nodes


def _drive(sim, nodes, schedule):
    for n in nodes:
        sim.every(1.0, n.on_periodic)
        sim.every(7.0, n.gc_deltas)
    sim._ae_scheduled = {n.id for n in nodes}
    for who, key in schedule:
        nodes[who].update(key, GCounter, "inc_delta", nodes[who].id)
        sim.run_for(1.0)
    run_to_convergence(sim, nodes, interval=1.0, max_time=120_000)
    assert converged(nodes)
    total = sum(nodes[0].get(f"k{j}").value() for j in range(N_KEYS))
    assert total == len(schedule), (total, len(schedule))


def _sim_schedule(seed=3):
    rng = random.Random(seed)
    return [(rng.randrange(N_WORKERS), f"k{t % N_KEYS}")
            for t in range(N_WRITES)]


def _sim_wan_bytes() -> Tuple[float, dict]:
    schedule = _sim_schedule()
    stats = {}
    t0 = time.perf_counter()
    for label, hier in (("flat", False), ("hier", True)):
        topo, sim, ids, nodes = _sim_cluster(hier, seed=2)
        _drive(sim, nodes, schedule)
        stats[label] = sim.stats
    wall = time.perf_counter() - t0
    flat, hier = stats["flat"], stats["hier"]
    assert hier.cross_zone_bytes() < flat.cross_zone_bytes(), (
        f"hierarchy must beat the flat mesh on WAN bytes: "
        f"{hier.cross_zone_bytes()} vs {flat.cross_zone_bytes()}")
    assert hier.link_cost < flat.link_cost, (
        f"per-class tariffs must favour the hierarchy: "
        f"{hier.link_cost:.0f} vs {flat.link_cost:.0f}")
    return wall, {
        "flat_wan": flat.cross_zone_bytes(),
        "hier_wan": hier.cross_zone_bytes(),
        "saving": 1 - hier.cross_zone_bytes() / flat.cross_zone_bytes(),
        "flat_cost": flat.link_cost, "hier_cost": hier.link_cost,
    }


def _sim_partition_heal() -> Tuple[float, int]:
    topo, sim, ids, nodes = _sim_cluster(hier=True, seed=9)
    rng = random.Random(9)
    for n in nodes:
        sim.every(1.0, n.on_periodic)
        sim.every(7.0, n.gc_deltas)
    sim._ae_scheduled = {n.id for n in nodes}
    for t in range(15):
        n = nodes[rng.randrange(len(nodes))]
        n.update(f"k{t % N_KEYS}", GCounter, "inc_delta", n.id)
        sim.run_for(1.0)
    t0_wall = time.perf_counter()
    t0 = sim.time
    sim.add_zone_partition(t0, t0 + 30.0, "z1")
    inside = [n for n in nodes if topo.zone(n.id) == "z1"]
    outside = [n for n in nodes if topo.zone(n.id) != "z1"]
    for t in range(10):
        a = inside[t % len(inside)]
        a.update("cut", GCounter, "inc_delta", a.id)
        b = outside[t % len(outside)]
        b.update("cut", GCounter, "inc_delta", b.id)
        sim.run_for(2.0)
    sim.run_until(t0 + 30.0)
    deadline = sim.time + 10_000
    while sim.time < deadline and not converged(nodes):
        sim.run_for(5.0)
    assert converged(nodes), "zoned cluster did not heal"
    got = nodes[0].get("cut").value()
    assert got == 20, f"writes lost across the partition: {got}/20"
    return time.perf_counter() - t0_wall, got


# ---------------------------------------------------------------------------
# socket: the same comparison over real loopback UDP
# ---------------------------------------------------------------------------

def _socket_wan_bytes(n=6, n_writes=36) -> Tuple[float, dict]:
    from repro.net.node import (start_cluster, start_gossip,
                                stop_cluster, wait_converged)
    from repro.obs import Tracer, report

    ids = [f"gw{k}" for k in range(n)]
    topo = Topology.zoned(ids, N_ZONES)
    rng = random.Random(41)
    schedule = [(rng.randrange(n), f"k{t % N_KEYS}", f"v{t}")
                for t in range(n_writes)]

    async def one(hier: bool) -> dict:
        policy = ((lambda: hierarchical_policy(topo, inter_every=4))
                  if hier else "bp+rr")
        # trace the hierarchical run: relayed digest routing must still
        # produce an anomaly-free trace (every write joined everywhere)
        tracers: dict = {}

        def tracer_factory(node_id):
            tracers[node_id] = Tracer(node=node_id)
            return tracers[node_id]

        nodes = await start_cluster(n, transport="udp", tick=0.03,
                                    policy=policy, topology=topo,
                                    start_gossip=False, seed=43,
                                    tracer_factory=(tracer_factory
                                                    if hier else None))
        try:
            for who, key, val in schedule:
                nodes[who].update(key, MVRegister, "write_delta",
                                  ids[who], val)
            await start_gossip(nodes)
            await wait_converged(nodes, timeout=60.0)
            await asyncio.sleep(0.2)          # let trailing acks land
            out = {
                "wan": sum(n_.stats.cross_zone_bytes() for n_ in nodes),
                "total": sum(n_.stats.bytes_sent for n_ in nodes),
            }
            if tracers:
                rep = report(list(tracers.values()), expect_converged=ids)
                assert rep["anomaly_list"] == [], rep["anomaly_list"]
                assert rep["unconverged_keys"] == {}, \
                    rep["unconverged_keys"]
                out["redundancy"] = rep["redundancy"]["ratio"]
            return out
        finally:
            await stop_cluster(nodes)

    t0 = time.perf_counter()
    flat = asyncio.run(one(False))
    hier = asyncio.run(one(True))
    wall = time.perf_counter() - t0
    assert hier["wan"] < flat["wan"], (
        f"socket mode: hierarchy must beat the flat mesh on cross-zone "
        f"bytes: {hier['wan']} vs {flat['wan']}")
    return wall, {"flat_wan": flat["wan"], "hier_wan": hier["wan"],
                  "saving": 1 - hier["wan"] / flat["wan"],
                  "redundancy": hier.get("redundancy")}


# ---------------------------------------------------------------------------


def run() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []

    wall, d = _sim_wan_bytes()
    rows.append(("topo_sim_wan_bytes", wall * 1e6 / (2 * N_WRITES),
                 f"3zx{N_WORKERS}w hier_wan={d['hier_wan']}B "
                 f"flat_wan={d['flat_wan']}B saving={d['saving']:.0%} "
                 f"cost {d['hier_cost']:.0f} vs {d['flat_cost']:.0f} "
                 f"(assert hier<flat, equal workload, both converged)"))

    wall, got = _sim_partition_heal()
    rows.append(("topo_sim_partition_heal", wall * 1e6,
                 f"z1 cut 30s, writes both sides, healed+converged, "
                 f"counter={got}/20 (no write lost)"))

    wall, d = _socket_wan_bytes()
    rows.append(("topo_socket_wan_bytes", wall * 1e6,
                 f"6-node udp 3-zone hier_wan={d['hier_wan']}B "
                 f"flat_wan={d['flat_wan']}B saving={d['saving']:.0%} "
                 f"hier_redundancy={d['redundancy']:.2f} "
                 f"(assert hier<flat over real sockets, trace "
                 f"anomaly-free)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
