"""Binary δ-wire subsystem benchmarks: frame bytes, rebalance handoff,
and digest-driven reconnect catch-up.

Three claims measured and asserted (regressions fail the suite):

1. **Sparse rounds are small on the wire.** A keyed store of converged
   ``TensorState`` objects takes a sparse workload (a few chunks across a
   few keys); the encoded delta frame for an anti-entropy round must be
   ≤ 25% of the dense full-state encoding — the paper's
   ``size(mᵟ(X)) ≪ size(X)``, realized in *measured bytes* rather than
   structural estimates. A simulated causal mesh under ``bp+rr``
   cross-checks the codec-level numbers end to end.

2. **Rebalance handoff beats organic anti-entropy.** After a membership
   change, moved keys reach their new owner in strictly fewer
   anti-entropy rounds when old owners push handoff frames than when the
   new owner waits for the periodic full-state fallback — with identical
   converged states (handoff is a plain join; organic gossip remains the
   safety net).

3. **Digest-driven catch-up beats the full-state fallback.** A
   reconnecting replica that missed a handful of sparse updates pulls
   them with a digest exchange (digest frame out, SparseChunks-backed
   digest-resp frame back) for ≤ 25% of the bytes of the ONE full-state
   frame the push fallback would have shipped it — and lands in exactly
   the same state.
"""

from __future__ import annotations

import random
import time
from typing import Dict, List, Tuple

import numpy as np


def _tensor_store(n_keys: int, n_chunks: int, chunk: int, seed: int = 0):
    from repro.core import LatticeStore
    from repro.core.tensor_lattice import ChunkedTensor, TensorState
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n_keys):
        out[f"obj{i:04d}"] = TensorState.of({"w": ChunkedTensor(
            rng.normal(size=(n_chunks, chunk)).astype(np.float32),
            np.full((n_chunks,), 1, dtype=np.int32))})
    return LatticeStore.of(out)


def frame_ratio_rows() -> List[Tuple[str, float, str]]:
    from repro.core import LatticeStore
    from repro.core.tensor_lattice import TensorState
    from repro.wire import encode_frame, encode_value

    n_keys, n_chunks, chunk = 64, 8, 256
    store = _tensor_store(n_keys, n_chunks, chunk)

    # a sparse round: 1 chunk rewritten in ~5% of the keys
    rng = np.random.default_rng(1)
    delta = LatticeStore.bottom()
    for i in range(0, n_keys, 20):
        key = f"obj{i:04d}"
        d = store.get(key, TensorState).write_delta(
            0, "w", rng.normal(size=(1, chunk)).astype(np.float32),
            chunk_idx=np.array([i % n_chunks]))
        delta = delta.join(LatticeStore.key_delta(key, d))

    t0 = time.perf_counter()
    delta_frame = encode_frame("delta", encode_value(delta))
    t_enc = time.perf_counter() - t0
    state_frame = encode_frame("state", encode_value(store))
    ratio = len(delta_frame) / len(state_frame)
    assert ratio <= 0.25, (
        f"sparse-round delta frame is {len(delta_frame)}B = "
        f"{ratio:.1%} of the {len(state_frame)}B dense full-state "
        f"encoding (claim: ≤25%)")
    return [
        ("wire_state_frame", len(state_frame),
         f"dense full-state encoding, {n_keys} keys"),
        ("wire_delta_frame", len(delta_frame),
         f"sparse round ({ratio:.1%} of full state; encode "
         f"{t_enc * 1e6:.0f}us)"),
    ]


def sim_round_rows() -> List[Tuple[str, float, str]]:
    """End-to-end cross-check: per-round frame bytes on a 3-replica
    causal mesh under bp+rr after a sparse workload vs the dense
    full-state shipping baseline over the same store."""
    from repro.core import (FullStateNode, NetConfig, Simulator,
                            StoreReplica, converged, make_policy,
                            run_to_convergence)
    from repro.core.tensor_lattice import TensorState
    from repro.wire import WireCodec

    wire = WireCodec()
    n_keys, chunk = 24, 256
    ids = [f"n{k}" for k in range(3)]
    rng = np.random.default_rng(3)

    # causal deltas under bp+rr
    sim = Simulator(NetConfig(loss=0.0, seed=7))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=make_policy("bp+rr"), rng=random.Random(11), wire=wire))
        for i in ids]
    for k in range(n_keys):
        nodes[k % 3].update(f"obj{k:04d}", TensorState, "write_delta",
                            k % 3, "w",
                            rng.normal(size=(4, chunk)).astype(np.float32),
                            None, chunk)
        sim.run_for(0.4)
    run_to_convergence(sim, nodes, interval=1.0)
    assert converged(nodes)
    sim.run_for(10.0)               # let trailing acks land, then GC, so
    for n in nodes:                 # phase 2 measures only fresh traffic
        n.gc_deltas()
    sim.stats.bytes_by_kind.clear()
    # the sparse phase: touch one chunk on two keys, converge again
    for k in (0, n_keys // 2):
        nodes[0].update(f"obj{k:04d}", TensorState, "write_delta", 0, "w",
                        rng.normal(size=(1, chunk)).astype(np.float32),
                        np.array([1]), None)
    run_to_convergence(sim, nodes, interval=1.0)
    delta_bytes = sim.stats.payload_atoms()

    # dense full-state shipping over the converged store
    sim2 = Simulator(NetConfig(loss=0.0, seed=7))
    full_nodes = [sim2.add_node(FullStateNode(
        i, nodes[0].X, [j for j in ids if j != i], wire=wire))
        for i in ids]
    for n in full_nodes:
        n.on_periodic()                      # ONE full-state round
    full_bytes = sim2.stats.payload_atoms()

    assert delta_bytes <= 0.25 * full_bytes, (
        f"sparse-update anti-entropy shipped {delta_bytes}B vs "
        f"{full_bytes}B for one dense full-state round (claim: ≤25%)")
    return [
        ("wire_sim_sparse_phase", delta_bytes,
         f"frame bytes to re-converge 2 touched keys of {n_keys}"),
        ("wire_sim_full_state_round", full_bytes,
         f"frame bytes for ONE dense full-state round "
         f"({delta_bytes / full_bytes:.1%})"),
    ]


def handoff_rows() -> List[Tuple[str, float, str]]:
    from repro.core import (Compose, GCounter, NetConfig, Simulator,
                            StoreReplica, make_policy)
    from repro.sync import KeyOwnership, RebalanceHandoff, ShardByKey
    from repro.wire import WireCodec

    interval = 1.0
    n_keys = 48

    def run(handoff: bool):
        wire = WireCodec()
        live = ["w0", "w1", "w2"]
        ownership = KeyOwnership(lambda: list(live), replication=2)
        sim = Simulator(NetConfig(loss=0.0, seed=9))
        ids = ["w0", "w1", "w2", "w3"]
        nodes = {i: sim.add_node(StoreReplica(
            i, [j for j in ids if j != i], causal=True,
            policy=Compose(make_policy("bp+rr+every:8"),
                           ShardByKey(ownership)),
            rng=random.Random(1), ownership=ownership, wire=wire))
            for i in ids}
        agents = [RebalanceHandoff(nodes[i], ownership) for i in ids]
        keys = [f"k{s:03d}" for s in range(n_keys)]
        for s, key in enumerate(keys):
            n = nodes[live[s % 3]]
            n.update(key, GCounter, "inc_delta", n.id)
            if s % 8 == 7:
                sim.run_for(interval)
        for n in nodes.values():
            sim.every(interval, n.on_periodic)
        sim.run_for(40.0)

        live.append("w3")                      # membership change
        moved = [k for k in keys if "w3" in ownership.owners(k)]
        if handoff:
            for a in agents:
                a.check()
        t0 = sim.time
        # a write trickle keeps counters ticking so the every:8 fallback
        # has something to ride (senders skip fully-acked receivers)
        tick = [0]

        def trickle():
            key = f"fresh{tick[0]}"
            tick[0] += 1
            nodes["w0"].update(key, GCounter, "inc_delta", "w0")
        sim.every(interval, trickle)

        def settled() -> bool:
            return all(nodes["w3"].get(k) is not None
                       and nodes["w3"].get(k, GCounter).value() >= 1
                       for k in moved)

        while sim.time - t0 < 500:
            sim.run_for(interval)
            if settled():
                break
        assert settled(), "moved keys never reached the new owner"
        rounds = (sim.time - t0) / interval
        states = {k: nodes["w3"].get(k, GCounter).value() for k in moved}
        return rounds, states, len(moved)

    t0 = time.perf_counter()
    r_handoff, s_handoff, n_moved = run(True)
    r_organic, s_organic, _ = run(False)
    wall_us = (time.perf_counter() - t0) * 1e6
    assert s_handoff == s_organic, "handoff and organic states diverged"
    assert r_handoff < r_organic, (
        f"handoff took {r_handoff:.0f} rounds vs organic "
        f"{r_organic:.0f} — must be strictly fewer")
    return [
        ("wire_handoff_rounds", r_handoff,
         f"{n_moved} moved keys on the new owner (push)"),
        ("wire_organic_rounds", r_organic,
         f"same keys via periodic full-state fallback "
         f"({wall_us:.0f}us wall total)"),
    ]


def digest_sync_rows() -> List[Tuple[str, float, str]]:
    """Reconnect catch-up: a replica that was away while a few sparse
    chunk writes landed pulls exactly the missing rows via the digest
    request/response exchange; measured frame bytes (request + response)
    must be ≤ 25% of the one full-state frame the engine's push fallback
    would otherwise ship the reconnecting replica."""
    from repro.core import (LatticeStore, NetConfig, Simulator,
                            StoreReplica, make_policy)
    from repro.core.tensor_lattice import TensorState
    from repro.wire import WireCodec, encode_frame, encode_value

    n_keys, n_chunks, chunk = 64, 8, 256
    stale_store = _tensor_store(n_keys, n_chunks, chunk)
    # the fleet moved on: one chunk rewritten in ~6% of the keys
    rng = np.random.default_rng(17)
    fresh_store = stale_store
    for i in range(0, n_keys, 16):
        key = f"obj{i:04d}"
        d = fresh_store.get(key, TensorState).write_delta(
            1, "w", rng.normal(size=(1, chunk)).astype(np.float32),
            chunk_idx=np.array([i % n_chunks]))
        fresh_store = fresh_store.join(LatticeStore.key_delta(key, d))

    wire = WireCodec()
    sim = Simulator(NetConfig(loss=0.0, seed=21))
    stale = sim.add_node(StoreReplica(
        "stale", ["peer"], causal=True, wire=wire,
        policy=make_policy("digest-sync"), rng=random.Random(3)))
    peer = sim.add_node(StoreReplica(
        "peer", ["stale"], causal=True, wire=wire,
        policy=make_policy("digest-sync"), rng=random.Random(3)))
    stale.X = stale_store           # the reconnecting replica
    peer.X = fresh_store

    t0 = time.perf_counter()
    stale.on_periodic()             # digest out → filtered rows back
    sim.run_for(5.0)
    wall_us = (time.perf_counter() - t0) * 1e6
    assert stale.X == peer.X, "digest catch-up did not converge"

    catchup = sim.stats.pull_bytes()
    req = sim.stats.bytes_by_kind.get("digest", 0)
    full = len(encode_frame("state", encode_value(fresh_store)))
    ratio = catchup / full
    assert 0 < catchup <= 0.25 * full, (
        f"digest catch-up cost {catchup}B = {ratio:.1%} of the {full}B "
        f"full-state frame (claim: ≤25%)")
    return [
        ("wire_digest_catchup_bytes", catchup,
         f"digest req {req}B + resp {catchup - req}B = {ratio:.1%} of "
         f"full state ({wall_us:.0f}us wall)"),
        ("wire_digest_full_state_bytes", full,
         f"the ONE full-state frame the push fallback would ship"),
    ]


def compression_rows() -> List[Tuple[str, float, str]]:
    """Per-group zlib column compression (``WireCodec(compress=True)``):
    on low-entropy payloads (quantized session state, repeated values —
    the realistic serving case) the compressed full-state frame must be
    strictly smaller than the uncompressed one, and decode to the
    identical store."""
    from repro.core import LatticeStore
    from repro.core.tensor_lattice import TensorState, chunk_tensor
    from repro.wire import decode_frame, decode_value, encode_frame, \
        encode_value

    n_keys, n_chunks, chunk = 32, 8, 128
    rng = np.random.default_rng(5)
    store = LatticeStore.of({
        f"sess{i:03d}": TensorState.of({"kv": chunk_tensor(
            rng.integers(0, 16, size=(n_chunks * chunk,))
            .astype(np.float32), chunk, version=1)})
        for i in range(n_keys)})

    t0 = time.perf_counter()
    plain = encode_frame("state", encode_value(store))
    t_plain = time.perf_counter() - t0
    t0 = time.perf_counter()
    packed = encode_frame("state", encode_value(store, True))
    t_packed = time.perf_counter() - t0
    assert len(packed) < len(plain), (
        f"compressed full-state frame is {len(packed)}B, not smaller "
        f"than the {len(plain)}B uncompressed frame")
    assert decode_value(decode_frame(packed)[1]) == store
    ratio = len(packed) / len(plain)
    return [
        ("wire_state_frame_plain", len(plain),
         f"uncompressed full-state frame ({t_plain * 1e6:.0f}us encode)"),
        ("wire_state_frame_zlib", len(packed),
         f"{ratio:.1%} of plain via per-group column zlib "
         f"({t_packed * 1e6:.0f}us encode)"),
    ]


def device_decode_rows() -> List[Tuple[str, float, str]]:
    """Decode-to-device ingest (``WireCodec(to_device=True)`` →
    ``kernels.resident``): a sparse delta frame decodes with its stacked
    columns uploaded once, and a device-resident receiver ingests it in
    exactly ONE kernel launch with no further value/version staging —
    the whole round's host→device traffic is ~the frame's column bytes,
    independent of the resident store's size."""
    from repro.core import LatticeStore
    from repro.core.tensor_lattice import TensorState, sparse_chunks
    from repro.kernels import ops, resident
    from repro.wire import decode_frame, encode_frame
    from repro.wire.codec import decode_store, encode_store

    n_keys, n_chunks, chunk, touched = 512, 8, 128, 16
    store = _tensor_store(n_keys, n_chunks, chunk, seed=6)
    rng = np.random.default_rng(7)
    delta = LatticeStore.of({
        f"obj{i:04d}": TensorState.of({"w": sparse_chunks(
            n_chunks, np.array([int(rng.integers(0, n_chunks))], np.int32),
            rng.normal(size=(1, chunk)).astype(np.float32),
            np.full((1,), 9, np.int32))})
        for i in range(touched)})
    frame = encode_frame("delta", encode_store(delta))

    assert resident.ensure(store) is not None
    # warm the dispatch caches (first scatter pays the jit trace)
    warm = decode_store(decode_frame(frame)[1], to_device=True)
    store.join(warm)

    snap = ops.counters.snapshot()
    t0 = time.perf_counter()
    ddev = decode_store(decode_frame(frame)[1], to_device=True)
    out = store.join(ddev)
    dt = time.perf_counter() - t0
    cost = ops.counters.since(snap)
    assert resident.resident_of(out) is not None
    assert cost["launches"] == 1, cost
    # staging = the decoded columns (≤ the frame) + the small padded
    # index column; the resident store's ~2 MB of columns never move
    assert cost["h2d_bytes"] <= len(frame) + 4 * 2 * touched, cost
    return [
        ("wire_device_decode_ingest", dt * 1e6,
         f"frame_bytes={len(frame)};h2d_bytes={cost['h2d_bytes']};"
         f"launches={cost['launches']}"),
    ]


def run() -> List[Tuple[str, float, str]]:
    return (frame_ratio_rows() + sim_round_rows() + handoff_rows()
            + digest_sync_rows() + compression_rows()
            + device_decode_rows())


if __name__ == "__main__":
    for name, val, derived in run():
        print(f"{name},{val:.1f},{derived}")
