"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV:

  bench_message_complexity  §9 tables (counter / OR-Set / MVR, + protocol)
  bench_antientropy         Algorithm 1 vs Algorithm 2 under loss
  bench_tensor_sync         tensor-lattice delta shipping + join throughput
  bench_kernels             kernel microbenchmarks (CPU proxies)
  bench_roofline            per-(arch × shape × mesh) roofline rows from
                            the dry-run artifacts (run dryrun first)
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from . import (bench_antientropy, bench_kernels,
                   bench_message_complexity, bench_roofline,
                   bench_tensor_sync)

    modules = [
        ("message_complexity", bench_message_complexity),
        ("antientropy", bench_antientropy),
        ("tensor_sync", bench_tensor_sync),
        ("kernels", bench_kernels),
        ("roofline", bench_roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.perf_counter()
        try:
            rows = mod.run()
        except Exception as e:  # report, keep going
            failures += 1
            print(f"{name}_FAILED,nan,{type(e).__name__}: {e}")
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{derived}")
        dt = time.perf_counter() - t0
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
