"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV:

  bench_message_complexity  §9 tables (counter / OR-Set / MVR, + protocol
                            bytes per shipping policy)
  bench_antientropy         Algorithm 1 vs Algorithm 2 under loss, plus
                            bytes-shipped per shipping policy under
                            loss/dup/partition
  bench_tensor_sync         tensor-lattice delta shipping + join throughput
  bench_kernels             kernel microbenchmarks (CPU proxies)
  bench_store               keyed LatticeStore: batched vs per-key join
                            throughput + sharded bytes-per-round scaling
  bench_wire                binary δ-wire codec: sparse-round frame bytes
                            vs dense full-state encoding, rebalance
                            handoff vs organic anti-entropy, digest-sync
                            reconnect catch-up vs the full-state fallback,
                            per-group zlib column compression
  bench_lifecycle           key lifecycle: resident bytes return to
                            ~baseline after TTL + acked reap, straggler
                            replays never resurrect, read-replica
                            hot-key convergence outside the write set
  bench_dots                columnar dot-store fast path: 1M-dot causal
                            join vs the frozenset oracle (>=10x,
                            bit-identical), per-dot digest reconnect
                            bytes vs full state (<=5%), add_dots
                            contiguous-append fast path
  bench_net                 real loopback sockets: UDP load generator
                            (throughput + p50/p99 convergence latency
                            under 10% loss), TCP kill/restart digest-sync
                            catch-up (<=25% of full state), 3-process
                            serve.py cluster fingerprint agreement
  bench_topology            3-zone hierarchical gossip vs flat mesh:
                            cross-zone (WAN) bytes strictly beat the
                            mesh at equal workload in sim AND over real
                            loopback sockets; zone partition heals with
                            no write lost
  bench_obs                 observability: tracing overhead within 10%
                            of untraced throughput, 3-process
                            serve.py --metrics cluster scraped over
                            sidecar HTTP, trace-analyzer redundancy +
                            convergence rollup with zero anomalies
  bench_roofline            per-(arch × shape × mesh) roofline rows from
                            the dry-run artifacts (run dryrun first)

``--json [out.json]`` additionally writes a machine-readable artifact
(name → {us_per_call, derived}, stamped with the git revision and
per-suite wall times, kernel-launch counts, and — per suite — the obs
registry snapshot the suite populated: marker replication lags, queue
drops, redundancy-ratio gauges) so the perf trajectory is
recorded per-commit; a
bare ``--json`` writes ``BENCH_tier1.json`` in the current directory,
which is the repo root in CI (the workflow uploads it). ``--only a,b``
restricts to a subset of suites.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
import time


def _git_revision() -> str:
    """The current commit hash (stamps the JSON artifact so per-commit
    perf trajectories can be reconstructed); 'unknown' outside a repo."""
    import subprocess
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_tier1.json",
                    default=None, metavar="OUT.json",
                    help="also write results as machine-readable JSON "
                         "(bare --json writes BENCH_tier1.json in the "
                         "current directory — the repo root in CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (default: all)")
    args = ap.parse_args(argv)
    if args.json:
        import os
        out_dir = os.path.dirname(os.path.abspath(args.json))
        if not os.path.isdir(out_dir):
            ap.error(f"--json: directory {out_dir} does not exist")

    from . import (bench_antientropy, bench_dots, bench_kernels,
                   bench_lifecycle, bench_message_complexity, bench_net,
                   bench_obs, bench_roofline, bench_store,
                   bench_tensor_sync, bench_topology, bench_wire)

    modules = [
        ("message_complexity", bench_message_complexity),
        ("antientropy", bench_antientropy),
        ("tensor_sync", bench_tensor_sync),
        ("kernels", bench_kernels),
        ("store", bench_store),
        ("wire", bench_wire),
        ("lifecycle", bench_lifecycle),
        ("dots", bench_dots),
        ("topology", bench_topology),
        ("net", bench_net),
        ("obs", bench_obs),
        ("roofline", bench_roofline),
    ]
    if args.only:
        keep = {s.strip() for s in args.only.split(",")}
        unknown = keep - {n for n, _ in modules}
        if unknown:
            raise SystemExit(f"unknown suites {sorted(unknown)}; "
                             f"have {[n for n, _ in modules]}")
        modules = [(n, m) for n, m in modules if n in keep]

    try:        # kernel-launch accounting rides along when jax is present
        from repro.kernels.ops import counters as _kernel_counters
    except Exception:  # pragma: no cover - partial installs
        _kernel_counters = None
    try:        # per-suite metrics snapshots from the obs registry
        from repro.obs import reset_global_registry as _reset_registry
    except Exception:  # pragma: no cover - partial installs
        _reset_registry = None

    print("name,us_per_call,derived")
    results = {}
    suite_wall = {}
    suite_launches = {}
    suite_metrics = {}
    failures = 0
    run_t0 = time.perf_counter()
    for name, mod in modules:
        t0 = time.perf_counter()
        snap = (_kernel_counters.snapshot() if _kernel_counters is not None
                else None)
        # each suite gets a fresh process-wide registry, so its snapshot
        # (marker lags, queue drops, redundancy gauges) is per-suite
        reg = _reset_registry() if _reset_registry is not None else None
        try:
            rows = mod.run()
        except Exception as e:  # report, keep going
            failures += 1
            print(f"{name}_FAILED,nan,{type(e).__name__}: {e}")
            results[f"{name}_FAILED"] = {
                "us_per_call": None, "derived": f"{type(e).__name__}: {e}"}
            continue
        for row_name, us, derived in rows:
            print(f"{row_name},{us:.1f},{derived}")
            results[row_name] = {
                "us_per_call": None if math.isnan(us) else us,
                "derived": derived,
            }
        dt = time.perf_counter() - t0
        suite_wall[name] = round(dt, 3)
        if snap is not None:
            suite_launches[name] = _kernel_counters.since(snap)["launches"]
        if reg is not None:
            metrics = json.loads(reg.render_json())   # NaN/Inf cleaned
            if metrics:
                suite_metrics[name] = metrics
        print(f"# {name} done in {dt:.1f}s", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"git_revision": _git_revision(),
                       "wall_time_s": round(time.perf_counter() - run_t0, 3),
                       "suite_wall_s": suite_wall,
                       "suite_launch_count": suite_launches,
                       "suite_metrics": suite_metrics,
                       "suites": [n for n, _ in modules],
                       "failures": failures,
                       "results": results}, f, indent=1, allow_nan=False)
        print(f"# wrote {args.json} ({len(results)} rows)", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
