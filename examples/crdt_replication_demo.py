"""Fleet-operations demo: the δ-CRDT control plane of the training fleet.

A 6-pod cluster where EVERYTHING riding the control plane is a δ-CRDT:
membership (add-wins OR-Set), heartbeats, duplicate-safe metrics, and an
LWW config register — gossiped with Algorithm 2 across a network with
loss, duplication, a long partition, and a crash/recovery. No coordinator,
no exactly-once delivery, yet every replica converges to the same view.

Run:  PYTHONPATH=src python examples/crdt_replication_demo.py
"""

import random
from dataclasses import dataclass

from repro.core import (CausalNode, LWWRegister, NetConfig, Simulator,
                        converged, run_to_convergence)
from repro.sync.membership import ClusterState, Membership
from repro.sync.metrics import MetricsState


@dataclass(frozen=True)
class ControlPlane:
    """Product lattice: cluster view × metrics × LWW config."""
    cluster: ClusterState = ClusterState.bottom()
    metrics: MetricsState = MetricsState.bottom()
    config: LWWRegister = LWWRegister.bottom()

    @staticmethod
    def bottom():
        return ControlPlane()

    def join(self, other):
        return ControlPlane(self.cluster.join(other.cluster),
                            self.metrics.join(other.metrics),
                            self.config.join(other.config))

    def leq(self, other):
        return self.join(other) == other


N = 6
sim = Simulator(NetConfig(loss=0.3, dup=0.2, seed=42))
ids = [f"pod{k}" for k in range(N)]
nodes = [sim.add_node(CausalNode(i, ControlPlane.bottom(),
                                 [j for j in ids if j != i],
                                 rng=random.Random(k)))
         for k, i in enumerate(ids)]
agents = {i: Membership(i, timeout=12.0, evict_after=40.0) for i in ids}

# pods announce themselves + initial LR config from pod0
for n in nodes:
    n.operation(lambda X, n=n: ControlPlane(
        cluster=agents[n.id].announce(X.cluster, sim.time)))
nodes[0].operation(lambda X: ControlPlane(
    config=X.config.write_delta("pod0", 1, {"lr": 3e-4})))

# partition pods 4,5 away for a while; pod3 crashes and recovers
sim.add_partition(5.0, 60.0, ids[:4], ids[4:])
sim.schedule(10.0, lambda: sim.crash("pod5", downtime=15.0))

step_count = {i: 0 for i in ids}
for round_idx in range(24):
    for n in nodes:
        if not n.alive:
            continue
        i = n.id
        step_count[i] += 1
        loss_val = 4.0 / (1 + 0.2 * step_count[i])
        n.operation(lambda X, i=i, lv=loss_val: ControlPlane(
            cluster=agents[i].heartbeat(X.cluster, sim.time),
            metrics=X.metrics.observe_delta(i, "loss", lv)
                     .join(X.metrics.observe_delta(i, "tokens", 4096.0))))
    if round_idx == 12:  # mid-run config push (survives the partition)
        nodes[1].operation(lambda X: ControlPlane(
            config=X.config.write_delta("pod1", 2, {"lr": 1e-4})))
    sim.run_for(4.0)

t = run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
assert converged(nodes)
view = nodes[0].X
print(f"converged at t={t:.0f} "
      f"(drops={sim.stats.dropped}, dups={sim.stats.duplicated})")
print(f"members: {sorted(view.cluster.workers())}")
print(f"config (LWW): {view.config.read()}")
print(f"tokens total (duplicate-safe): {view.metrics.total('tokens'):.0f} "
      f"over {view.metrics.count('tokens')} reports")
print(f"mean loss: {view.metrics.mean('loss'):.3f} "
      f"min={view.metrics.minimum('loss'):.3f}")
expected_reports = sum(step_count.values())
assert view.metrics.count("tokens") == expected_reports, \
    (view.metrics.count("tokens"), expected_reports)
print(f"exactly {expected_reports} reports counted despite loss+dup ✓")
