"""Quickstart: the δ-CRDT core in five minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import random

from repro.core import (AWORSet, CausalNode, GCounter, MVRegister, NetConfig,
                        ORMap, Simulator, converged, run_to_convergence,
                        structural_size)

print("=" * 72)
print("1. Delta-mutators: ship one map entry, not the whole counter (Fig. 2)")
print("=" * 72)
X = GCounter.bottom()
for k in range(64):
    X = X.join(X.inc_delta(f"replica{k}"))       # 64 replicas ever wrote
delta = X.inc_delta("replica7")
print(f"full state: {structural_size(X)} atoms; delta: "
      f"{structural_size(delta)} atoms")
print(f"value before={X.value()} after join={X.join(delta).value()} "
      f"after re-delivering the same delta 3x="
      f"{X.join(delta).join(delta).join(delta).value()}  (idempotent!)")

print()
print("=" * 72)
print("2. Optimized add-wins OR-Set (Fig. 3b): concurrent add beats remove")
print("=" * 72)
base = AWORSet.bottom()
base = base.join(base.add_delta("a", "x"))
ra = base.join(base.rmv_delta("a", "x"))         # replica a removes x
rb = base.join(base.add_delta("b", "x"))         # replica b re-adds x
print(f"a's view: {set(ra.elements())}, b's view: {set(rb.elements())}, "
      f"joined: {set(ra.join(rb).elements())}  (add wins)")
print(f"causal context compressed to a version vector: "
      f"{ra.join(rb).ctx.vv_dict()} cloud={set(ra.join(rb).ctx.cloud)}")

print()
print("=" * 72)
print("3. Multi-value register (Fig. 4): siblings on concurrency")
print("=" * 72)
r = MVRegister.bottom()
wa = r.join(r.write_delta("a", "blue"))
wb = r.join(r.write_delta("b", "green"))
both = wa.join(wb)
print(f"concurrent writes -> read() = {set(both.read())}")
final = both.join(both.write_delta("a", "teal"))
print(f"after a later write -> read() = {set(final.read())}")

print()
print("=" * 72)
print("4. Composable ORMap (the Riak-DT-Map shape)")
print("=" * 72)
m = ORMap.bottom()
m = m.join(m.apply_delta("a", "tags", AWORSet, "add_delta", "crdt"))
m = m.join(m.apply_delta("a", "tags", AWORSet, "add_delta", "delta"))
m = m.join(m.apply_delta("b", "authors", AWORSet, "add_delta", "almeida"))
print(f"keys={set(m.keys())}, "
      f"tags={set(m.get_value('tags', AWORSet).elements())}")

print()
print("=" * 72)
print("5. Algorithm 2 over a terrible network (40% loss, duplication)")
print("=" * 72)
sim = Simulator(NetConfig(loss=0.4, dup=0.25, seed=1))
ids = ["n0", "n1", "n2", "n3"]
nodes = [sim.add_node(CausalNode(i, AWORSet.bottom(),
                                 [j for j in ids if j != i],
                                 rng=random.Random(7))) for i in ids]
for k in range(40):
    n = nodes[k % 4]
    n.operation(lambda X, i=n.id, k=k: X.add_delta(i, f"item{k}"))
    sim.run_for(0.5)
t = run_to_convergence(sim, nodes, interval=1.0)
print(f"converged at t={t:.0f} despite {sim.stats.dropped} drops / "
      f"{sim.stats.duplicated} dups; all replicas hold "
      f"{len(nodes[0].X.elements())} items; states equal: {converged(nodes)}")
