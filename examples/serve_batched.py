"""Serving example: batched prefill + decode with ring KV caches, plus a
δ-CRDT-replicated session table across 3 gateways on a lossy network.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen1.5-0.5b", "--batch", "4",
                "--prompt-len", "32", "--gen", "24", "--replicate", "3"]
    main()
