"""End-to-end driver: train a ~100M-parameter decoder with δ-CRDT
machinery in the loop.

Two parts:

  (a) single-replica training with delta-interval checkpointing
      (crash-safe, idempotent restore) on a ~100M dense LM;
  (b) multi-pod local-SGD where pods gossip uniquely-dotted pseudo-gradient
      deltas over a 20%-loss network (Algorithm 2) — the paper's protocol
      carrying real training state.

CPU note: ~100M × a few hundred steps is hours on this 1-core container;
``--quick`` (default) runs a ~20M config × 60 steps so the loss curve is
visible in minutes. Pass ``--full`` for the ~100M × 300-step run.

Run:  PYTHONPATH=src python examples/train_delta_sync.py [--full]
"""

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (DeltaCheckpointStore, pytree_from_state,
                              state_from_pytree)
from repro.data import SyntheticLMStream
from repro.models import ModelConfig, init_model
from repro.optim import AdamWConfig
from repro.optim.adamw import init_opt_state
from repro.runtime import TrainConfig, make_train_step


def lm_config(full: bool) -> ModelConfig:
    if full:  # ~97M params
        return ModelConfig(name="lm-97m", family="dense", n_layers=10,
                           d_model=640, n_heads=10, n_kv_heads=10,
                           d_ff=2560, vocab=50_000, tie_embeddings=True,
                           act="swiglu", norm="rms", pos="rope",
                           dtype="float32")
    return ModelConfig(name="lm-21m", family="dense", n_layers=6,
                       d_model=384, n_heads=6, n_kv_heads=6,
                       d_ff=1536, vocab=16_000, tie_embeddings=True,
                       act="swiglu", norm="rms", pos="rope",
                       dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_delta_ckpt")
    args = ap.parse_args()

    cfg = lm_config(args.full)
    steps = args.steps or (300 if args.full else 120)
    batch, seq = (8, 256) if args.full else (8, 128)
    total, _ = cfg.param_counts()
    print(f"model {cfg.name}: {total / 1e6:.0f}M params, "
          f"{steps} steps of batch {batch}x{seq}")

    stream = SyntheticLMStream(vocab=cfg.vocab, seq=seq, batch=batch, seed=3)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=3e-3, warmup_steps=max(10, steps // 10), total_steps=steps),
        remat=False)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    store = DeltaCheckpointStore(args.ckpt)
    losses = []
    t0 = time.time()
    for step in range(steps):
        b = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        params, opt, m = step_fn(params, opt, b)
        losses.append(float(m["loss"]))
        if step % 10 == 0 or step == steps - 1:
            print(f"  step {step:4d}  loss {losses[-1]:.4f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
        if (step + 1) % 20 == 0:
            # delta-interval checkpoint: snapshot every 3rd, delta otherwise
            full_state, spec = state_from_pytree(
                {"p": params, "o": opt}, chunk_size=65536, rank=0,
                lamport=step + 1)
            ck = store.seq + 1
            if ck % 3 == 0:
                store.save_snapshot(full_state, seq=ck)
            else:
                store.append_delta(full_state, seq=ck)
    want = 0.7 if args.full else 0.88   # quick mode: 120 CPU steps
    assert losses[-1] < losses[0] * want, "loss did not decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({(1 - losses[-1] / losses[0]):.0%} drop)")

    # crash/recovery: restore from the delta log and verify equality
    restored, seq = store.restore()
    full_state, spec = state_from_pytree({"p": params, "o": opt},
                                         chunk_size=65536, rank=0)
    back = pytree_from_state(restored, spec)
    same = all(np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree_util.tree_leaves(back["p"]),
                               jax.tree_util.tree_leaves(params)))
    print(f"restore from snapshot+deltas at ckpt-seq {seq}: "
          f"params identical = {same}")

    # (b) multi-pod delta gossip (smoke-scale; see repro.launch.train
    #     --mode delta for the full CLI)
    print("\nmulti-pod δ-CRDT local-SGD over a lossy network:")
    from repro.launch.train import run_delta

    class A:  # tiny args namespace
        arch = "qwen1.5-0.5b"
        seq, batch, lr, seed = 64, 4, 1e-3, 0
        steps, local_steps, pods = 9, 3, 3
        net_loss, topk = 0.2, None
        # BP+RR: never echo a delta to its origin, never re-ship acked
        # state — same converged params, fewer gossip bytes
        ship_policy = "bp+rr"
    run_delta(A)


if __name__ == "__main__":
    main()
