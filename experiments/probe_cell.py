"""Fast perf-iteration probe: lower ONE layer-group of a cell and print the
collective/memory breakdown. Usage:

  PYTHONPATH=src python experiments/probe_cell.py <arch> <shape> [group_idx]
      [--multi] [--attn chunked] [--micro N] [--donate]

Iterating on the probe is seconds instead of minutes; the full cell is
re-lowered with repro.launch.dryrun once a change wins on the probe.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse       # noqa: E402
import dataclasses    # noqa: E402
import sys            # noqa: E402

from repro.configs import SHAPE_CASES, get_config  # noqa: E402
from repro.dist import make_rules  # noqa: E402
from repro.launch.dryrun import (_cell_costs, _lower_and_compile,
                                 _memory_summary)  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import layout_groups  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("group", nargs="?", type=int, default=None)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--attn", default=None)
    ap.add_argument("--moe", default=None)
    ap.add_argument("--block", type=int, default=None)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--full-depth", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.attn:
        cfg = dataclasses.replace(cfg, attn_impl=args.attn)
    if args.moe:
        cfg = dataclasses.replace(cfg, moe_impl=args.moe)
    if args.block:
        cfg = dataclasses.replace(cfg, attn_block=args.block)
    if not args.full_depth:
        groups = layout_groups(cfg.default_layout())
        if args.group is None:
            # biggest group by repeats
            gi = max(range(len(groups)), key=lambda i: groups[i][1])
        else:
            gi = args.group
        block = groups[gi][0]
        cfg = dataclasses.replace(cfg, layout=tuple(block),
                                  n_layers=len(block))
        print(f"probing group {gi}: {len(block)} layer(s) "
              f"(full model: x{groups[gi][1]})")
    case = SHAPE_CASES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi)
    chips = int(len(mesh.devices.flat))
    rules = make_rules(mesh)
    import time
    t0 = time.time()
    lowered, compiled = _lower_and_compile(cfg, case, mesh, args.multi,
                                           rules, args.micro)
    costs = _cell_costs(compiled, chips)
    mem = _memory_summary(compiled) or {}
    print(f"compile {time.time()-t0:.1f}s | flops/chip {costs['flops']:.3e} "
          f"| HBM {costs['bytes accessed']/1e9:.1f} GB "
          f"| wire {costs['wire']/1e9:.2f} GB "
          f"| temp {mem.get('temp_size_in_bytes', 0)/1e9:.1f} GB")
    print("per-kind GB:", {k: round(v / 1e9, 2)
                           for k, v in costs["per_kind"].items()})
    print("counts:", costs["counts"])
    # biggest collective shapes
    import re
    from collections import Counter
    pat = re.compile(r"= ((?:\(?[a-z0-9]+\[[0-9,]*\])[^ ]*) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(")
    c = Counter()
    for line in compiled.as_text().splitlines():
        m = pat.search(line)
        if m and "-done(" not in line:
            c[f"{m.group(2)} {m.group(1)[:48]}"] += 1
    for k, n in c.most_common(12):
        print(f"  {n:3d}x {k}")


if __name__ == "__main__":
    main()
