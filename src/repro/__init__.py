"""repro — δ-CRDTs (Almeida, Shoker & Baquero 2014) as the replication
substrate of a multi-pod JAX training/serving framework.

Subpackages: core (the paper), models/configs (10 architectures),
kernels (Pallas TPU), dist (sharding + roofline), sync (cross-pod δ
runtime), checkpoint (delta-interval durable store), optim, data,
runtime (step functions), launch (mesh / dryrun / train / serve).
"""

__version__ = "0.1.0"
