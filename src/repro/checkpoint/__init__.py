"""Delta-interval incremental checkpointing (Algorithm 2 semantics on disk).

The checkpoint directory plays the role of the paper's durable storage
(§2: "durable state is written atomically at each state transition"):
a full ``TensorState`` snapshot at sequence ``c₀`` plus a log of delta
files ``c₀+1 .. c``; restore is ``snapshot ⊔ d₁ ⊔ … ⊔ dₖ`` — joins are
idempotent, so replaying a suffix after a partial restore is harmless,
and a crash mid-write leaves only an ignored temp file (atomic rename).
"""

from .store import DeltaCheckpointStore, pytree_from_state, state_from_pytree

__all__ = ["DeltaCheckpointStore", "pytree_from_state", "state_from_pytree"]
