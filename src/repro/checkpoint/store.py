"""Durable delta-interval checkpoint store.

Layout (one directory per replica):

    snapshot-<seq>.npz     full TensorState as of sequence <seq>
    delta-<seq>.npz        the delta joined at sequence <seq>
    manifest.json          {"seq": c, "snapshots": [...], "meta": {...}}

Every write is write-temp + ``os.replace`` (atomic on POSIX), mirroring the
paper's atomic durable transitions; the manifest is rewritten last, so a
crash at ANY point leaves a consistent prefix:

* crash before manifest update → the orphan snapshot/delta file is ignored;
* restore = latest manifest'd snapshot ⊔ subsequent deltas (in sequence
  order). Joins are idempotent, so an operator re-running a restore, or a
  restore that races a replay, cannot corrupt state (same argument that
  lets Algorithm 2 re-send delta-intervals).

``state_from_pytree``/``pytree_from_state`` bridge model/optimizer pytrees
to the chunked ``TensorState`` lattice.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from ..core.tensor_lattice import (ChunkedTensor, TensorState, chunk_tensor,
                                   make_version, unchunk)


# ---------------------------------------------------------------------------
# pytree <-> TensorState
# ---------------------------------------------------------------------------

def state_from_pytree(tree: Any, chunk_size: int, rank: int,
                      lamport: int = 1) -> Tuple[TensorState, Dict[str, Any]]:
    """Chunk every leaf; returns (state, spec) where spec records
    shapes/dtypes for reconstruction."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    chunks: Dict[str, ChunkedTensor] = {}
    spec: Dict[str, Any] = {"treedef": treedef, "leaves": {}}
    version = make_version(lamport, rank)
    for path, leaf in leaves:
        name = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        ct = chunk_tensor(arr, chunk_size)
        chunks[name] = ChunkedTensor(
            ct.values,
            np.full((ct.values.shape[0],), version, dtype=np.int64))
        spec["leaves"][name] = (arr.shape, str(arr.dtype))
    return TensorState.of(chunks, lamport=lamport), spec


def pytree_from_state(state: TensorState, spec: Dict[str, Any]) -> Any:
    leaves = []
    d = state.as_dict()
    for name, (shape, dtype) in spec["leaves"].items():
        ct = d[name]
        leaves.append(np.asarray(unchunk(ct, tuple(shape))).astype(dtype))
    return jax.tree_util.tree_unflatten(spec["treedef"], leaves)


# ---------------------------------------------------------------------------
# npz (de)serialization of TensorState
# ---------------------------------------------------------------------------

def _state_to_arrays(state: TensorState) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {"__lamport__": np.asarray(state.lamport)}
    for name, ct in state.chunks:
        out[f"v::{name}"] = np.asarray(ct.values)
        out[f"s::{name}"] = np.asarray(ct.versions)
    return out


def _state_from_arrays(arrs: Dict[str, np.ndarray]) -> TensorState:
    chunks: Dict[str, ChunkedTensor] = {}
    for key in arrs:
        if key.startswith("v::"):
            name = key[3:]
            chunks[name] = ChunkedTensor(arrs[key], arrs[f"s::{name}"])
    return TensorState.of(chunks, lamport=int(arrs["__lamport__"]))


def _atomic_write(path: str, write_fn) -> None:
    d = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
        os.replace(tmp, path)  # atomic durable transition
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


class DeltaCheckpointStore:
    """Algorithm-2-shaped durable store: (X at snapshot, delta log, seq c)."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    # -- manifest ----------------------------------------------------------------
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def _read_manifest(self) -> Dict[str, Any]:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except FileNotFoundError:
            return {"seq": -1, "snapshots": [], "deltas": [], "meta": {}}

    def _write_manifest(self, m: Dict[str, Any]) -> None:
        _atomic_write(self._manifest_path(),
                      lambda f: f.write(json.dumps(m).encode()))

    @property
    def seq(self) -> int:
        return self._read_manifest()["seq"]

    # -- writes ---------------------------------------------------------------
    def save_snapshot(self, state: TensorState, seq: int,
                      meta: Optional[Dict[str, Any]] = None) -> None:
        path = os.path.join(self.dir, f"snapshot-{seq:08d}.npz")
        arrs = _state_to_arrays(state)
        _atomic_write(path, lambda f: np.savez(f, **arrs))
        m = self._read_manifest()
        m["snapshots"] = sorted(set(m["snapshots"]) | {seq})
        m["seq"] = max(m["seq"], seq)
        if meta:
            m["meta"].update(meta)
        self._write_manifest(m)

    def append_delta(self, delta: TensorState, seq: int) -> None:
        m = self._read_manifest()
        assert seq == m["seq"] + 1, (
            f"delta log must be contiguous (got {seq}, have {m['seq']}) — "
            "the causal delta-merging condition on disk")
        path = os.path.join(self.dir, f"delta-{seq:08d}.npz")
        arrs = _state_to_arrays(delta)
        _atomic_write(path, lambda f: np.savez(f, **arrs))
        m["deltas"] = sorted(set(m.get("deltas", [])) | {seq})
        m["seq"] = seq
        self._write_manifest(m)

    # -- restore ---------------------------------------------------------------
    def restore(self) -> Tuple[TensorState, int]:
        """Latest snapshot ⊔ subsequent deltas. Idempotent by construction."""
        m = self._read_manifest()
        if not m["snapshots"]:
            return TensorState.bottom(), m["seq"]
        snap_seq = max(m["snapshots"])
        with np.load(os.path.join(self.dir,
                                  f"snapshot-{snap_seq:08d}.npz")) as z:
            state = _state_from_arrays(dict(z))
        for seq in sorted(m.get("deltas", [])):
            if seq <= snap_seq:
                continue
            with np.load(os.path.join(self.dir, f"delta-{seq:08d}.npz")) as z:
                state = state.join(_state_from_arrays(dict(z)))
        return state, m["seq"]

    # -- GC ------------------------------------------------------------------
    def gc(self, keep_snapshots: int = 1) -> None:
        """Drop snapshots older than the newest ``keep_snapshots`` and any
        delta at/below the oldest kept snapshot (acked-by-disk prefix)."""
        m = self._read_manifest()
        snaps = sorted(m["snapshots"])
        keep = snaps[-keep_snapshots:] if snaps else []
        horizon = keep[0] if keep else -1
        for s in snaps:
            if s not in keep:
                _try_unlink(os.path.join(self.dir, f"snapshot-{s:08d}.npz"))
        kept_deltas = []
        for d in sorted(m.get("deltas", [])):
            if d <= horizon:
                _try_unlink(os.path.join(self.dir, f"delta-{d:08d}.npz"))
            else:
                kept_deltas.append(d)
        m["snapshots"] = keep
        m["deltas"] = kept_deltas
        self._write_manifest(m)


def _try_unlink(path: str) -> None:
    try:
        os.unlink(path)
    except FileNotFoundError:
        pass
