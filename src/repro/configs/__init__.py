"""Architecture registry: ``--arch <id>`` → ModelConfig.

Each module defines the exact published CONFIG plus a REDUCED config of the
same family (same layer-kind pattern, same structural features, tiny dims)
for CPU smoke tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models import ModelConfig

_MODULES: Dict[str, str] = {
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "qwen2-1.5b": "qwen2_1_5b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "gemma2-27b": "gemma2_27b",
    "mamba2-130m": "mamba2_130m",
    "musicgen-large": "musicgen_large",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.REDUCED if reduced else mod.CONFIG


from .shapes import SHAPE_CASES, ShapeCase, applicable, input_specs, smoke_batch  # noqa: E402

__all__ = ["ARCH_IDS", "get_config", "SHAPE_CASES", "ShapeCase",
           "applicable", "input_specs", "smoke_batch"]
