"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536(expert)
vocab=102400, MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf] First layer keeps a dense MLP (first_k_dense=1,
dense d_ff=12288, per the published config)."""

from repro.models import LayerSpec, MLASpec, ModelConfig, MoESpec

_LAYOUT = (LayerSpec(kind="mla", mlp="dense"),) + tuple(
    LayerSpec(kind="mla", mlp="moe") for _ in range(59))

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400,
    layout=_LAYOUT,
    mla=MLASpec(q_lora_rank=1536, kv_lora_rank=512,
                qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoESpec(num_experts=160, top_k=6, expert_d_ff=1536,
                num_shared_experts=2, shared_d_ff=1536),
    act="swiglu", norm="rms", pos="rope",
    subquadratic=False,  # MLA is full attention → skip long_500k
)

REDUCED = ModelConfig(
    name="deepseek-v2-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=89,
    layout=(LayerSpec(kind="mla", mlp="dense"),
            LayerSpec(kind="mla", mlp="moe")),
    mla=MLASpec(q_lora_rank=32, kv_lora_rank=16,
                qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoESpec(num_experts=8, top_k=2, expert_d_ff=64,
                num_shared_experts=2, shared_d_ff=64,
                capacity_factor=float(8)),
    act="swiglu", norm="rms", pos="rope",
    subquadratic=False, dtype="float32",
)
