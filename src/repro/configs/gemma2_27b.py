"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; local(4096-window)/global alternating attention, attn/final
logit softcaps, pre+post norms, GeGLU, scaled+tied embeddings.
[arXiv:2408.00118; hf] query_pre_attn_scalar=144 → query scale 144^-1/2."""

from repro.models import LayerSpec, ModelConfig

_LAYOUT = tuple(
    LayerSpec(kind="attn", window=(4096 if i % 2 == 0 else None),
              mlp="dense")
    for i in range(46))

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000,
    layout=_LAYOUT,
    attn_softcap=50.0, final_softcap=30.0, query_scale=144.0 ** -0.5,
    act="geglu", norm="rms", post_norms=True, pos="rope",
    scale_embed=True, tie_embeddings=True,
    subquadratic=False,  # global layers keep full KV → skip long_500k
)

REDUCED = ModelConfig(
    name="gemma2-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab=93,
    layout=(LayerSpec(kind="attn", window=16, mlp="dense"),
            LayerSpec(kind="attn", window=None, mlp="dense")),
    attn_softcap=50.0, final_softcap=30.0, query_scale=16.0 ** -0.5,
    act="geglu", norm="rms", post_norms=True, pos="rope",
    scale_embed=True, tie_embeddings=True,
    subquadratic=False, dtype="float32",
)
