"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2; Mamba+attention 1:7 interleave (attention at
layer offset 4, period 8) and MoE every 2nd layer (offset 1, period 2).
[arXiv:2403.19887; hf] Jamba's Mamba-1 layers are mapped to the SSD block
(DESIGN.md §changed-assumptions)."""

from repro.models import LayerSpec, ModelConfig, MoESpec, SSMSpec

_LAYOUT = tuple(
    LayerSpec(kind=("attn" if i % 8 == 4 else "ssm"),
              mlp=("moe" if i % 2 == 1 else "dense"))
    for i in range(32))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    layout=_LAYOUT,
    moe=MoESpec(num_experts=16, top_k=2, expert_d_ff=14336),
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2, head_dim=64,
                n_groups=1, chunk=256),
    act="swiglu", norm="rms", pos="none",  # jamba uses no positional emb
    subquadratic=True,  # SSM-dominant: runs long_500k
)

REDUCED = ModelConfig(
    name="jamba-reduced", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=91,
    layout=tuple(
        LayerSpec(kind=("attn" if i % 4 == 2 else "ssm"),
                  mlp=("moe" if i % 2 == 1 else "dense"))
        for i in range(4)),
    moe=MoESpec(num_experts=4, top_k=2, expert_d_ff=128,
                capacity_factor=float(4)),
    ssm=SSMSpec(d_state=8, d_conv=4, expand=2, head_dim=16,
                n_groups=1, chunk=8),
    act="swiglu", norm="rms", pos="none",
    subquadratic=True, dtype="float32",
)
