"""mamba2-130m [ssm] — 24L d_model=768, attn-free, ssm_state=128, SSD
(state-space duality). [arXiv:2405.21060; unverified] Pure mixer blocks
(no MLP), tied embeddings."""

from repro.models import LayerSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    layout=tuple(LayerSpec(kind="ssm", mlp="none") for _ in range(24)),
    ssm=SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64,
                n_groups=1, chunk=256),
    act="swiglu", norm="rms", pos="none", tie_embeddings=True,
    subquadratic=True,  # O(1)-in-seq decode state → runs long_500k
)

REDUCED = ModelConfig(
    name="mamba2-reduced", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=89,
    layout=tuple(LayerSpec(kind="ssm", mlp="none") for _ in range(2)),
    ssm=SSMSpec(d_state=16, d_conv=4, expand=2, head_dim=16,
                n_groups=1, chunk=8),
    act="swiglu", norm="rms", pos="none", tie_embeddings=True,
    subquadratic=True, dtype="float32",
)
