"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""

from repro.models import LayerSpec, ModelConfig, MoESpec

_WINDOW = 4096

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=32768,
    layout=tuple(LayerSpec(kind="attn", window=_WINDOW, mlp="moe")
                 for _ in range(56)),
    moe=MoESpec(num_experts=8, top_k=2, expert_d_ff=16384),
    act="swiglu", norm="rms", pos="rope", rope_theta=1e6,
    subquadratic=True,  # SWA: decode cache bounded by the window
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=97,
    layout=tuple(LayerSpec(kind="attn", window=16, mlp="moe")
                 for _ in range(2)),
    moe=MoESpec(num_experts=4, top_k=2, expert_d_ff=128,
                capacity_factor=float(4)),
    act="swiglu", norm="rms", pos="rope", rope_theta=1e6,
    subquadratic=True, dtype="float32",
)
