"""musicgen-large [audio] — 48L d_model=2048 32H (MHA) d_ff=8192
vocab=2048; decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]
Backbone only: the EnCodec frontend is a STUB — input_specs() supplies
precomputed frame embeddings; LN + GELU + sinusoidal positions."""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048,
    act="gelu", norm="ln", pos="sinusoidal",
    input_mode="embeds",
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="musicgen-reduced", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=64,
    act="gelu", norm="ln", pos="sinusoidal",
    input_mode="embeds",
    subquadratic=False, dtype="float32",
)
