"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend (STUB: input_specs supplies
precomputed patch embeddings). [hf:microsoft/Phi-3-vision-128k-instruct]"""

from repro.models import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    act="swiglu", norm="rms", pos="rope",
    input_mode="tokens+prefix", prefix_len=256,  # 256 patch positions
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="phi-3-vision-reduced", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=101,
    act="swiglu", norm="rms", pos="rope",
    input_mode="tokens+prefix", prefix_len=8,
    subquadratic=False, dtype="float32",
)
