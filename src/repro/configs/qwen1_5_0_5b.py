"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (MHA) d_ff=2816
vocab=151936, QKV bias, tied embeddings. [hf:Qwen/Qwen1.5-0.5B]"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab=151936,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    act="swiglu", norm="rms", pos="rope",
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="qwen1.5-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=111,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    act="swiglu", norm="rms", pos="rope",
    subquadratic=False, dtype="float32",
)
