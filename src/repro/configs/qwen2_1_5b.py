"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151936,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    act="swiglu", norm="rms", pos="rope",
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="qwen2-reduced", family="dense",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=2, head_dim=8,
    d_ff=96, vocab=103,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    act="swiglu", norm="rms", pos="rope",
    subquadratic=False, dtype="float32",
)
