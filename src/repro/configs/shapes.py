"""Assigned input-shape cases and per-(arch × shape) input specs.

The four LM shape cells (seq_len × global_batch):

    train_4k      4,096 × 256    → lowers train_step
    prefill_32k   32,768 × 32    → lowers serve prefill
    decode_32k    32,768 × 128   → lowers serve_step (1 token + 32k cache)
    long_500k     524,288 × 1    → lowers serve_step; sub-quadratic archs
                                   only (cfg.subquadratic)

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for the dry-run; ``smoke_batch`` builds
tiny concrete batches for the per-arch CPU smoke tests.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_caches


@dataclass(frozen=True)
class ShapeCase:
    name: str
    seq: int
    batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPE_CASES: Dict[str, ShapeCase] = {
    "train_4k": ShapeCase("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ModelConfig, case: ShapeCase) -> Tuple[bool, str]:
    """Whether this (arch × shape) cell runs, and why not if it doesn't."""
    if case.name == "long_500k" and not cfg.subquadratic:
        return False, (f"{cfg.name}: full-attention decode state at 512k "
                       "context is not sub-quadratic — skipped per the "
                       "assignment (DESIGN.md §Arch-applicability)")
    return True, ""


def _tok_dtype():
    return jnp.int32


def input_specs(cfg: ModelConfig, case: ShapeCase) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree for the step function of this cell."""
    b, s = case.batch, case.seq
    f = jnp.dtype(cfg.dtype)
    SDS = jax.ShapeDtypeStruct
    if case.step in ("train", "prefill"):
        if cfg.input_mode == "embeds":
            batch = {"embeds": SDS((b, s, cfg.d_model), f)}
            if case.step == "train":
                batch["labels"] = SDS((b, s), _tok_dtype())
        elif cfg.input_mode == "tokens+prefix":
            st = s - cfg.prefix_len
            batch = {"tokens": SDS((b, st), _tok_dtype()),
                     "prefix_embeds": SDS((b, cfg.prefix_len, cfg.d_model), f)}
            if case.step == "train":
                batch["labels"] = SDS((b, st), _tok_dtype())
        else:
            batch = {"tokens": SDS((b, s), _tok_dtype())}
            if case.step == "train":
                batch["labels"] = SDS((b, s), _tok_dtype())
        return batch

    # decode: one new token against a seq-length cache
    if cfg.input_mode == "embeds":
        tok = SDS((b, 1, cfg.d_model), f)
    else:
        tok = SDS((b, 1), _tok_dtype())
    caches = jax.eval_shape(lambda: init_caches(cfg, None, b, s))
    return {"tokens": tok, "pos": SDS((b, 1), _tok_dtype()),
            "caches": caches}


# ---------------------------------------------------------------------------
# Concrete tiny batches for smoke tests
# ---------------------------------------------------------------------------

def smoke_batch(cfg: ModelConfig, b: int = 2, s: int = 16,
                seed: int = 0, train: bool = True) -> Dict[str, Any]:
    rng = np.random.default_rng(seed)
    f = jnp.dtype(cfg.dtype)
    if cfg.input_mode == "embeds":
        batch = {"embeds": jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)).astype(np.float32), f)}
        if train:
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)
    elif cfg.input_mode == "tokens+prefix":
        st = s - cfg.prefix_len
        assert st > 0
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(b, st)),
                                  jnp.int32),
            "prefix_embeds": jnp.asarray(
                rng.normal(size=(b, cfg.prefix_len, cfg.d_model))
                .astype(np.float32), f),
        }
        if train:
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=(b, st)), jnp.int32)
    else:
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)}
        if train:
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab, size=(b, s)), jnp.int32)
    return batch
