"""stablelm-1.6b [dense] — 24L d_model=2048 32H (MHA) d_ff=5632
vocab=100352. LayerNorm + partial rotary (25%), QKV bias per the published
stablelm-2-1_6b config. [hf:stabilityai/stablelm-2-1_6b; unverified]"""

from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=5632, vocab=100352,
    qkv_bias=True, rotary_pct=0.25,
    act="swiglu", norm="ln", pos="rope",
    subquadratic=False,
)

REDUCED = ModelConfig(
    name="stablelm-reduced", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=97,
    qkv_bias=True, rotary_pct=0.25,
    act="swiglu", norm="ln", pos="rope",
    subquadratic=False, dtype="float32",
)
