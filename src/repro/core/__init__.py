"""δ-CRDT core — the paper's primary contribution.

Layers:

* ``dots``          — dots, compressed causal contexts (§7.2), dot stores,
                      the generic causal join of Figs. 3b/4.
* ``crdts``         — the datatype catalogue (counter Figs. 1-2, OR-Sets
                      Figs. 3a/3b, MVRegister Fig. 4, + the library types
                      the paper lists: GSet, 2PSet, PN, LWW, RWORSet,
                      flags, ORMap).
* ``propagation``   — the unified delta-propagation runtime: one
                      ``Replica`` engine (send/receive/ack/GC) behind both
                      algorithms, parameterized by pluggable
                      ``ShippingPolicy`` objects (ship-all, state-every-k,
                      avoid-back-propagation, remove-redundant,
                      digest-budgeted chunk selection).
* ``antientropy``   — Algorithms 1 (basic) and 2 (causal delta-intervals)
                      as thin wrappers over the runtime, plus the
                      classical full-state baseline.
* ``sim``           — the §2 network model as a discrete-event simulator
                      (loss, duplication, reordering, partitions,
                      crash/recovery with durable state).
* ``tensor_lattice``— join-semilattices over JAX pytrees: versioned chunk
                      stores and dot-stores for replicating ML training
                      state across pods (the framework integration).

Key lifecycle (TTL/expiry lattice, acked reaper GC, read-replica
subscriptions) is the sibling package :mod:`repro.lifecycle`;
``LatticeStore`` carries its per-key ``(epoch, expiry)`` component.
"""

from .dots import CausalContext, Dot, DotFun, DotMap, DotSet, causal_join
from .crdts import (ALL_CRDT_TYPES, AWORSet, AWORSetTombstone, DWFlag,
                    DeltaCRDT, EWFlag, GCounter, GSet, LWWRegister, LWWSet,
                    MVRegister, ORMap, PNCounter, RWORSet, TwoPSet)
from .store import LatticeStore, digest_select_store
from .digest import StoreDigest, digest_diff, opaque_hash, store_digest
from .propagation import (AvoidBackPropagation, Compose, DeltaEntry,
                          DigestBudget, DigestExchange, POLICY_SPECS,
                          RemoveRedundant, Replica, ShipAll,
                          ShipStateEveryK, ShippingPolicy, StoreReplica,
                          causal_policy_spec, make_policy, stable_seed)
from .antientropy import (BasicNode, CausalNode, FullStateNode, converged,
                          run_to_convergence)
from .hiergossip import HierarchicalGossip, hierarchical_policy
from .sim import NetConfig, NetStats, Node, Simulator, structural_size

__all__ = [
    "CausalContext", "Dot", "DotFun", "DotMap", "DotSet", "causal_join",
    "ALL_CRDT_TYPES", "AWORSet", "AWORSetTombstone", "DWFlag", "DeltaCRDT",
    "EWFlag", "GCounter", "GSet", "LWWRegister", "LWWSet", "MVRegister",
    "ORMap", "PNCounter", "RWORSet", "TwoPSet",
    "LatticeStore", "digest_select_store",
    "StoreDigest", "digest_diff", "opaque_hash", "store_digest",
    "AvoidBackPropagation", "Compose", "DeltaEntry", "DigestBudget",
    "DigestExchange", "POLICY_SPECS", "RemoveRedundant", "Replica",
    "ShipAll", "ShipStateEveryK", "ShippingPolicy", "StoreReplica",
    "causal_policy_spec", "make_policy", "stable_seed",
    "BasicNode", "CausalNode", "FullStateNode", "converged",
    "run_to_convergence",
    "HierarchicalGossip", "hierarchical_policy",
    "NetConfig", "NetStats", "Node", "Simulator", "structural_size",
]
