"""Anti-entropy algorithms for δ-CRDTs (paper Algorithms 1 and 2).

``BasicNode`` implements Algorithm 1 — convergence only (Prop. 1): deltas are
accumulated in a volatile delta-group ``D`` and periodically broadcast to
neighbours; received payloads are joined into ``X`` (and into ``D`` too when
in *transitive* mode). ``choose`` decides between shipping the delta-group or
the full state (the paper leaves the policy open; we provide a
ship-state-every-k policy so convergence holds under message loss, since
Algorithm 1 clears ``D`` after a send even if the message is dropped).

``CausalNode`` implements Algorithm 2 — causal consistency: every delta
joined into ``X`` is recorded in the sequence ``D`` under an increasing
counter ``c`` (durable, like ``X``); a sender only ships *delta-intervals*
Δᵢᵃ'ᵇ starting at the receiver's acknowledged index, which establishes the
causal delta-merging condition (Def. 6) — see Props. 2 & 3. Old deltas are
garbage-collected once acknowledged by all neighbours; a receiver that is
too far behind (or the sender lost its volatile state in a crash) gets the
full state instead.

Both classes are datatype-generic: they operate on any value implementing
``join``/``leq`` (every datatype in ``repro.core.crdts`` and the tensor
lattices in ``repro.core.tensor_lattice``).

For verifying Prop. 2 operationally, messages optionally carry a *ghost*
copy of the sender's full state at send time: the proof's simulation
argument says joining Δⱼᵃ'ᵇ must produce exactly the state that joining the
full Xⱼᵇ would. ``ghost_check=True`` asserts that equality at every
delivery.
"""

from __future__ import annotations

import random
from typing import Any, Callable, Dict, List, Optional, Sequence

from .sim import Node, Simulator


class BasicNode(Node):
    """Algorithm 1: basic anti-entropy (convergence, no causal guarantees)."""

    def __init__(self, node_id: str, bottom: Any, neighbors: Sequence[str],
                 transitive: bool = True,
                 ship_state_every: Optional[int] = None):
        super().__init__(node_id)
        self.bottom = bottom
        self.X = bottom                 # durable state
        self.D = bottom                 # volatile delta-group
        self.neighbors = list(neighbors)
        self.transitive = transitive
        self.ship_state_every = ship_state_every
        self._round = 0

    # -- paper: on operationᵢ(mᵟ) ------------------------------------------------
    def operation(self, m_delta: Callable[[Any], Any]) -> Any:
        d = m_delta(self.X)
        self.X = self.X.join(d)
        self.D = self.D.join(d)
        return d

    # -- paper: chooseᵢ(Xᵢ, Dᵢ) -----------------------------------------------
    def choose(self) -> Any:
        self._round += 1
        if self.ship_state_every and self._round % self.ship_state_every == 0:
            return self.X
        if self.D == self.bottom:
            return self.X
        return self.D

    # -- paper: periodically -------------------------------------------------
    def on_periodic(self) -> None:
        if not self.alive:
            return
        m = self.choose()
        for j in self.neighbors:
            self.send(j, ("delta", m))
        self.D = self.bottom

    # -- paper: on receiveⱼ,ᵢ(d) ---------------------------------------------
    def on_receive(self, src: str, msg: Any) -> None:
        _, d = msg
        self.X = self.X.join(d)
        if self.transitive:
            self.D = self.D.join(d)

    # -- crash model: X durable, D volatile -----------------------------------
    def durable_snapshot(self) -> Any:
        return self.X

    def recover(self, durable: Any) -> None:
        self.X = durable
        self.D = self.bottom


class CausalNode(Node):
    """Algorithm 2: delta-interval anti-entropy with the causal
    delta-merging condition."""

    def __init__(self, node_id: str, bottom: Any, neighbors: Sequence[str],
                 rng: Optional[random.Random] = None,
                 ghost_check: bool = False,
                 fanout: int = 1):
        super().__init__(node_id)
        self.bottom = bottom
        # durable state
        self.X = bottom
        self.c = 0
        # volatile state
        self.D: Dict[int, Any] = {}
        self.A: Dict[str, int] = {}
        self.neighbors = list(neighbors)
        self.rng = rng or random.Random(hash(node_id) & 0xFFFF)
        self.ghost_check = ghost_check
        self.fanout = fanout
        self.ghost_failures: List[str] = []

    # -- paper: on operationᵢ(mᵟ) -----------------------------------------------
    def operation(self, m_delta: Callable[[Any], Any]) -> Any:
        d = m_delta(self.X)
        self.X = self.X.join(d)
        self.D[self.c] = d
        self.c += 1
        return d

    # -- paper: on receiveⱼ,ᵢ(delta, d, n) ------------------------------------
    def _receive_delta(self, src: str, d: Any, n: int,
                       ghost: Any = None) -> None:
        if not d.leq(self.X):
            if self.ghost_check and ghost is not None:
                got = self.X.join(d)
                want = self.X.join(ghost)
                if got != want:
                    self.ghost_failures.append(
                        f"{src}->{self.id} delta-interval join != full-state join")
            self.X = self.X.join(d)
            self.D[self.c] = d
            self.c += 1
        self.send(src, ("ack", n))

    # -- paper: on receiveⱼ,ᵢ(ack, n) ------------------------------------------
    def _receive_ack(self, src: str, n: int) -> None:
        self.A[src] = max(self.A.get(src, 0), n)

    def on_receive(self, src: str, msg: Any) -> None:
        kind = msg[0]
        if kind == "delta":
            _, d, n, ghost = msg
            self._receive_delta(src, d, n, ghost)
        elif kind == "ack":
            self._receive_ack(src, msg[1])
        else:  # pragma: no cover
            raise ValueError(f"unknown message kind {kind!r}")

    # -- paper: periodically (ship delta-interval or state) -----------------------
    def on_periodic(self) -> None:
        if not self.alive or not self.neighbors:
            return
        targets = self.rng.sample(self.neighbors,
                                  k=min(self.fanout, len(self.neighbors)))
        for j in targets:
            self._ship_to(j)

    def _ship_to(self, j: str) -> None:
        aj = self.A.get(j, 0)
        if not self.D or min(self.D.keys()) > aj:
            d = self.X                      # full-state fallback
        else:
            d = self.bottom
            for l in range(aj, self.c):
                if l in self.D:
                    d = d.join(self.D[l])
        if aj < self.c:
            ghost = self.X if self.ghost_check else None
            self.send(j, ("delta", d, self.c, ghost))

    # -- paper: periodically (garbage collect deltas) ------------------------------
    def gc_deltas(self) -> None:
        # min over *all* neighbours; absent ⇒ 0 (nothing GC-able yet).
        if not self.D:
            return
        l = min(self.A.get(j, 0) for j in self.neighbors)
        self.D = {n: d for n, d in self.D.items() if n >= l}

    # -- crash model: (X, c) durable; (D, A) volatile ----------------------------
    def durable_snapshot(self) -> Any:
        return (self.X, self.c)

    def recover(self, durable: Any) -> None:
        self.X, self.c = durable
        self.D = {}
        self.A = {}


# ---------------------------------------------------------------------------
# Reference: classical full-state shipping (the baseline the paper improves)
# ---------------------------------------------------------------------------

class FullStateNode(Node):
    """Classical state-based CRDT anti-entropy: ship the entire state."""

    def __init__(self, node_id: str, bottom: Any, neighbors: Sequence[str]):
        super().__init__(node_id)
        self.bottom = bottom
        self.X = bottom
        self.neighbors = list(neighbors)

    def operation(self, m_full: Callable[[Any], Any]) -> None:
        self.X = m_full(self.X)

    def on_periodic(self) -> None:
        if not self.alive:
            return
        for j in self.neighbors:
            self.send(j, ("state", self.X))

    def on_receive(self, src: str, msg: Any) -> None:
        _, s = msg
        self.X = self.X.join(s)

    def durable_snapshot(self) -> Any:
        return self.X

    def recover(self, durable: Any) -> None:
        self.X = durable


def converged(nodes: Sequence[Node]) -> bool:
    states = [n.X for n in nodes]
    return all(s == states[0] for s in states[1:])


def run_to_convergence(sim: Simulator, nodes: Sequence[Node],
                       interval: float = 1.0, max_time: float = 10_000.0,
                       gc: bool = True) -> float:
    """Drive periodic anti-entropy until all nodes' states agree.

    Returns the simulated time at convergence; raises if the bound is hit.
    """
    scheduled = getattr(sim, "_ae_scheduled", set())
    for n in nodes:
        if n.id in scheduled:
            continue  # idempotent: don't double-schedule on repeated calls
        scheduled.add(n.id)
        sim.every(interval, n.on_periodic)
        if gc and isinstance(n, CausalNode):
            sim.every(interval * 7, n.gc_deltas)
    sim._ae_scheduled = scheduled
    step = interval * 2
    while sim.time < max_time:
        sim.run_for(step)
        if converged(nodes):
            return sim.time
    raise AssertionError(
        f"no convergence by t={max_time}; states differ: "
        + "; ".join(repr(n.X)[:120] for n in nodes))
