"""Anti-entropy algorithms for δ-CRDTs (paper Algorithms 1 and 2).

Both algorithms are thin configurations of the unified propagation runtime
(:mod:`repro.core.propagation`): one :class:`~repro.core.propagation.Replica`
engine owns the send/receive/ack/GC machinery and a pluggable
:class:`~repro.core.propagation.ShippingPolicy` decides *what* ships each
round (the paper's open ``chooseᵢ(Xᵢ, Dᵢ)``).

``BasicNode`` is Algorithm 1 — convergence only (Prop. 1): deltas accumulate
in a volatile delta-group ``D`` and are periodically broadcast to
neighbours; received payloads join into ``X`` (and into ``D`` too when in
*transitive* mode). The default policy is ``ShipStateEveryK`` when
``ship_state_every`` is set (so convergence holds under message loss, since
Algorithm 1 clears ``D`` after a send even if the message is dropped) and
``ShipAll`` otherwise.

``CausalNode`` is Algorithm 2 — causal consistency: every delta joined into
``X`` is recorded in the sequence ``D`` under an increasing counter ``c``
(durable, like ``X``); a sender only ships *delta-intervals* Δᵢᵃ'ᵇ starting
at the receiver's acknowledged index, which establishes the causal
delta-merging condition (Def. 6) — see Props. 2 & 3. Old deltas are
garbage-collected once acknowledged by all neighbours; a receiver that is
too far behind (or a sender that lost volatile state in a crash) gets the
full state instead. Pass ``policy=`` (e.g. ``AvoidBackPropagation``,
``RemoveRedundant``, or a ``Compose`` of both) to change what enters each
delta-interval; every policy preserves the merging condition (see the
propagation module docstring).

Both classes are datatype-generic: they operate on any value implementing
``join``/``leq`` (every datatype in ``repro.core.crdts`` and the tensor
lattices in ``repro.core.tensor_lattice``).

For verifying Prop. 2 operationally, messages optionally carry a *ghost*
copy of the sender's full state at send time: the proof's simulation
argument says joining Δⱼᵃ'ᵇ must produce exactly the state that joining the
full Xⱼᵇ would. ``ghost_check=True`` asserts that equality at every
delivery — under every shipping policy.
"""

from __future__ import annotations

import random
from typing import Any, Callable, Optional, Sequence

from .propagation import (Replica, ShipAll, ShippingPolicy,
                          ShipStateEveryK)
from .sim import Node, Simulator


class BasicNode(Replica):
    """Algorithm 1: basic anti-entropy (convergence, no causal guarantees)."""

    def __init__(self, node_id: str, bottom: Any, neighbors: Sequence[str],
                 transitive: bool = True,
                 ship_state_every: Optional[int] = None,
                 policy: Optional[ShippingPolicy] = None,
                 wire: Optional[Any] = None):
        if policy is None:
            policy = (ShipStateEveryK(ship_state_every)
                      if ship_state_every else ShipAll())
        super().__init__(node_id, bottom, neighbors, causal=False,
                         policy=policy, transitive=transitive, fanout=None,
                         wire=wire)
        self.ship_state_every = ship_state_every

    # -- paper: chooseᵢ(Xᵢ, Dᵢ), kept for the paper correspondence -------------
    def choose(self, dst: Optional[str] = None) -> Any:
        """What the next broadcast would carry: to ``dst`` when given
        (the full per-destination pipeline — watermark, ``include``
        filter, ``finalize``), else to a *generic* neighbour (coarse
        ``X``-or-``D`` preview, per-destination hooks skipped).

        The generic case passes ``dst=None`` — a sentinel no policy hook
        treats as a real receiver. It used to pass ``""``, which is a
        perfectly legal replica id: ``RemoveRedundant`` would consult
        ``known_state("")`` (any bound actually tracked for a replica
        named ``""`` would silently filter the preview) and
        ``AvoidBackPropagation``'s ``include`` compares it against entry
        origins. ``None`` is unambiguous, and dst-dependent hooks must
        treat it as "no specific receiver" (``dict.get(None)`` misses and
        ``origin != None`` holds for every remote entry, so the built-in
        policies do so for free).

        Peeks at the round counter the engine will use: ``on_periodic``
        increments ``rounds`` before shipping.
        """
        rounds = self.rounds
        try:
            self.rounds += 1
            if self.policy.pull_exchange and self.policy.pull_round(self,
                                                                    dst):
                from .digest import store_digest
                return ("digest", store_digest(self.store))
            if dst is None:
                # coarse preview: per-destination hooks (watermarks,
                # include) are skipped — BP's include would misread the
                # sentinel as "local entries echo back to their origin"
                if self.policy.want_full_state(self, None) \
                        or not self.entries:
                    return self.X
                return self.D
            # the real pipeline _ship_basic runs, minus the side effects
            m, _full = self._basic_payload(dst)
            return m if m is not None else self.bottom
        finally:
            self.rounds = rounds


class CausalNode(Replica):
    """Algorithm 2: delta-interval anti-entropy with the causal
    delta-merging condition."""

    def __init__(self, node_id: str, bottom: Any, neighbors: Sequence[str],
                 rng: Optional[random.Random] = None,
                 ghost_check: bool = False,
                 fanout: int = 1,
                 policy: Optional[ShippingPolicy] = None,
                 wire: Optional[Any] = None):
        super().__init__(node_id, bottom, neighbors, causal=True,
                         policy=policy, rng=rng, ghost_check=ghost_check,
                         fanout=fanout, wire=wire)


# ---------------------------------------------------------------------------
# Reference: classical full-state shipping (the baseline the paper improves)
# ---------------------------------------------------------------------------

class FullStateNode(Node):
    """Classical state-based CRDT anti-entropy: ship the entire state."""

    def __init__(self, node_id: str, bottom: Any, neighbors: Sequence[str],
                 wire: Optional[Any] = None):
        super().__init__(node_id)
        self.bottom = bottom
        self.X = bottom
        self.neighbors = list(neighbors)
        self.wire = wire

    def operation(self, m_full: Callable[[Any], Any]) -> None:
        self.X = m_full(self.X)

    def on_periodic(self) -> None:
        if not self.alive:
            return
        for j in self.neighbors:
            # WireCodec routes on the engine's "delta" tuple shape and
            # tags the frame as state traffic via full_state
            msg = (self.wire.encode_msg(("delta", self.X), full_state=True)
                   if self.wire is not None else ("state", self.X))
            self.send(j, msg)

    def on_receive(self, src: str, msg: Any) -> None:
        if self.wire is not None and isinstance(msg, (bytes, bytearray)):
            msg = self.wire.decode_msg(msg)
        _, s = msg
        self.X = self.X.join(s)

    def durable_snapshot(self) -> Any:
        return self.X

    def recover(self, durable: Any) -> None:
        self.X = durable


def converged(nodes: Sequence[Node]) -> bool:
    states = [n.X for n in nodes]
    return all(s == states[0] for s in states[1:])


def run_to_convergence(sim: Simulator, nodes: Sequence[Node],
                       interval: float = 1.0, max_time: float = 10_000.0,
                       gc: bool = True) -> float:
    """Drive periodic anti-entropy until all nodes' states agree.

    Returns the simulated time at convergence; raises if the bound is hit.
    """
    scheduled = getattr(sim, "_ae_scheduled", set())
    for n in nodes:
        if n.id in scheduled:
            continue  # idempotent: don't double-schedule on repeated calls
        scheduled.add(n.id)
        sim.every(interval, n.on_periodic)
        if gc and isinstance(n, Replica) and n.causal:
            sim.every(interval * 7, n.gc_deltas)
    sim._ae_scheduled = scheduled
    step = interval * 2
    while sim.time < max_time:
        sim.run_for(step)
        if converged(nodes):
            return sim.time
    raise AssertionError(
        f"no convergence by t={max_time}; states differ: "
        + "; ".join(repr(n.X)[:120] for n in nodes))
