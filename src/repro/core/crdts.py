"""δ-CRDT datatype catalogue.

Every datatype below is specified as a triple ``(S, Mᵟ, Q)`` (paper Def. 3):

* the state is an immutable value in a join-semilattice (``join`` is
  commutative, associative, idempotent; ``bottom()`` is ⊥);
* *delta-mutators* ``mᵟ`` take the current state (plus the local replica id
  where the paper indexes the mutator by replica) and return a **delta** —
  a small state in the same semilattice, to be joined locally and shipped;
* *full mutators* ``m`` (suffix ``_full``) implement the corresponding
  standard state-based CRDT mutator, so the delta-state-decomposition law
  of §4.1, ``m(X) = X ⊔ mᵟ(X)``, is directly testable for every datatype.

Datatypes implemented (paper figures in brackets):

  GCounter [Figs. 1–2]          PNCounter           GSet            TwoPSet
  AWORSetTombstone [Fig. 3a]    AWORSet [Fig. 3b]   RWORSet         LWWRegister
  MVRegister [Fig. 4]           LWWSet              EWFlag / DWFlag ORMap

``AWORSet`` / ``MVRegister`` / flags / ``ORMap`` use the compressed causal
context of §7.2 (version vector + dot cloud) and the generic causal join
from ``repro.core.dots``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, FrozenSet, Mapping, Optional, Tuple

from .dots import (CausalContext, Dot, DotFun, DotMap, DotSet, ReplicaId,
                   causal_join)


class DeltaCRDT:
    """Mixin: derived partial order and convenience operators."""

    def join(self, other):  # pragma: no cover - overridden
        raise NotImplementedError

    def leq(self, other) -> bool:
        return self.join(other) == other

    def __or__(self, other):
        return self.join(other)


# ---------------------------------------------------------------------------
# Counters
# ---------------------------------------------------------------------------

def _map_max(a: Tuple[Tuple[ReplicaId, int], ...],
             b: Tuple[Tuple[ReplicaId, int], ...]) -> Tuple[Tuple[ReplicaId, int], ...]:
    m = dict(a)
    for i, n in b:
        m[i] = max(m.get(i, 0), n)
    return tuple(sorted(m.items()))


@dataclass(frozen=True)
class GCounter(DeltaCRDT):
    """Grow-only counter (paper Figs. 1 & 2). State: 𝕀 ↪ ℕ, join: pointwise max."""

    entries: Tuple[Tuple[ReplicaId, int], ...] = ()

    @staticmethod
    def bottom() -> "GCounter":
        return GCounter()

    def value(self) -> int:
        return sum(n for _, n in self.entries)

    def _get(self, i: ReplicaId) -> int:
        return dict(self.entries).get(i, 0)

    # Fig. 2: incᵟᵢ(m) = {i ↦ m(i) + 1} — ONLY the updated entry.
    def inc_delta(self, i: ReplicaId, by: int = 1) -> "GCounter":
        assert by >= 0
        return GCounter(((i, self._get(i) + by),))

    # Fig. 1: incᵢ(m) = m{i ↦ m(i) + 1} — the full map.
    def inc_full(self, i: ReplicaId, by: int = 1) -> "GCounter":
        m = dict(self.entries)
        m[i] = m.get(i, 0) + by
        return GCounter(tuple(sorted(m.items())))

    def join(self, other: "GCounter") -> "GCounter":
        return GCounter(_map_max(self.entries, other.entries))

    def decompose(self):
        """Join-irreducible atoms (one per map entry) — lets the
        RemoveRedundant shipping policy trim payloads part-wise."""
        return [GCounter(((i, n),)) for i, n in self.entries]


@dataclass(frozen=True)
class PNCounter(DeltaCRDT):
    """Increment/decrement counter: a pair of GCounters (P, N)."""

    pos: GCounter = GCounter()
    neg: GCounter = GCounter()

    @staticmethod
    def bottom() -> "PNCounter":
        return PNCounter()

    def value(self) -> int:
        return self.pos.value() - self.neg.value()

    def inc_delta(self, i: ReplicaId, by: int = 1) -> "PNCounter":
        return PNCounter(pos=self.pos.inc_delta(i, by))

    def dec_delta(self, i: ReplicaId, by: int = 1) -> "PNCounter":
        return PNCounter(neg=self.neg.inc_delta(i, by))

    def inc_full(self, i: ReplicaId, by: int = 1) -> "PNCounter":
        return PNCounter(pos=self.pos.inc_full(i, by), neg=self.neg)

    def dec_full(self, i: ReplicaId, by: int = 1) -> "PNCounter":
        return PNCounter(pos=self.pos, neg=self.neg.inc_full(i, by))

    def join(self, other: "PNCounter") -> "PNCounter":
        return PNCounter(self.pos.join(other.pos), self.neg.join(other.neg))

    def decompose(self):
        return ([PNCounter(pos=a) for a in self.pos.decompose()]
                + [PNCounter(neg=a) for a in self.neg.decompose()])


# ---------------------------------------------------------------------------
# Grow-only / two-phase sets
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GSet(DeltaCRDT):
    """Grow-only set. addᵟ(e) = {e}."""

    elems: FrozenSet[Any] = frozenset()

    @staticmethod
    def bottom() -> "GSet":
        return GSet()

    def elements(self) -> FrozenSet[Any]:
        return self.elems

    def add_delta(self, e: Any) -> "GSet":
        return GSet(frozenset([e]))

    def add_full(self, e: Any) -> "GSet":
        return GSet(self.elems | {e})

    def join(self, other: "GSet") -> "GSet":
        return GSet(self.elems | other.elems)


@dataclass(frozen=True)
class TwoPSet(DeltaCRDT):
    """Two-phase set: adds + tombstones; once removed, never re-added."""

    added: FrozenSet[Any] = frozenset()
    removed: FrozenSet[Any] = frozenset()

    @staticmethod
    def bottom() -> "TwoPSet":
        return TwoPSet()

    def elements(self) -> FrozenSet[Any]:
        return self.added - self.removed

    def add_delta(self, e: Any) -> "TwoPSet":
        return TwoPSet(added=frozenset([e]))

    def rmv_delta(self, e: Any) -> "TwoPSet":
        # Observed-remove discipline: tombstone only what was added (paper
        # Fig. 3a applies the same guard for the tombstoned OR-Set).
        if e in self.added:
            return TwoPSet(removed=frozenset([e]))
        return TwoPSet()

    def add_full(self, e: Any) -> "TwoPSet":
        return self.join(self.add_delta(e))

    def rmv_full(self, e: Any) -> "TwoPSet":
        return self.join(self.rmv_delta(e))

    def join(self, other: "TwoPSet") -> "TwoPSet":
        return TwoPSet(self.added | other.added, self.removed | other.removed)


# ---------------------------------------------------------------------------
# Add-wins OR-Set, tombstone version (paper Fig. 3a)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AWORSetTombstone(DeltaCRDT):
    """Σ = 𝒫(𝕀 × ℕ × E) × 𝒫(𝕀 × ℕ); both components grow-only (Fig. 3a)."""

    s: FrozenSet[Tuple[ReplicaId, int, Any]] = frozenset()
    t: FrozenSet[Dot] = frozenset()  # tombstones

    @staticmethod
    def bottom() -> "AWORSetTombstone":
        return AWORSetTombstone()

    def elements(self) -> FrozenSet[Any]:
        return frozenset(e for (j, n, e) in self.s if (j, n) not in self.t)

    def _next_n(self, i: ReplicaId) -> int:
        # n = max({k | (i, k, ⊥) ∈ s}), max(∅) = 0.
        return max((k for (j, k, _) in self.s if j == i), default=0)

    def add_delta(self, i: ReplicaId, e: Any) -> "AWORSetTombstone":
        n = self._next_n(i)
        return AWORSetTombstone(s=frozenset([(i, n + 1, e)]))

    def rmv_delta(self, i: ReplicaId, e: Any) -> "AWORSetTombstone":
        return AWORSetTombstone(
            t=frozenset((j, n) for (j, n, e2) in self.s if e2 == e))

    def add_full(self, i: ReplicaId, e: Any) -> "AWORSetTombstone":
        return self.join(self.add_delta(i, e))

    def rmv_full(self, i: ReplicaId, e: Any) -> "AWORSetTombstone":
        return self.join(self.rmv_delta(i, e))

    def join(self, other: "AWORSetTombstone") -> "AWORSetTombstone":
        return AWORSetTombstone(self.s | other.s, self.t | other.t)


# ---------------------------------------------------------------------------
# Optimized add-wins OR-Set (paper Fig. 3b) — causal context, no tombstones
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AWORSet(DeltaCRDT):
    """Optimized OR-Set: tagged elements shrink on removal (Fig. 3b).

    The causal context is stored compressed (§7.2).
    """

    store: DotFun = DotFun()          # dot -> element
    ctx: CausalContext = CausalContext()

    @staticmethod
    def bottom() -> "AWORSet":
        return AWORSet()

    def elements(self) -> FrozenSet[Any]:
        # Fig. 3b: elements((s, c)) = {e | (j, n, e) ∈ s} — no tombstone check.
        return frozenset(self.store.values())

    def contains(self, e: Any) -> bool:
        return e in self.elements()

    def add_delta(self, i: ReplicaId, e: Any) -> "AWORSet":
        d = self.ctx.next_dot(i)  # n = max{k | (i,k) ∈ c} + 1
        return AWORSet(DotFun.of({d: e}), CausalContext.from_dots([d]))

    def rmv_delta(self, i: ReplicaId, e: Any) -> "AWORSet":
        dots = [d for d, v in self.store.entries if v == e]
        return AWORSet(DotFun(), CausalContext.from_dots(dots))

    def add_full(self, i: ReplicaId, e: Any) -> "AWORSet":
        return self.join(self.add_delta(i, e))

    def rmv_full(self, i: ReplicaId, e: Any) -> "AWORSet":
        return self.join(self.rmv_delta(i, e))

    def join(self, other: "AWORSet") -> "AWORSet":
        store, ctx = causal_join(self.store, self.ctx, other.store, other.ctx)
        return AWORSet(store, ctx)


# ---------------------------------------------------------------------------
# Remove-wins OR-Set (as in the paper's companion C++ library [11])
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RWORSet(DeltaCRDT):
    """Remove-wins OR-Set: concurrent add ∥ rmv of the same element ⇒ absent.

    Store: dot → (element, is_add_token). An element is present iff it has at
    least one add token and **no** remove token.
    """

    store: DotFun = DotFun()  # dot -> (element, bool)
    ctx: CausalContext = CausalContext()

    @staticmethod
    def bottom() -> "RWORSet":
        return RWORSet()

    def elements(self) -> FrozenSet[Any]:
        tokens: Dict[Any, set] = {}
        for _, (e, is_add) in self.store.entries:
            tokens.setdefault(e, set()).add(is_add)
        return frozenset(e for e, tk in tokens.items() if tk == {True})

    def _token_delta(self, i: ReplicaId, e: Any, token: bool) -> "RWORSet":
        # Supersede all existing tokens for e (their dots go in the context),
        # then place a single fresh token.
        old = [d for d, (e2, _) in self.store.entries if e2 == e]
        d = self.ctx.next_dot(i)
        return RWORSet(DotFun.of({d: (e, token)}),
                       CausalContext.from_dots(old + [d]))

    def add_delta(self, i: ReplicaId, e: Any) -> "RWORSet":
        return self._token_delta(i, e, True)

    def rmv_delta(self, i: ReplicaId, e: Any) -> "RWORSet":
        return self._token_delta(i, e, False)

    def add_full(self, i: ReplicaId, e: Any) -> "RWORSet":
        return self.join(self.add_delta(i, e))

    def rmv_full(self, i: ReplicaId, e: Any) -> "RWORSet":
        return self.join(self.rmv_delta(i, e))

    def join(self, other: "RWORSet") -> "RWORSet":
        store, ctx = causal_join(self.store, self.ctx, other.store, other.ctx)
        return RWORSet(store, ctx)


# ---------------------------------------------------------------------------
# Optimized multi-value register (paper Fig. 4)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MVRegister(DeltaCRDT):
    """Optimized MVR: scalar dots, not per-value version vectors (Fig. 4).

    wrᵟᵢ(v, (s, c)) = ({(i, n+1, v)}, {(i, n+1)} ∪ {(j, m) | (j, m, ⊥) ∈ s})
    — the write's causal context covers every currently-visible value, so
    overwritten values are deleted at replicas that still hold them; values
    written concurrently survive as siblings.
    """

    store: DotFun = DotFun()  # dot -> value
    ctx: CausalContext = CausalContext()

    @staticmethod
    def bottom() -> "MVRegister":
        return MVRegister()

    def read(self) -> FrozenSet[Any]:
        return frozenset(self.store.values())

    def write_delta(self, i: ReplicaId, v: Any) -> "MVRegister":
        d = self.ctx.next_dot(i)
        covered = list(self.store.all_dots()) + [d]
        return MVRegister(DotFun.of({d: v}), CausalContext.from_dots(covered))

    def write_full(self, i: ReplicaId, v: Any) -> "MVRegister":
        return self.join(self.write_delta(i, v))

    def join(self, other: "MVRegister") -> "MVRegister":
        store, ctx = causal_join(self.store, self.ctx, other.store, other.ctx)
        return MVRegister(store, ctx)


# ---------------------------------------------------------------------------
# LWW register / set
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LWWRegister(DeltaCRDT):
    """Last-writer-wins register; (timestamp, replica-id) lexicographic max."""

    stamp: Tuple[int, ReplicaId] = (0, "")
    value: Any = None

    @staticmethod
    def bottom() -> "LWWRegister":
        return LWWRegister()

    def read(self) -> Any:
        return self.value

    def write_delta(self, i: ReplicaId, ts: int, v: Any) -> "LWWRegister":
        return LWWRegister((ts, i), v)

    def write_full(self, i: ReplicaId, ts: int, v: Any) -> "LWWRegister":
        return self.join(self.write_delta(i, ts, v))

    def join(self, other: "LWWRegister") -> "LWWRegister":
        return self if other.stamp <= self.stamp else other


@dataclass(frozen=True)
class LWWSet(DeltaCRDT):
    """LWW element set: per-element (stamp, present) register, max-join."""

    entries: Tuple[Tuple[Any, Tuple[Tuple[int, ReplicaId], bool]], ...] = ()

    @staticmethod
    def bottom() -> "LWWSet":
        return LWWSet()

    def elements(self) -> FrozenSet[Any]:
        return frozenset(e for e, (_, present) in self.entries if present)

    def _write(self, i: ReplicaId, ts: int, e: Any, present: bool) -> "LWWSet":
        return LWWSet(((e, ((ts, i), present)),))

    def add_delta(self, i: ReplicaId, ts: int, e: Any) -> "LWWSet":
        return self._write(i, ts, e, True)

    def rmv_delta(self, i: ReplicaId, ts: int, e: Any) -> "LWWSet":
        return self._write(i, ts, e, False)

    def add_full(self, i: ReplicaId, ts: int, e: Any) -> "LWWSet":
        return self.join(self.add_delta(i, ts, e))

    def rmv_full(self, i: ReplicaId, ts: int, e: Any) -> "LWWSet":
        return self.join(self.rmv_delta(i, ts, e))

    def join(self, other: "LWWSet") -> "LWWSet":
        m = dict(self.entries)
        for e, sv in other.entries:
            cur = m.get(e)
            m[e] = sv if cur is None or cur < sv else cur
        return LWWSet(tuple(sorted(m.items(), key=lambda kv: repr(kv[0]))))


# ---------------------------------------------------------------------------
# Flags
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EWFlag(DeltaCRDT):
    """Enable-wins flag (concurrent enable ∥ disable ⇒ enabled)."""

    store: DotSet = DotSet()
    ctx: CausalContext = CausalContext()

    @staticmethod
    def bottom() -> "EWFlag":
        return EWFlag()

    def read(self) -> bool:
        return bool(self.store.dots)

    def enable_delta(self, i: ReplicaId) -> "EWFlag":
        d = self.ctx.next_dot(i)
        # fresh dot survives; all old dots are covered (collapses siblings)
        return EWFlag(DotSet(frozenset([d])),
                      CausalContext.from_dots(list(self.store.dots) + [d]))

    def disable_delta(self, i: ReplicaId) -> "EWFlag":
        return EWFlag(DotSet(), CausalContext.from_dots(self.store.dots))

    def enable_full(self, i: ReplicaId) -> "EWFlag":
        return self.join(self.enable_delta(i))

    def disable_full(self, i: ReplicaId) -> "EWFlag":
        return self.join(self.disable_delta(i))

    def join(self, other: "EWFlag") -> "EWFlag":
        store, ctx = causal_join(self.store, self.ctx, other.store, other.ctx)
        return EWFlag(store, ctx)


@dataclass(frozen=True)
class DWFlag(DeltaCRDT):
    """Disable-wins flag: presence of a dot means *disabled*."""

    store: DotSet = DotSet()
    ctx: CausalContext = CausalContext()

    @staticmethod
    def bottom() -> "DWFlag":
        return DWFlag()

    def read(self) -> bool:
        return not self.store.dots

    def disable_delta(self, i: ReplicaId) -> "DWFlag":
        d = self.ctx.next_dot(i)
        return DWFlag(DotSet(frozenset([d])),
                      CausalContext.from_dots(list(self.store.dots) + [d]))

    def enable_delta(self, i: ReplicaId) -> "DWFlag":
        return DWFlag(DotSet(), CausalContext.from_dots(self.store.dots))

    def disable_full(self, i: ReplicaId) -> "DWFlag":
        return self.join(self.disable_delta(i))

    def enable_full(self, i: ReplicaId) -> "DWFlag":
        return self.join(self.enable_delta(i))

    def join(self, other: "DWFlag") -> "DWFlag":
        store, ctx = causal_join(self.store, self.ctx, other.store, other.ctx)
        return DWFlag(store, ctx)


# ---------------------------------------------------------------------------
# ORMap — composable map of causal CRDTs (the Riak-DT-Map shape, paper §1)
# ---------------------------------------------------------------------------

_CAUSAL_TYPES = (AWORSet, RWORSet, MVRegister, EWFlag, DWFlag)


@dataclass(frozen=True)
class ORMap(DeltaCRDT):
    """Observed-remove map: key → embedded causal δ-CRDT, shared context.

    ``apply_delta(i, key, f)`` lifts a delta-mutator of the embedded type;
    ``rmv_delta(i, key)`` deletes a key by covering all its dots (the
    embedded store becomes ⊥ at join time — observed-remove semantics).
    Values must be causal δ-CRDTs (AWORSet/RWORSet/MVRegister/flags/ORMap).
    """

    store: DotMap = DotMap()
    ctx: CausalContext = CausalContext()

    @staticmethod
    def bottom() -> "ORMap":
        return ORMap()

    def keys(self) -> FrozenSet[Any]:
        return frozenset(k for k, _ in self.store.entries)

    def get(self, key: Any, typ=None):
        """View of the embedded CRDT at ``key`` (with the shared context)."""
        sub = self.store.get(key, None)
        if sub is None:
            if typ is None:
                return None
            return typ.bottom()
        return self._wrap(sub)

    def _wrap(self, sub):
        if isinstance(sub, DotFun):
            raise TypeError("ambiguous DotFun embedding; use typed wrapper")
        return sub

    def get_value(self, key: Any, typ):
        """Typed read: returns an instance of ``typ`` sharing this map's ctx.

        Uses the store's keyed ``get`` (O(log n) on the columnar
        representation) rather than materializing ``as_dict`` — per-op
        delta mutators call this on every write."""
        sub = self.store.get(key, None)
        inner_store = sub if sub is not None else typ.bottom().store
        return typ(inner_store, self.ctx)

    def apply_delta(self, i: ReplicaId, key: Any, typ, mutator_name: str,
                    *args) -> "ORMap":
        """Run ``typ.<mutator_name>ᵟ`` on the embedded value, lift to a map delta."""
        cur = self.get_value(key, typ)
        sub_delta = getattr(cur, mutator_name)(i, *args)
        return ORMap(DotMap.of({key: sub_delta.store}), sub_delta.ctx)

    def rmv_delta(self, i: ReplicaId, key: Any) -> "ORMap":
        sub = self.store.get(key, None)
        dots = sub.all_dots() if sub is not None else frozenset()
        return ORMap(DotMap(), CausalContext.from_dots(dots))

    def apply_full(self, i: ReplicaId, key: Any, typ, mutator_name: str,
                   *args) -> "ORMap":
        """Standard (state-based) map mutator: mutate the embedded value in
        place — NOT defined via the delta join, so the decomposition law
        ``m(X) = X ⊔ mᵟ(X)`` is a real property for this type too."""
        cur = self.get_value(key, typ)
        full_name = mutator_name.replace("_delta", "_full")
        new_sub = getattr(cur, full_name)(i, *args)
        store = self.store.as_dict()
        if new_sub.store.is_bottom():
            store.pop(key, None)          # bottom payload ⇒ absent key
        else:
            store[key] = new_sub.store
        return ORMap(DotMap.of(store), self.ctx.join(new_sub.ctx))

    def rmv_full(self, i: ReplicaId, key: Any) -> "ORMap":
        return self.join(self.rmv_delta(i, key))

    def join(self, other: "ORMap") -> "ORMap":
        store, ctx = causal_join(self.store, self.ctx, other.store, other.ctx)
        return ORMap(store, ctx)


ALL_CRDT_TYPES = (GCounter, PNCounter, GSet, TwoPSet, AWORSetTombstone,
                  AWORSet, RWORSet, MVRegister, LWWRegister, LWWSet,
                  EWFlag, DWFlag, ORMap)

# Positional wire type-id registry for the dot-column store encoding
# (wire.codec _KIND_DOTSTORE bodies) and the causal digest section.
# Append-only: the index IS the on-wire type id.
CAUSAL_WIRE_TYPES = (AWORSet, RWORSet, MVRegister, EWFlag, DWFlag, ORMap)
