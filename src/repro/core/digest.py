"""Digest summaries for request/response (pull-shaped) anti-entropy.

Push-shaped shipping (the :class:`~repro.core.propagation.Replica`
delta/interval machinery) needs the *sender* to know what the receiver
lacks; when it cannot (a reconnecting replica behind the GC horizon, a
read-heavy replica that generates no deltas of its own), the engine falls
back to shipping the full state. Digest-driven sync (Enes et al.,
*Efficient Synchronization of State-based CRDTs*) closes that gap with a
pull exchange: the replica that wants data summarizes **what it holds** in
a compact digest, and the peer replies with exactly the join-irreducible
pieces the digest provably lacks.

The digest of a :class:`~repro.core.store.LatticeStore` has two parts:

* ``tensors``  — per ``(key, tensor-name)``: the dense ``[n_chunks]``
                 version column of the resident
                 :class:`~repro.core.tensor_lattice.TensorState` value.
                 Chunk versions ``(lamport, writer-rank)`` are totally
                 ordered and unique per write, so ``peer_version >
                 digest_version`` identifies exactly the rows the
                 requester lacks — no content ships for the summary.
* ``opaque``   — per key holding any non-tensor lattice (counters,
                 OR-Sets, registers, membership views, dot stores…): a
                 16-byte blake2b hash of the canonical pickled value.
                 Equal hashes ⇒ equal values ⇒ nothing ships; a
                 representation-sensitive false mismatch only costs a
                 redundant (idempotent) re-ship, never a missed update.
* ``life``     — per key with non-bottom lifecycle state: the
                 ``(epoch, expiry)`` pair (``repro.lifecycle``). Epochs
                 gate the other two sections: rows/hashes only compare
                 within one incarnation, a requester at a *higher* epoch
                 needs nothing for the key (its tombstone absorbs
                 whatever the responder still holds), and a requester at
                 a *lower* epoch gets the key wholesale — so pull-sync
                 propagates reaps and never resurrects them.

``digest_diff(store, digest)`` is the responder's half: the sub-delta of
``store`` that the digest's owner lacks. Its load-bearing property (the
reason pull-sync preserves the causal delta-merging condition) is **join
equivalence to the full state**::

    requester_X ⊔ digest_diff(responder_X, digest(requester_X))
        == requester_X ⊔ responder_X

Every row the filter removes is one the requester's version dominates
(LWW keeps the requester's row either way), and every opaque key it
removes is value-equal — so joining a digest response is indistinguishable
from joining the responder's full state, which Def. 6 always permits.
The wire layer applies the same filter directly at encode time
(``wire.codec.encode_store(known_versions=...)``) so the response frame
is built straight from resident state without materializing this
intermediate; this module is the object-mode path and the oracle.
"""

from __future__ import annotations

import dataclasses
import hashlib
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, Tuple

import numpy as np

from ..lifecycle.lattice import LIFE_BOTTOM, Life
from .store import LatticeStore, _tensorstate_cls


def _canon(x: Any) -> Any:
    """Representation-independent form of a lattice value for hashing.

    Equal values must hash equal, but several datatypes store
    ``frozenset``s (GSet, the OR-Set dot clouds, …) whose pickle bytes
    depend on insertion order and on the per-process hash seed — two
    converged replicas would hash-mismatch and re-ship the value every
    pull round forever. Canonicalization sorts every set/dict by the
    ``repr`` of its canonicalized members (``repr`` is deterministic
    across processes; mixed element types make direct ``sorted``
    unusable) and flattens dataclasses into (type-name, field, value)
    tuples so nested containers are reached."""
    if isinstance(x, (frozenset, set)):
        return ("set\x00", tuple(sorted((_canon(v) for v in x), key=repr)))
    if isinstance(x, dict):
        return ("dict\x00", tuple(sorted(
            ((_canon(k), _canon(v)) for k, v in x.items()), key=repr)))
    if isinstance(x, tuple):
        return tuple(_canon(v) for v in x)
    if isinstance(x, list):
        return ("list\x00", tuple(_canon(v) for v in x))
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return (type(x).__name__, tuple(
            (f.name, _canon(getattr(x, f.name)))
            for f in dataclasses.fields(x)))
    return x


def opaque_hash(value: Any) -> bytes:
    """16-byte content hash of a non-tensor lattice value: blake2b over
    the pickled *canonical* form (see :func:`_canon`), so equal values
    hash equal regardless of internal set/dict ordering or process."""
    return hashlib.blake2b(pickle.dumps(_canon(value), protocol=4),
                           digest_size=16).digest()


@dataclass(eq=False)
class StoreDigest:
    """Compact 'what I hold' summary of a store (see module docstring).

    ``causal`` is the per-dot section: per key holding a causal dot
    store, a :class:`~repro.core.dotcols.CausalDigest` (vv + cloud
    summary plus the flat store dot column) — enough for a responder to
    compute the *exact* missing-dot response instead of re-shipping the
    value whenever a content hash mismatches."""

    tensors: Dict[Tuple[str, str], np.ndarray] = field(default_factory=dict)
    opaque: Dict[str, bytes] = field(default_factory=dict)
    life: Dict[str, Life] = field(default_factory=dict)
    causal: Dict[str, Any] = field(default_factory=dict)

    def epoch_of(self, key: str) -> int:
        return self.life.get(key, LIFE_BOTTOM)[0]

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, StoreDigest):
            return NotImplemented
        return (self.opaque == other.opaque
                and self.life == other.life
                and self.causal == other.causal
                and set(self.tensors) == set(other.tensors)
                and all(np.array_equal(v, other.tensors[k])
                        for k, v in self.tensors.items()))

    def __repr__(self) -> str:
        return (f"StoreDigest({len(self.tensors)} tensor cols, "
                f"{len(self.opaque)} opaque keys, "
                f"{len(self.causal)} causal keys, "
                f"{len(self.life)} life keys)")


def _causal_wire_types():
    """The causal CRDT classes that digest per-dot (lazy import — crdts
    is a leaf module, but keep the import out of module load order)."""
    from .crdts import CAUSAL_WIRE_TYPES
    return CAUSAL_WIRE_TYPES


def store_digest(store: LatticeStore) -> StoreDigest:
    """Summarize ``store``: dense per-chunk version columns for tensor
    values, content hashes for everything else, plus every key's
    non-bottom lifecycle state (expiries and tombstones pull-sync like
    any other state)."""
    ts_cls = _tensorstate_cls()
    out = StoreDigest()
    # A stacked/resident cache already holds every covered tensor's dense
    # version column contiguously (and the resident cache mirrors it on
    # host — vers_host — precisely so digests never touch the device);
    # serve those as zero-copy slices and densify only uncovered tensors.
    spans, vers_col = None, None
    cache = store.__dict__.get("_resident_cache")
    if cache is not None:
        spans, vers_col = cache.spans, cache.vers_host
    else:
        sc = store.__dict__.get("_stacked_cache")
        if sc is not None and sc is not False:   # False = "not stackable"
            spans, vers_col = sc.spans, sc.vers
    for key, val in store.entries:
        if ts_cls is not None and isinstance(val, ts_cls):
            from .tensor_lattice import dense_versions
            for name, ct in val.chunks:
                span = spans.get((key, name)) if spans is not None else None
                if span is not None:
                    out.tensors[(key, name)] = vers_col[span[0]:span[1]]
                else:
                    out.tensors[(key, name)] = dense_versions(ct)
        elif isinstance(val, _causal_wire_types()):
            from . import dotcols
            g = dotcols.causal_digest_of(val)
            if g is not None:
                out.causal[key] = g
            else:                     # nested-map shape: hash like opaque
                out.opaque[key] = opaque_hash(val)
        else:
            out.opaque[key] = opaque_hash(val)
    out.life.update(store.life)
    return out


def life_diff(life, shipped_keys, known_life) -> list:
    """The life entries a digest response must carry: every entry
    strictly above the peer's (``known_life`` None ⇒ unfiltered: all of
    them), plus an ``(epoch, -inf)`` stamp for any *shipped* key at a
    past-0 epoch whose full life entry is lex-dominated — an unstamped
    value would join at epoch 0 and be absorbed by the requester's own
    lifecycle state. The single implementation behind both responders
    (object-mode :func:`digest_diff` and the wire encoder's
    ``encode_store(known_life=...)``), so the no-resurrection invariant
    cannot drift between modes. Returns sorted ``(key, Life)`` pairs."""
    out = [(k, lv) for k, lv in life
           if known_life is None or lv > known_life.get(k, LIFE_BOTTOM)]
    have = {k for k, _ in out}
    life_map = dict(life)
    for key in shipped_keys:
        epoch = life_map.get(key, LIFE_BOTTOM)[0]
        if epoch and key not in have:
            out.append((key, (epoch, LIFE_BOTTOM[1])))
    return sorted(out)


def versions_at(known: np.ndarray, idx: np.ndarray,
                vers_dtype) -> np.ndarray:
    """The digest owner's version at each chunk position in ``idx`` —
    positions beyond the digest column (the requester's tensor is
    shorter) read as ⊥, so those rows always ship."""
    known = np.asarray(known)
    at = np.zeros(idx.shape, dtype=vers_dtype)
    in_range = idx < known.size
    at[in_range] = known[idx[in_range]].astype(vers_dtype)
    return at


def _causal_diff_obj(value, g):
    """Set-based reference implementation of the per-dot digest response
    (:func:`~repro.core.dotcols.causal_diff_cols` is the columnar twin
    the wire encoder uses; property tests hold the two equal). Computes

        s_ship = {d ∈ s_resp | d ∉ c_req}
        c_ship = {d ∈ g.dots | d ∈ c_resp, d ∉ s_resp} ∪ (c_resp \\ c_req)

    directly with Python sets over the object representation. Joining
    ``(s_ship, c_ship)`` at the requester reproduces the join of the
    responder's full state exactly (DESIGN.md §9), and ``s_ship`` never
    carries a dot the requester's context contains. Returns None when
    the requester lacks nothing."""
    from . import dotcols
    from .dots import DotFun, DotMap, DotSet

    val = dotcols.value_to_obj(value)
    store, ctx = val.store, val.ctx
    gvv = {g.rids[j]: int(n) for j, n in enumerate(g.vvcol) if n}
    gcloud = dotcols._unpack(g.rids, g.cloudcol)
    gdots = dotcols._unpack(g.rids, g.dotcol)

    def req_has(d):
        return d[1] <= gvv.get(d[0], 0) or d in gcloud

    s_all = store.all_dots()
    new = {d for d in s_all if not req_has(d)}
    removed = {d for d in gdots if ctx.contains(d) and d not in s_all}
    extras = set()
    for i, n in ctx.vv:
        for k in range(gvv.get(i, 0) + 1, n + 1):
            if (i, k) not in gcloud:
                extras.add((i, k))
    for d in ctx.cloud:
        if not req_has(d):
            extras.add(d)
    cship = removed | extras
    if not new and not cship:
        return None

    def filt(s):
        if isinstance(s, DotSet):
            return DotSet(frozenset(s.dots & new))
        if isinstance(s, DotFun):
            return DotFun(tuple((d, v) for d, v in s.entries if d in new))
        return DotMap(tuple((k, f) for k, sub in s.entries
                            if not (f := filt(sub)).is_bottom()))

    from .dots import CausalContext
    return type(val)(filt(store), CausalContext.from_dots(cship))


def digest_diff(store: LatticeStore, digest: StoreDigest) -> LatticeStore:
    """The sub-delta of ``store`` that ``digest``'s owner provably lacks:
    per tensor, only the chunk rows whose version strictly exceeds the
    digest's version at that position (as sparse row sets); per opaque
    key, the whole value iff its content hash differs; keys absent from
    the digest ship wholesale. Lifecycle-aware: life entries ship iff
    strictly above the digest's (tombstones and expiry extensions
    propagate through pull), a key whose digest epoch *exceeds* the
    responder's ships nothing (the requester's tombstone absorbs it),
    and version/hash filters only apply within the same incarnation —
    an epoch-0 version column must never suppress epoch-1 rows. Always
    ≤ ``store``, and join-equivalent to it for the digest's owner
    (module docstring)."""
    ts_cls = _tensorstate_cls()
    la = dict(store.life)
    out: Dict[str, Any] = {}
    for key, val in store.entries:
        epoch = la.get(key, LIFE_BOTTOM)[0]
        q_epoch = digest.epoch_of(key)
        if q_epoch > epoch:
            continue                 # requester's incarnation dominates
        same_epoch = q_epoch == epoch
        if ts_cls is None or not isinstance(val, ts_cls):
            if isinstance(val, _causal_wire_types()):
                g = digest.causal.get(key) if same_epoch else None
                if g is None:
                    out[key] = val        # requester lacks the key: whole
                else:
                    d = _causal_diff_obj(val, g)
                    if d is not None:
                        out[key] = d      # exact missing-dot sub-delta
                continue
            h = digest.opaque.get(key) if same_epoch else None
            if h is None or h != opaque_hash(val):
                out[key] = val
            continue
        from .tensor_lattice import live_rows, sparse_chunks
        chunks: Dict[str, Any] = {}
        for name, ct in val.chunks:
            idx, vals, vers = live_rows(ct)
            known = (digest.tensors.get((key, name)) if same_epoch
                     else None)
            if known is not None and idx.size:
                keep = vers > versions_at(known, idx, vers.dtype)
                idx, vals, vers = idx[keep], vals[keep], vers[keep]
            if idx.size:
                chunks[name] = sparse_chunks(ct.shape[0], idx, vals, vers)
        if chunks:
            out[key] = ts_cls.of(chunks, lamport=val.lamport)
    return LatticeStore(tuple(sorted(out.items())),
                        tuple(life_diff(store.life, out, digest.life)))
