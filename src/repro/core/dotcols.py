"""Array-backed (columnar) dot stores and causal contexts.

The :mod:`repro.core.dots` objects are the paper-shaped small-state path
and the oracle: frozensets of ``(replica_id, counter)`` tuples walked
dot-by-dot. At a million dots every causal join re-derives a vv dict per
``contains`` call and re-sorts tuple entries — seconds of Python time
for an operation that is structurally a sorted merge. This module is the
large-state fast path, mirroring the ``SparseChunks``/``ChunkedTensor``
dual-representation precedent of the tensor side:

* A dot packs into one ``int64`` as ``(rid_index << 48) | seq`` against
  a per-object sorted replica-id string table, so sorted packed order is
  exactly lexicographic ``(replica_id, seq)`` order and every causal
  operation becomes a vectorized sorted-merge / ``searchsorted`` pass.
* :class:`CausalContextCols` carries the §7.2 compressed context as a
  dense vv column (aligned with the rid table) plus a sorted packed
  cloud column.
* :class:`DotSetCols` / :class:`DotFunCols` / :class:`DotMapCols` carry
  the store as (rid table, sorted packed dot column, value table, and —
  for maps — a key table with per-key group offsets).
* :func:`causal_join_cols` computes the Fig. 3b/4 causal join

      (s, c) ⊔ (s', c') = ((s∩s') ∪ {d∈s | d∉c'} ∪ {d∈s' | d∉c}, c∪c')

  entirely with array ops: dot membership of each side in the other via
  ``searchsorted`` over the flat sorted dot column (dots are globally
  unique 𝕀×ℕ tags, so dot identity implies key identity), containment
  in the other causal context via a vectorized vv-lookup + cloud
  ``searchsorted`` (:func:`missing_mask`, with a jitted dispatch
  mirroring ``kernels/ops.use_pallas_default`` for large columns), and
  the result assembled with one merge.

Every columnar class duck-types the ``dots.py`` API surface the causal
CRDTs in :mod:`repro.core.crdts` consume (``.dots``, ``.entries``,
``.all_dots()``, ``.values()``, ``.as_dict()``, ``is_bottom``,
``next_dot`` …), materializing tuples only at those small-state call
sites, and equality is cross-representation (``AWORSet(obj) ==
AWORSet(cols)`` holds whenever the states are equal), so engine code
never branches on representation.

The module also hosts the **per-dot digest** machinery behind
digest-sync pull for dot stores: :class:`CausalDigest` (a key's vv +
cloud summary plus its flat store dot column) and
:func:`causal_diff_cols`, which computes the provably-minimal response

    s_ship = {d ∈ s_resp | d ∉ c_req}
    c_ship = {d ∈ s_req_digest | d ∈ c_resp, d ∉ s_resp}  ∪  (c_resp \\ c_req)

whose join at the requester is *exactly* the join of the responder's
full state (the Def. 6 merging-condition argument is spelled out in
DESIGN.md §9). Nested ``DotMap``-inside-``DotMap`` stores are the one
shape the columnar form does not model; conversion returns ``None`` and
callers fall back to the object path (wire: opaque pickle).
"""

from __future__ import annotations

import functools
from bisect import bisect_left
from dataclasses import dataclass
from itertools import compress
from typing import Any, Dict, FrozenSet, Optional, Tuple

import numpy as np

from .dots import CausalContext, Dot, DotFun, DotMap, DotSet, _freeze_vv

SEQ_BITS = 48                      # seq < 2^48; rid index < 2^15 (sign clear)
SEQ_MASK = np.int64((1 << SEQ_BITS) - 1)

SHAPE_SET, SHAPE_FUN, SHAPE_MAP = 0, 1, 2

_EMPTY64 = np.empty(0, np.int64)
_EMPTY_OBJ = np.empty(0, object)

# columns at or above this row count dispatch membership filtering to the
# jitted kernel when the session's default backend is an accelerator —
# the same auto-dispatch convention as kernels/ops.use_pallas_default
_JIT_MIN_ROWS = 1 << 17


def is_columnar(x: Any) -> bool:
    return getattr(x, "columnar", False)


# ---------------------------------------------------------------------------
# Packing / rid tables
# ---------------------------------------------------------------------------

def pack_dot(rids: Tuple[str, ...], dot: Dot) -> int:
    return (rids.index(dot[0]) << SEQ_BITS) | dot[1]


def _pack_pairs(rids: Tuple[str, ...], pairs) -> np.ndarray:
    idx = {r: j for j, r in enumerate(rids)}
    pairs = list(pairs)
    return np.fromiter(((idx[i] << SEQ_BITS) | n for i, n in pairs),
                       np.int64, count=len(pairs))


def _unpack(rids: Tuple[str, ...], packed: np.ndarray) -> FrozenSet[Dot]:
    return frozenset((rids[int(d) >> SEQ_BITS], int(d & SEQ_MASK))
                     for d in packed)


def _union_rids(*tables: Tuple[str, ...]):
    """Union rid table plus one remap column per input (None = identity).

    Both inputs and the union are sorted, so every remap column is
    monotone — remapping a sorted packed column preserves its order.
    """
    base = tables[0]
    if all(t == base for t in tables[1:]):
        return base, [None] * len(tables)
    u = tuple(sorted(set().union(*tables)))
    idx = {r: j for j, r in enumerate(u)}
    maps = []
    for t in tables:
        if t == u:
            maps.append(None)
        else:
            maps.append(np.fromiter((idx[r] for r in t), np.int64,
                                    count=len(t)))
    return u, maps


def _remap(packed: np.ndarray, rmap: Optional[np.ndarray]) -> np.ndarray:
    if rmap is None or packed.size == 0:
        return packed
    return (rmap[packed >> SEQ_BITS] << SEQ_BITS) | (packed & SEQ_MASK)


def _dense_vv(n_rids: int, rmap: Optional[np.ndarray],
              vvcol: np.ndarray) -> np.ndarray:
    """Densify a vv column over a union rid table."""
    if rmap is None and vvcol.size == n_rids:
        return vvcol
    out = np.zeros(n_rids, np.int64)
    if vvcol.size:
        out[rmap if rmap is not None else np.arange(vvcol.size)] = vvcol
    return out


def _in_sorted(sorted_arr: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Boolean membership of ``queries`` in a sorted array."""
    if queries.size == 0:
        return np.zeros(0, bool)
    if sorted_arr.size == 0:
        return np.zeros(queries.size, bool)
    pos = np.searchsorted(sorted_arr, queries)
    posc = np.minimum(pos, sorted_arr.size - 1)
    return (pos < sorted_arr.size) & (sorted_arr[posc] == queries)


# ---------------------------------------------------------------------------
# Vectorized containment: the inner loop of every causal join
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jax_missing_kernel(has_cloud: bool):
    import jax
    import jax.numpy as jnp

    def kernel(vv, cloud, dots):
        rid = dots >> SEQ_BITS
        seq = dots & SEQ_MASK
        miss = seq > vv[rid]
        if has_cloud:
            pos = jnp.searchsorted(cloud, dots)
            posc = jnp.clip(pos, 0, cloud.shape[0] - 1)
            found = (pos < cloud.shape[0]) & (cloud[posc] == dots)
            miss = miss & ~found
        return miss

    return jax.jit(kernel)


def _jax_default() -> bool:
    try:
        from ..kernels import ops
        return ops.use_pallas_default()
    except Exception:  # pragma: no cover - partial installs
        return False


def missing_mask(vvcol: np.ndarray, cloudcol: np.ndarray,
                 dots: np.ndarray, backend: Optional[str] = None
                 ) -> np.ndarray:
    """``mask[i]`` ⇔ ``dots[i]`` is NOT contained in the context
    ``(vvcol, cloudcol)``. All three operands share one rid space and
    ``vvcol`` is dense over it; ``cloudcol`` is sorted.

    ``backend=None`` auto-dispatches: numpy, or the jitted kernel for
    columns of ≥ ``_JIT_MIN_ROWS`` rows when the session's default
    backend is an accelerator (``kernels.ops.use_pallas_default`` — the
    same convention the tensor kernels use). Pass ``"numpy"``/``"jax"``
    to force a path (parity tests do).
    """
    if dots.size == 0:
        return np.zeros(0, bool)
    if backend is None:
        backend = ("jax" if dots.size >= _JIT_MIN_ROWS and _jax_default()
                   else "numpy")
    if backend == "jax":
        # packed dots need all 64 bits (rid<<48 | seq); jax truncates to
        # int32 unless x64 is scoped on around both trace and call
        from jax.experimental import enable_x64
        kern = _jax_missing_kernel(bool(cloudcol.size))
        with enable_x64():
            return np.asarray(kern(vvcol, cloudcol, dots))
    rid = dots >> SEQ_BITS
    seq = dots & SEQ_MASK
    miss = seq > vvcol[rid]
    if cloudcol.size:
        miss &= ~_in_sorted(cloudcol, dots)
    return miss


def _normalize_cols(vvcol: np.ndarray, cloud: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """§7.2 compression, columnar: absorb contiguous cloud dots into the
    vv prefix and drop covered ones. ``vvcol`` is dense; ``cloud`` need
    not be sorted or unique. Returns the new (vv, sorted cloud)."""
    vv = np.array(vvcol, np.int64, copy=True)
    if cloud.size == 0:
        return vv, _EMPTY64
    cloud = np.unique(cloud)
    rid = cloud >> SEQ_BITS
    seq = cloud & SEQ_MASK
    starts = np.flatnonzero(np.r_[True, rid[1:] != rid[:-1]])
    ends = np.r_[starts[1:], np.int64(rid.size)]
    keep = np.zeros(cloud.size, bool)
    for s, e in zip(starts, ends):          # one iteration per replica
        r = int(rid[s])
        base = int(vv[r])
        seqs = seq[s:e]
        rest = seqs[seqs > base]
        if rest.size == 0:
            continue                         # all covered by the prefix
        run = (rest - np.arange(rest.size)) == base + 1
        t = int(rest.size if run.all() else run.argmin())
        if t:
            vv[r] = base + t
        kk = np.zeros(seqs.size, bool)
        kk[seqs > base] = np.arange(rest.size) >= t
        keep[s:e] = kk
    return vv, cloud[keep]


# ---------------------------------------------------------------------------
# Columnar causal context
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class CausalContextCols:
    """Compressed causal context as columns: a sorted rid table, a dense
    int64 vv column aligned with it, and a sorted packed cloud column.
    Same normalization invariant as :class:`~repro.core.dots.
    CausalContext`; equality and hashing are cross-representation."""

    rids: Tuple[str, ...]
    vvcol: np.ndarray
    cloudcol: np.ndarray

    columnar = True

    @staticmethod
    def bottom() -> "CausalContextCols":
        return _CTX_BOTTOM

    @staticmethod
    def from_obj(cc: CausalContext) -> "CausalContextCols":
        if isinstance(cc, CausalContextCols):
            return cc
        rids = tuple(sorted({i for i, _ in cc.vv}
                            | {i for i, _ in cc.cloud}))
        vvd = dict(cc.vv)
        vv = np.fromiter((vvd.get(r, 0) for r in rids), np.int64,
                         count=len(rids))
        cloud = np.sort(_pack_pairs(rids, cc.cloud))
        return CausalContextCols(rids, vv, cloud)

    def to_obj(self) -> CausalContext:
        vv = {r: int(n) for r, n in zip(self.rids, self.vvcol) if n}
        return CausalContext(vv=_freeze_vv(vv),
                             cloud=_unpack(self.rids, self.cloudcol))

    # -- dots.py-compatible surface -----------------------------------------
    @property
    def vv(self) -> Tuple[Tuple[str, int], ...]:
        return tuple((r, int(n)) for r, n in zip(self.rids, self.vvcol)
                     if n)

    @property
    def cloud(self) -> FrozenSet[Dot]:
        return _unpack(self.rids, self.cloudcol)

    def vv_dict(self) -> Dict[str, int]:
        return dict(self.vv)

    def contains(self, dot: Dot) -> bool:
        i, n = dot
        if n <= 0:
            return True
        try:
            j = self.rids.index(i)
        except ValueError:
            return False
        if n <= int(self.vvcol[j]):
            return True
        return bool(_in_sorted(self.cloudcol,
                               np.array([(j << SEQ_BITS) | n], np.int64))[0])

    def max_for(self, i: str) -> int:
        try:
            j = self.rids.index(i)
        except ValueError:
            return 0
        base = int(self.vvcol[j])
        lo = np.searchsorted(self.cloudcol, np.int64(j) << SEQ_BITS)
        hi = np.searchsorted(self.cloudcol, np.int64(j + 1) << SEQ_BITS)
        if hi > lo:
            base = max(base, int(self.cloudcol[hi - 1] & SEQ_MASK))
        return base

    def next_dot(self, i: str) -> Dot:
        return (i, self.max_for(i) + 1)

    def join(self, other) -> "CausalContextCols":
        o = CausalContextCols.from_obj(other)
        rids, (ma, mb) = _union_rids(self.rids, o.rids)
        vv = np.maximum(_dense_vv(len(rids), ma, self.vvcol),
                        _dense_vv(len(rids), mb, o.vvcol))
        cloud = np.concatenate([_remap(self.cloudcol, ma),
                                _remap(o.cloudcol, mb)])
        vv, cloud = _normalize_cols(vv, cloud)
        return CausalContextCols(rids, vv, cloud)

    def leq(self, other) -> bool:
        o = CausalContextCols.from_obj(other)
        rids, (ma, mb) = _union_rids(self.rids, o.rids)
        vv_s = _dense_vv(len(rids), ma, self.vvcol)
        vv_o = _dense_vv(len(rids), mb, o.vvcol)
        if (vv_s > vv_o).any():
            return False
        cloud_s = _remap(self.cloudcol, ma)
        return not missing_mask(vv_o, _remap(o.cloudcol, mb),
                                cloud_s).any()

    def __eq__(self, other: object) -> bool:
        if isinstance(other, CausalContextCols):
            if self.rids == other.rids:
                return (np.array_equal(self.vvcol, other.vvcol)
                        and np.array_equal(self.cloudcol, other.cloudcol))
            return self.vv == other.vv and self.cloud == other.cloud
        if isinstance(other, CausalContext):
            return self.vv == other.vv and self.cloud == other.cloud
        return NotImplemented

    def __hash__(self) -> int:
        # matches CausalContext's frozen-dataclass hash of (vv, cloud)
        return hash((self.vv, self.cloud))


_CTX_BOTTOM = CausalContextCols((), _EMPTY64, _EMPTY64)


def ctx_to_cols(ctx) -> CausalContextCols:
    return CausalContextCols.from_obj(ctx)


# ---------------------------------------------------------------------------
# Columnar dot stores
# ---------------------------------------------------------------------------

class _ColsStore:
    """Shared duck-typed surface; subclasses are frozen dataclasses."""

    columnar = True

    def flat_sorted(self) -> np.ndarray:
        """The store's dot column, globally sorted (memoized — packed
        columns are only guaranteed sorted within a key group)."""
        return self.packed                     # single-group default

    def all_dots(self) -> FrozenSet[Dot]:
        return _unpack(self.rids, self.packed)

    def is_bottom(self) -> bool:
        return self.packed.size == 0


@dataclass(frozen=True, eq=False)
class DotSetCols(_ColsStore):
    """Columnar :class:`~repro.core.dots.DotSet`: a sorted packed dot
    column against a sorted rid table."""

    rids: Tuple[str, ...]
    packed: np.ndarray

    @staticmethod
    def bottom() -> "DotSetCols":
        return _DOTSET_BOTTOM

    @staticmethod
    def from_obj(s: DotSet) -> "DotSetCols":
        rids = tuple(sorted({i for i, _ in s.dots}))
        return DotSetCols(rids, np.sort(_pack_pairs(rids, s.dots)))

    def to_obj(self) -> DotSet:
        return DotSet(self.all_dots())

    @property
    def dots(self) -> FrozenSet[Dot]:
        return self.all_dots()

    def __eq__(self, other: object) -> bool:
        if isinstance(other, DotSetCols):
            if self.rids == other.rids:
                return np.array_equal(self.packed, other.packed)
            return self.dots == other.dots
        if isinstance(other, DotSet):
            return self.dots == other.dots
        return NotImplemented

    def __hash__(self) -> int:
        return hash((self.dots,))


_DOTSET_BOTTOM = DotSetCols((), _EMPTY64)


@dataclass(frozen=True, eq=False)
class DotFunCols(_ColsStore):
    """Columnar :class:`~repro.core.dots.DotFun`: sorted packed dot
    column plus a value table aligned with it (object ndarray, so joins
    gather values with fancy indexing instead of Python loops)."""

    rids: Tuple[str, ...]
    packed: np.ndarray
    vals: np.ndarray

    @staticmethod
    def bottom() -> "DotFunCols":
        return _DOTFUN_BOTTOM

    @staticmethod
    def from_obj(s: DotFun) -> "DotFunCols":
        rids = tuple(sorted({i for (i, _), _ in s.entries}))
        # DotFun entries are sorted by (rid, seq) tuples — identical to
        # packed order against the sorted rid table
        packed = _pack_pairs(rids, (d for d, _ in s.entries))
        vals = np.empty(len(s.entries), object)
        for j, (_, v) in enumerate(s.entries):
            vals[j] = v
        return DotFunCols(rids, packed, vals)

    def to_obj(self) -> DotFun:
        return DotFun(self.entries)

    @property
    def entries(self) -> Tuple[Tuple[Dot, Any], ...]:
        rids = self.rids
        return tuple(((rids[int(d) >> SEQ_BITS], int(d & SEQ_MASK)), v)
                     for d, v in zip(self.packed, self.vals))

    def as_dict(self) -> Dict[Dot, Any]:
        return dict(self.entries)

    def values(self) -> Tuple[Any, ...]:
        return tuple(self.vals)

    def __eq__(self, other: object) -> bool:
        if isinstance(other, DotFunCols):
            if self.packed.size != other.packed.size:
                return False
            if self.rids == other.rids:
                return (np.array_equal(self.packed, other.packed)
                        and bool(np.array_equal(self.vals, other.vals)))
            return self.entries == other.entries
        if isinstance(other, DotFun):
            return self.entries == other.entries
        return NotImplemented

    def __hash__(self) -> int:
        return hash((self.entries,))


_DOTFUN_BOTTOM = DotFunCols((), _EMPTY64, _EMPTY_OBJ)


@dataclass(frozen=True, eq=False)
class DotMapCols(_ColsStore):
    """Columnar :class:`~repro.core.dots.DotMap`: a key table sorted by
    ``repr`` (the ``DotMap.of`` order) with per-key group offsets into
    one packed dot column (sorted within each group) and one aligned
    value table. ``shapes[k]`` says whether group ``k`` is a DotSet or a
    DotFun; nested DotMap values are not modeled (conversion returns
    None and callers stay on the object path)."""

    rids: Tuple[str, ...]
    map_keys: Tuple[Any, ...]
    shapes: bytes                      # SHAPE_SET / SHAPE_FUN per key
    offsets: np.ndarray                # int64 [len(map_keys) + 1]
    packed: np.ndarray
    vals: np.ndarray                   # aligned; None under SET groups

    @staticmethod
    def bottom() -> "DotMapCols":
        return _DOTMAP_BOTTOM

    @staticmethod
    def from_obj(s: DotMap) -> Optional["DotMapCols"]:
        rid_set: set = set()
        for _, sub in s.entries:
            if isinstance(sub, DotMap):
                return None            # nested maps: object path only
            for i, _ in sub.all_dots():
                rid_set.add(i)
        rids = tuple(sorted(rid_set))
        keys, shapes, offs, cols, vals = [], bytearray(), [0], [], []
        for k, sub in s.entries:
            keys.append(k)
            if isinstance(sub, DotSet):
                shapes.append(SHAPE_SET)
                col = np.sort(_pack_pairs(rids, sub.dots))
                vals.extend([None] * col.size)
            else:
                shapes.append(SHAPE_FUN)
                col = _pack_pairs(rids, (d for d, _ in sub.entries))
                vals.extend(v for _, v in sub.entries)
            cols.append(col)
            offs.append(offs[-1] + col.size)
        packed = (np.concatenate(cols) if cols else _EMPTY64)
        va = np.empty(len(vals), object)
        for j, v in enumerate(vals):
            va[j] = v
        return DotMapCols(rids, tuple(keys), bytes(shapes),
                          np.asarray(offs, np.int64), packed, va)

    def to_obj(self) -> DotMap:
        return DotMap(tuple((k, sub.to_obj()) for k, sub in self.entries))

    def flat_sorted(self) -> np.ndarray:
        cached = self.__dict__.get("_flat")
        if cached is None:
            cached = np.sort(self.packed)
            object.__setattr__(self, "_flat", cached)
        return cached

    def _sub(self, i: int):
        s, e = int(self.offsets[i]), int(self.offsets[i + 1])
        if self.shapes[i] == SHAPE_SET:
            return DotSetCols(self.rids, self.packed[s:e])
        return DotFunCols(self.rids, self.packed[s:e], self.vals[s:e])

    def _key_reprs(self):
        cached = self.__dict__.get("_reprs")
        if cached is None:
            cached = [repr(k) for k in self.map_keys]
            object.__setattr__(self, "_reprs", cached)
        return cached

    def get(self, key: Any, default: Any) -> Any:
        """O(log n) lookup by the repr-sorted key table (the object
        DotMap's ``get`` materializes the whole dict)."""
        reprs = self._key_reprs()
        r = repr(key)
        i = bisect_left(reprs, r)
        while i < len(reprs) and reprs[i] == r:
            if self.map_keys[i] == key:
                return self._sub(i)
            i += 1
        return default

    @property
    def entries(self) -> Tuple[Tuple[Any, Any], ...]:
        return tuple((k, self._sub(i))
                     for i, k in enumerate(self.map_keys))

    def as_dict(self) -> Dict[Any, Any]:
        return {k: self._sub(i) for i, k in enumerate(self.map_keys)}

    def is_bottom(self) -> bool:
        return len(self.map_keys) == 0

    def __eq__(self, other: object) -> bool:
        if isinstance(other, DotMapCols):
            if self.map_keys != other.map_keys or self.shapes != other.shapes:
                return False
            if not np.array_equal(self.offsets, other.offsets):
                return False
            if self.rids == other.rids:
                return (np.array_equal(self.packed, other.packed)
                        and bool(np.array_equal(self.vals, other.vals)))
            return self.entries == other.entries
        if isinstance(other, DotMap):
            if len(self.map_keys) != len(other.entries):
                return False
            return self.entries == other.entries
        return NotImplemented

    def __hash__(self) -> int:
        return hash((self.entries,))


_DOTMAP_BOTTOM = DotMapCols((), (), b"", np.zeros(1, np.int64),
                            _EMPTY64, _EMPTY_OBJ)


def store_to_cols(store) -> Optional[Any]:
    """Columnar form of a dot store (identity if already columnar);
    None for shapes the columnar form does not model (nested maps)."""
    if is_columnar(store):
        return store
    if isinstance(store, DotSet):
        return DotSetCols.from_obj(store)
    if isinstance(store, DotFun):
        return DotFunCols.from_obj(store)
    if isinstance(store, DotMap):
        return DotMapCols.from_obj(store)
    return None


def value_to_cols(value):
    """Same causal CRDT with columnar store + context, or None if the
    store shape is not columnar-representable."""
    store = store_to_cols(value.store)
    if store is None:
        return None
    if is_columnar(value.store) and is_columnar(value.ctx):
        return value
    return type(value)(store, ctx_to_cols(value.ctx))


def value_to_obj(value):
    """Same causal CRDT on the dots.py object representation."""
    store = value.store.to_obj() if is_columnar(value.store) else value.store
    ctx = value.ctx.to_obj() if is_columnar(value.ctx) else value.ctx
    if store is value.store and ctx is value.ctx:
        return value
    return type(value)(store, ctx)


# ---------------------------------------------------------------------------
# The columnar causal join
# ---------------------------------------------------------------------------

def _merge_disjoint(a: np.ndarray, b: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Merge two sorted disjoint columns; returns (merged, pos_a, pos_b)
    with the output positions of each input element."""
    if a.size == 0:
        return b, _EMPTY64, np.arange(b.size, dtype=np.int64)
    if b.size == 0:
        return a, np.arange(a.size, dtype=np.int64), _EMPTY64
    pos_a = np.searchsorted(b, a) + np.arange(a.size)
    pos_b = np.searchsorted(a, b) + np.arange(b.size)
    out = np.empty(a.size + b.size, np.int64)
    out[pos_a] = a
    out[pos_b] = b
    return out, pos_a, pos_b


def _group_counts(mask: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    """Per-group surviving-row counts of a keep mask."""
    cs = np.concatenate([np.zeros(1, np.int64),
                         np.cumsum(mask, dtype=np.int64)])
    return cs[offsets[1:]] - cs[offsets[:-1]]


def _union_keys(a: DotMapCols, b: DotMapCols):
    """Union key table (repr-sorted) plus per-side position columns."""
    if a.map_keys == b.map_keys:
        ar = np.arange(len(a.map_keys), dtype=np.int64)
        return a.map_keys, ar, ar
    da = {k: i for i, k in enumerate(a.map_keys)}
    db = {k: i for i, k in enumerate(b.map_keys)}
    if all(k in da for k in b.map_keys):
        return (a.map_keys, np.arange(len(a.map_keys), dtype=np.int64),
                np.fromiter((da[k] for k in b.map_keys), np.int64,
                            count=len(b.map_keys)))
    if all(k in db for k in a.map_keys):
        return (b.map_keys,
                np.fromiter((db[k] for k in a.map_keys), np.int64,
                            count=len(a.map_keys)),
                np.arange(len(b.map_keys), dtype=np.int64))
    u = tuple(sorted(set(a.map_keys) | set(b.map_keys), key=repr))
    du = {k: i for i, k in enumerate(u)}
    return (u,
            np.fromiter((du[k] for k in a.map_keys), np.int64,
                        count=len(a.map_keys)),
            np.fromiter((du[k] for k in b.map_keys), np.int64,
                        count=len(b.map_keys)))


def causal_join_cols(store_a, ctx_a, store_b, ctx_b):
    """Vectorized Fig. 3b/4 causal join; returns (store, ctx), both
    columnar. Either side may be on the object representation (it is
    converted); if either store shape is not columnar-representable the
    whole join falls back to the object path."""
    A = store_to_cols(store_a)
    B = store_to_cols(store_b)
    if A is None or B is None:
        sa = store_a.to_obj() if is_columnar(store_a) else store_a
        sb = store_b.to_obj() if is_columnar(store_b) else store_b
        ca = ctx_a.to_obj() if is_columnar(ctx_a) else ctx_a
        cb = ctx_b.to_obj() if is_columnar(ctx_b) else ctx_b
        return sa.causal_join(ca, sb, cb), ca.join(cb)
    if type(A) is not type(B):
        raise TypeError(f"cannot causal-join {type(A).__name__} "
                        f"with {type(B).__name__}")
    ca = ctx_to_cols(ctx_a)
    cb = ctx_to_cols(ctx_b)

    rids, (ma, mb, mca, mcb) = _union_rids(A.rids, B.rids, ca.rids, cb.rids)
    pa = _remap(A.packed, ma)
    pb = _remap(B.packed, mb)
    vv_a = _dense_vv(len(rids), mca, ca.vvcol)
    vv_b = _dense_vv(len(rids), mcb, cb.vvcol)
    cloud_a = _remap(ca.cloudcol, mca)
    cloud_b = _remap(cb.cloudcol, mcb)

    # membership of each side's dots in the other store — dots are
    # globally unique 𝕀×ℕ tags, so dot identity implies key identity
    in_b = _in_sorted(_remap(B.flat_sorted(), mb), pa)
    in_a = _in_sorted(_remap(A.flat_sorted(), ma), pb)
    keep_a = in_b | missing_mask(vv_b, cloud_b, pa)
    keep_b = (~in_a) & missing_mask(vv_a, cloud_a, pb)

    vv_j = np.maximum(vv_a, vv_b)
    vv_j, cloud_j = _normalize_cols(vv_j,
                                    np.concatenate([cloud_a, cloud_b]))
    ctx = CausalContextCols(rids, vv_j, cloud_j)

    if isinstance(A, DotSetCols):
        merged, _, _ = _merge_disjoint(pa[keep_a], pb[keep_b])
        return DotSetCols(rids, merged), ctx

    if isinstance(A, DotFunCols):
        ka, kb = pa[keep_a], pb[keep_b]
        merged, pos_a, pos_b = _merge_disjoint(ka, kb)
        vals = np.empty(merged.size, object)
        vals[pos_a] = A.vals[keep_a]
        vals[pos_b] = B.vals[keep_b]
        return DotFunCols(rids, merged, vals), ctx

    # DotMap: align key tables, order survivors by (key, dot), rebuild
    # group offsets; keys whose group empties disappear (observed-remove)
    ku, pos_ak, pos_bk = _union_keys(A, B)
    key_a = np.repeat(pos_ak, np.diff(A.offsets))
    key_b = np.repeat(pos_bk, np.diff(B.offsets))
    kd = np.concatenate([pa[keep_a], pb[keep_b]])
    kk = np.concatenate([key_a[keep_a], key_b[keep_b]])
    kv = np.concatenate([A.vals[keep_a], B.vals[keep_b]])
    order = np.lexsort((kd, kk))
    kd, kv = kd[order], kv[order]
    counts = np.bincount(kk, minlength=len(ku))

    sh = np.full(len(ku), 255, np.uint8)
    sh[pos_ak] = np.frombuffer(A.shapes, np.uint8)
    shb = np.frombuffer(B.shapes, np.uint8)
    clash = (sh[pos_bk] != 255) & (sh[pos_bk] != shb)
    if clash.any():
        k = ku[int(pos_bk[int(np.flatnonzero(clash)[0])])]
        raise TypeError(f"mismatched dot-store shapes under map key {k!r}")
    sh[pos_bk] = shb

    present = counts > 0
    offsets = np.concatenate([np.zeros(1, np.int64),
                              np.cumsum(counts[present])])
    keys_out = (ku if present.all()
                else tuple(compress(ku, present.tolist())))
    return DotMapCols(rids, keys_out, sh[present].tobytes(),
                      offsets, kd, kv), ctx


# ---------------------------------------------------------------------------
# Per-dot digests (the causal section of StoreDigest)
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class CausalDigest:
    """One causal key's digest entry: the requester's compressed context
    (vv + cloud) **plus its flat store dot column** — the per-dot part.
    The context alone lets the responder compute the missing dots
    (``s_ship``); the store column is what makes the *removal* half of
    the response exact (``c_ship``'s first term) instead of shipping the
    responder's whole context. Columns are in the packed int64 encoding
    against ``rids``; the dot column is sorted."""

    rids: Tuple[str, ...]
    vvcol: np.ndarray
    cloudcol: np.ndarray
    dotcol: np.ndarray

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, CausalDigest):
            return NotImplemented
        if self.rids == other.rids:
            return (np.array_equal(self.vvcol, other.vvcol)
                    and np.array_equal(self.cloudcol, other.cloudcol)
                    and np.array_equal(self.dotcol, other.dotcol))
        return (dict(zip(self.rids, map(int, self.vvcol)))
                == dict(zip(other.rids, map(int, other.vvcol)))
                and _unpack(self.rids, self.cloudcol)
                == _unpack(other.rids, other.cloudcol)
                and _unpack(self.rids, self.dotcol)
                == _unpack(other.rids, other.dotcol))

    def __repr__(self) -> str:
        return (f"CausalDigest({len(self.rids)} rids, "
                f"{self.cloudcol.size} cloud, {self.dotcol.size} dots)")


def causal_digest_of(value) -> Optional[CausalDigest]:
    """The :class:`CausalDigest` of a causal CRDT value (any
    representation); None if the store shape is not columnar."""
    cv = value_to_cols(value)
    if cv is None:
        return None
    S, C = cv.store, cv.ctx
    rids, (ms, mc) = _union_rids(S.rids, C.rids)
    return CausalDigest(rids, _dense_vv(len(rids), mc, C.vvcol),
                        _remap(C.cloudcol, mc),
                        _remap(S.flat_sorted(), ms))


def _filter_store(S, ms, rids, mask):
    """The sub-store of ``S`` (remapped onto ``rids``) at a keep mask."""
    p = _remap(S.packed, ms)
    if isinstance(S, DotSetCols):
        return DotSetCols(rids, p[mask])
    if isinstance(S, DotFunCols):
        return DotFunCols(rids, p[mask], S.vals[mask])
    counts = _group_counts(mask, S.offsets)
    present = counts > 0
    offsets = np.concatenate([np.zeros(1, np.int64),
                              np.cumsum(counts[present])])
    keys = tuple(compress(S.map_keys, present.tolist()))
    shapes = np.frombuffer(S.shapes, np.uint8)[present].tobytes()
    return DotMapCols(rids, keys, shapes, offsets, p[mask], S.vals[mask])


def causal_diff_cols(value, g: CausalDigest):
    """The provably-minimal digest response for one causal key: the
    value ``(s_ship, c_ship)`` with

        s_ship = {d ∈ s_resp | d ∉ c_req}          (with its values)
        c_ship = {d ∈ digest.dots | d ∈ c_resp, d ∉ s_resp}
                 ∪ (c_resp \\ c_req)

    Joining it at the requester equals joining the responder's full
    state (DESIGN.md §9 gives the three-term argument), and by
    construction ``s_ship`` never contains a dot the requester's context
    already holds. Returns None when the requester lacks nothing — the
    caller elides the key so converged meshes trade only digests."""
    cv = value_to_cols(value)
    if cv is None:
        raise TypeError("causal_diff_cols: store shape is not columnar")
    S, C = cv.store, cv.ctx
    rids, (ms, mc, mg) = _union_rids(S.rids, C.rids, g.rids)
    vv_c = _dense_vv(len(rids), mc, C.vvcol)
    cloud_c = _remap(C.cloudcol, mc)
    vv_g = _dense_vv(len(rids), mg, g.vvcol)
    cloud_g = _remap(g.cloudcol, mg)
    gdots = _remap(g.dotcol, mg)
    flat_s = _remap(S.flat_sorted(), ms)

    # dots we hold that the requester's context lacks (ship with values)
    miss = missing_mask(vv_g, cloud_g, _remap(S.packed, ms))
    # digest dots we have observed but no longer hold (observed-removes)
    seen = ~missing_mask(vv_c, cloud_c, gdots)
    removed = gdots[seen & ~_in_sorted(flat_s, gdots)]
    # context the requester lacks: per-rid prefix ranges + cloud extras
    extras = [removed]
    for j in range(len(rids)):
        lo, hi = int(vv_g[j]), int(vv_c[j])
        if hi > lo:
            rng = ((np.int64(j) << SEQ_BITS)
                   | np.arange(lo + 1, hi + 1, dtype=np.int64))
            extras.append(rng[~_in_sorted(cloud_g, rng)])
    extras.append(cloud_c[missing_mask(vv_g, cloud_g, cloud_c)])
    cship = np.concatenate(extras)
    if not miss.any() and cship.size == 0:
        return None
    vvn, cloudn = _normalize_cols(np.zeros(len(rids), np.int64), cship)
    return type(value)(_filter_store(S, ms, rids, miss),
                       CausalContextCols(rids, vvn, cloudn))
