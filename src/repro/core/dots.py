"""Dots, causal contexts, and dot stores — the meta-data substrate of δ-CRDTs.

This module implements:

* ``Dot`` — a globally-unique event tag ``(replica_id, counter)`` from 𝕀 × ℕ
  (paper §7.1: "Globally unique tags of the form 𝕀 × ℕ").
* ``CausalContext`` — the set ``c`` of Fig. 3b/4, with the compression of
  §7.2 ("Causal Context Compression"): a version vector encoding the
  contiguous prefix of tags per replica, plus a *dot cloud* for the
  non-contiguous tags that appear under non-causal anti-entropy. As
  anti-entropy proceeds each cloud dot is eventually absorbed into the
  vector, so the cloud remains small.
* Dot stores (``DotSet``, ``DotFun``, ``DotMap``) and the *causal join*,
  the generic form of the join in Fig. 3b/4:

      (s, c) ⊔ (s', c') = ((s ∩ s') ∪ {d ∈ s | d ∉ c'} ∪ {d ∈ s' | d ∉ c},
                           c ∪ c')

  i.e. keep events seen by both, or seen by one and *not yet observed*
  (not in the causal context) by the other. Observed-but-absent ⇒ deleted.

These structures are plain immutable Python values so that the lattice laws
(commutativity / associativity / idempotence) can be property-tested
directly with hypothesis, and so that simulator state snapshots are cheap.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, FrozenSet, Iterable, Mapping, Optional, Tuple

ReplicaId = str
Dot = Tuple[ReplicaId, int]  # (replica id, 1-based counter)

# guard for CausalContext.dots(): materializing is O(total events), so
# it is reserved for tests/debug on small contexts (see its docstring)
_DOTS_MATERIALIZE_LIMIT = 1 << 16


def _freeze_vv(vv: Mapping[ReplicaId, int]) -> Tuple[Tuple[ReplicaId, int], ...]:
    return tuple(sorted((i, n) for i, n in vv.items() if n > 0))


@dataclass(frozen=True)
class CausalContext:
    """Compressed causal context: version-vector prefix + sparse dot cloud.

    Invariant (enforced by ``_normalize``): for every replica ``i`` the dots
    ``(i, 1) .. (i, vv[i])`` are contained, and the cloud holds only dots
    ``(i, k)`` with ``k > vv[i] + 1`` or gaps above the prefix (never dots
    already covered by the prefix, and never the dot that would extend it).
    """

    vv: Tuple[Tuple[ReplicaId, int], ...] = ()
    cloud: FrozenSet[Dot] = frozenset()

    # -- constructors -------------------------------------------------------
    @staticmethod
    def bottom() -> "CausalContext":
        return _CC_BOTTOM

    @staticmethod
    def from_dots(dots: Iterable[Dot]) -> "CausalContext":
        return CausalContext().add_dots(dots)

    @staticmethod
    def from_vv(vv: Mapping[ReplicaId, int]) -> "CausalContext":
        return CausalContext(vv=_freeze_vv(vv))

    # -- queries -------------------------------------------------------------
    def vv_dict(self) -> Dict[ReplicaId, int]:
        return dict(self.vv)

    def contains(self, dot: Dot) -> bool:
        i, n = dot
        if n <= 0:
            return True
        if n <= dict(self.vv).get(i, 0):
            return True
        return dot in self.cloud

    def max_for(self, i: ReplicaId) -> int:
        """max{k | (i,k) ∈ c}, 0 if none (paper: max(∅) = 0)."""
        base = dict(self.vv).get(i, 0)
        cloud_max = max((k for (j, k) in self.cloud if j == i), default=0)
        return max(base, cloud_max)

    def next_dot(self, i: ReplicaId) -> Dot:
        """The next unique tag for replica ``i`` (Fig. 3b: n+1 with
        n = max{k | (i,k) ∈ c})."""
        return (i, self.max_for(i) + 1)

    def dots(self) -> FrozenSet[Dot]:
        """Explicit dot set — **test/debug only**. Materializing every
        covered dot is O(total events) and is exactly what the §7.2
        compression exists to avoid; no engine path may call this
        (audited: only tests do). Bulk consumers should iterate ``vv``
        and ``cloud``, or use :mod:`repro.core.dotcols` columns."""
        total = sum(n for _, n in self.vv) + len(self.cloud)
        assert total <= _DOTS_MATERIALIZE_LIMIT, (
            f"CausalContext.dots() would materialize {total} dots "
            f"(> {_DOTS_MATERIALIZE_LIMIT}); it is a test/debug helper — "
            "iterate vv/cloud or use repro.core.dotcols for bulk work")
        out = set(self.cloud)
        for i, n in self.vv:
            out.update((i, k) for k in range(1, n + 1))
        return frozenset(out)

    # -- mutation (functional) ------------------------------------------------
    def add_dot(self, dot: Dot) -> "CausalContext":
        return self.add_dots((dot,))

    def add_dots(self, dots: Iterable[Dot]) -> "CausalContext":
        ds = dots if isinstance(dots, (tuple, list)) else tuple(dots)
        if not ds:
            return self
        # Contiguous-append fast path: per-op δ-mutators add exactly the
        # next dot per replica, so the common case extends vv prefixes
        # in place — no dict+set copy of the cloud and no per-replica
        # re-sort in _normalize. Only safe when the cloud holds nothing
        # for the touched replicas (an extension could absorb it).
        touched = {i for i, _ in ds}
        if not any(i in touched for i, _ in self.cloud):
            vv = dict(self.vv)
            for i, n in ds:
                cur = vv.get(i, 0)
                if n == cur + 1:
                    vv[i] = n
                elif n > cur:
                    break              # gap above the prefix: cloud path
            else:
                return CausalContext(vv=_freeze_vv(vv), cloud=self.cloud)
        vv = dict(self.vv)
        cloud = set(self.cloud)
        for d in ds:
            i, n = d
            if n > vv.get(i, 0):
                cloud.add(d)
        return _normalize(vv, cloud)

    def join(self, other: "CausalContext") -> "CausalContext":
        """c ∪ c' (then re-compressed)."""
        vv = dict(self.vv)
        for i, n in other.vv:
            vv[i] = max(vv.get(i, 0), n)
        cloud = set(self.cloud) | set(other.cloud)
        return _normalize(vv, cloud)

    def leq(self, other: "CausalContext") -> bool:
        """Direct dominance check, equivalent to the lattice definition
        ``other.join(self) == other`` but without allocating and
        re-normalizing a joined context per comparison. Relies on the
        normalization invariant: ``other``'s cloud never holds the dot
        that would extend a vv prefix, so a prefix of ``self`` that
        exceeds ``other``'s vv cannot be covered by ``other``'s cloud."""
        ovv = dict(other.vv)
        if any(n > ovv.get(i, 0) for i, n in self.vv):
            return False
        oc = other.cloud
        return all(k <= ovv.get(i, 0) or (i, k) in oc
                   for i, k in self.cloud)

    def __le__(self, other: "CausalContext") -> bool:  # pragma: no cover
        return self.leq(other)


def _normalize(vv: Dict[ReplicaId, int], cloud: set) -> CausalContext:
    """Absorb contiguous cloud dots into the version-vector prefix (§7.2)."""
    by_rep: Dict[ReplicaId, list] = {}
    for (i, n) in cloud:
        by_rep.setdefault(i, []).append(n)
    out_cloud = set()
    for i, ks in by_rep.items():
        base = vv.get(i, 0)
        for k in sorted(set(ks)):
            if k <= base:
                continue  # already covered
            if k == base + 1:
                base = k  # extend the contiguous prefix
            else:
                out_cloud.add((i, k))
        if base > 0:
            vv[i] = base
    return CausalContext(vv=_freeze_vv(vv), cloud=frozenset(out_cloud))


_CC_BOTTOM = CausalContext()


# ---------------------------------------------------------------------------
# Dot stores
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DotSet:
    """A set of dots (the store behind flags and the tag component of sets)."""

    dots: FrozenSet[Dot] = frozenset()

    @staticmethod
    def bottom() -> "DotSet":
        return DotSet()

    def is_bottom(self) -> bool:
        return not self.dots

    def all_dots(self) -> FrozenSet[Dot]:
        return self.dots

    def causal_join(self, c: CausalContext, other: "DotSet",
                    c_other: CausalContext) -> "DotSet":
        keep = (self.dots & other.dots)
        keep |= {d for d in self.dots if not c_other.contains(d)}
        keep |= {d for d in other.dots if not c.contains(d)}
        return DotSet(frozenset(keep))


@dataclass(frozen=True)
class DotFun:
    """A map dot → value (MVRegister payloads, tagged set elements)."""

    entries: Tuple[Tuple[Dot, Any], ...] = ()

    @staticmethod
    def bottom() -> "DotFun":
        return DotFun()

    @staticmethod
    def of(mapping: Mapping[Dot, Any]) -> "DotFun":
        return DotFun(tuple(sorted(mapping.items())))

    def as_dict(self) -> Dict[Dot, Any]:
        return dict(self.entries)

    def is_bottom(self) -> bool:
        return not self.entries

    def all_dots(self) -> FrozenSet[Dot]:
        return frozenset(d for d, _ in self.entries)

    def values(self) -> Tuple[Any, ...]:
        return tuple(v for _, v in self.entries)

    def causal_join(self, c: CausalContext, other: "DotFun",
                    c_other: CausalContext) -> "DotFun":
        a, b = self.as_dict(), other.as_dict()
        out: Dict[Dot, Any] = {}
        for d, v in a.items():
            if d in b or not c_other.contains(d):
                out[d] = v
        for d, v in b.items():
            if d not in a and not c.contains(d):
                out[d] = v
        return DotFun.of(out)


@dataclass(frozen=True)
class DotMap:
    """A map key → dot store (recursively composable — the Riak-Map shape).

    The causal join is applied pointwise with the *shared* causal contexts;
    keys whose joined sub-store is ⊥ disappear (observed-remove semantics).
    """

    entries: Tuple[Tuple[Any, Any], ...] = ()  # key -> DotSet|DotFun|DotMap

    @staticmethod
    def bottom() -> "DotMap":
        return DotMap()

    @staticmethod
    def of(mapping: Mapping[Any, Any]) -> "DotMap":
        return DotMap(tuple(sorted(mapping.items(), key=lambda kv: repr(kv[0]))))

    def as_dict(self) -> Dict[Any, Any]:
        return dict(self.entries)

    def is_bottom(self) -> bool:
        return not self.entries

    def all_dots(self) -> FrozenSet[Dot]:
        out: set = set()
        for _, store in self.entries:
            out |= store.all_dots()
        return frozenset(out)

    def get(self, key: Any, default: Any) -> Any:
        return self.as_dict().get(key, default)

    def causal_join(self, c: CausalContext, other: "DotMap",
                    c_other: CausalContext) -> "DotMap":
        a, b = self.as_dict(), other.as_dict()
        out: Dict[Any, Any] = {}
        for k in set(a) | set(b):
            sa = a.get(k)
            sb = b.get(k)
            if sa is None:
                sa = type(sb).bottom()
            if sb is None:
                sb = type(sa).bottom()
            joined = sa.causal_join(c, sb, c_other)
            if not joined.is_bottom():
                out[k] = joined
        return DotMap.of(out)


def causal_join(store_a, ctx_a: CausalContext, store_b, ctx_b: CausalContext):
    """Join two causal states ((store, ctx) pairs); returns (store, ctx).

    Dispatch point for the dual representation: when either side is
    columnar (:mod:`repro.core.dotcols`), the join runs vectorized and
    the result stays columnar; pure-object joins keep the paper-shaped
    path below, which doubles as the oracle the columnar path is
    property-tested against.
    """
    if (getattr(store_a, "columnar", False)
            or getattr(store_b, "columnar", False)):
        from . import dotcols
        return dotcols.causal_join_cols(store_a, ctx_a, store_b, ctx_b)
    return store_a.causal_join(ctx_a, store_b, ctx_b), ctx_a.join(ctx_b)
