"""Hierarchical gossip: intra-zone push, relay-batched cross-zone pull.

On a flat mesh every replica pushes delta-intervals to every neighbor,
so a Z-zone cluster pays O(members²) cross-zone traffic — the regime
where delta sync stops beating state sync on the WAN bill. The
:class:`HierarchicalGossip` shipping policy restructures the same
anti-entropy engine around the topology:

* **push gossip stays intra-zone** — a replica's broadcast targets only
  its zone-mates (fast, cheap links);
* **one elected relay per zone** (:func:`repro.topology.relay_for`: the
  HRW-highest live member, so election is a pure function of the
  membership view and failover is automatic when the relay leaves the
  live set) additionally targets the *other zones' relays*;
* **the cross-zone channel is digest-sync only** — a relay ships the
  remote relay a compact :class:`~repro.core.digest.StoreDigest` and
  gets back exactly the rows it lacks; raw delta fanout never crosses a
  zone boundary. Both relays digest each other, so rows flow both ways,
  and each relay re-buffers what it pulls (``_receive_digest_response``
  records the response) so the next intra-zone push round spreads it to
  zone-mates.

Correctness is the paper's Def. 6 (causal delta-merging condition):
a digest response is join-equivalent to the responder's full state for
the requester, and joining a full state is always permitted — so
routing all cross-zone repair through relayed, aggregated digest
exchanges is just another join-equivalent delivery order, and every
replica still converges to the join of all operations (see DESIGN.md
§6 and §11). What changes is only *where* bytes travel: O(zones²)
digest pairs cross the WAN instead of O(members²) delta streams.

Composes with the existing policies: ``bp+rr`` sharpen the intra-zone
pushes, ``ShardByKey`` restricts both push and pull traffic to owned
keys, and an extra ``DigestExchange(every=k)`` adds periodic intra-zone
pull repair. The policy never forces basic mode and works with or
without the wire codec.
"""

from __future__ import annotations

from typing import List, Optional

from ..topology import Topology
from .propagation import Compose, ShippingPolicy, make_policy


class HierarchicalGossip(ShippingPolicy):
    """Zone-aware target selection + cross-zone digest routing.

    ``inter_every=k`` throttles the relay's cross-zone exchanges to
    every k-th round (k=1: every round). Throttling happens in
    :meth:`targets` — on an off round the cross-zone relays are simply
    not addressed — so a cross-zone destination, whenever it *is*
    addressed, always gets a digest request (:meth:`pull_round` is true
    for any cross-zone link), never raw fanout.
    """

    pull_exchange = True
    pure_pull = False

    def __init__(self, topology: Topology, *, inter_every: int = 1):
        if not isinstance(inter_every, int) or inter_every <= 0:
            raise ValueError(f"inter_every must be a positive int, "
                             f"got {inter_every!r}")
        self.topology = topology
        self.inter_every = inter_every
        self.name = "hier" if inter_every == 1 else f"hier:{inter_every}"

    # -- helpers ---------------------------------------------------------------
    def _members(self, replica, neighbors: List[str]) -> List[str]:
        """The live membership view this replica acts on: itself plus
        its current neighbor list (elastic membership keeps that list
        pruned to live workers, which is what makes relay election
        self-healing)."""
        return sorted({replica.id, *neighbors})

    def intra_peers(self, replica, neighbors: List[str]) -> List[str]:
        me = self.topology.zone(replica.id)
        return [j for j in neighbors if self.topology.zone(j) == me]

    def relay_targets(self, replica, neighbors: List[str]) -> List[str]:
        """Other zones' relays — addressed only when this replica is its
        own zone's relay. A zone with no live member has no relay and is
        skipped (its keys are repaired when it comes back)."""
        members = self._members(replica, neighbors)
        me = self.topology.zone(replica.id)
        if self.topology.relay(me, members) != replica.id:
            return []
        out = []
        for zone in self.topology.zone_names(members):
            if zone == me:
                continue
            r = self.topology.relay(zone, members)
            if r is not None and r in neighbors:
                out.append(r)
        return out

    # -- policy hooks ------------------------------------------------------------
    def targets(self, replica, neighbors: List[str]) -> List[str]:
        out = self.intra_peers(replica, neighbors)
        if self.inter_every == 1 or replica.rounds % self.inter_every == 0:
            out += self.relay_targets(replica, neighbors)
        return out

    def pull_round(self, replica, dst: Optional[str] = None) -> bool:
        """Any cross-zone destination is a digest exchange; intra-zone
        destinations stay push (``dst=None`` — a destination-free probe,
        e.g. ``BasicNode.choose`` previews — reads as local)."""
        if dst is None:
            return False
        return self.topology.zone(dst) != self.topology.zone(replica.id)

    def ack_peers(self, replica, neighbors: List[str]) -> List[str]:
        """Only zone-mates gate buffer GC: cross-zone relays are reached
        by digest pull and never ack. (A single-member zone therefore
        has *no* ack peers — the engine clears its buffer and relies on
        digest-sync, which computes responses from ``X``.)"""
        return self.intra_peers(replica, neighbors)


def hierarchical_policy(topology: Topology, base: Optional[str] = "bp+rr",
                        *, inter_every: int = 1) -> ShippingPolicy:
    """The standard zoned-cluster policy stack: ``base`` (a
    :func:`make_policy` spec sharpening intra-zone pushes, or None for
    plain ship-all) composed with :class:`HierarchicalGossip`."""
    hier = HierarchicalGossip(topology, inter_every=inter_every)
    if not base:
        return hier
    return Compose(make_policy(base), hier)


__all__ = ["HierarchicalGossip", "hierarchical_policy"]
