"""Discrete-event simulator of the paper's network model (§2).

The network is asynchronous and unreliable: messages can be **lost,
duplicated, or reordered** (never corrupted); arbitrarily long partitions
happen but eventually heal; if a node sends infinitely many messages,
infinitely many get through. Nodes have durable storage, can crash, and
recover with the durable content as of the last atomic state transition.

The simulator drives ``Node`` subclasses (anti-entropy replicas, pods in the
training runtime) with:

* seeded randomness — every run is reproducible;
* per-link loss / duplication probability and delay jitter (reordering
  falls out of random delays);
* time-windowed partitions;
* crash / recover events that reset volatile state from durable state;
* message / byte accounting (structural sizes) for the §9
  message-complexity benchmarks.
"""

from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple


# ---------------------------------------------------------------------------
# Structural size accounting (the Õ(·) of §9: counts of atoms, ignoring
# logarithmic factors in the size of integers and ids)
# ---------------------------------------------------------------------------

def structural_size(x: Any) -> int:
    """Number of atomic entries in a (nested) CRDT value / message.

    Encoded wire frames (bytes) are the exception: their size is not an
    estimate but the measured frame length, so byte accounting under the
    wire codec reports real bytes shipped."""
    if x is None:
        return 0
    if isinstance(x, (bytes, bytearray)):
        return len(x)
    if isinstance(x, (int, float, str, bool)):
        return 1
    try:
        import numpy as _np
        if isinstance(x, _np.ndarray):
            return int(x.size)    # digest version columns in object mode
    except ImportError:  # pragma: no cover
        pass
    if isinstance(x, (list, tuple, set, frozenset)):
        return sum(structural_size(v) for v in x)
    if isinstance(x, dict):
        return sum(structural_size(k) + structural_size(v) for k, v in x.items())
    if hasattr(x, "__dataclass_fields__"):
        return sum(structural_size(getattr(x, f)) for f in x.__dataclass_fields__)
    return 1


@dataclass
class NetConfig:
    loss: float = 0.0          # P(drop) per transmission
    dup: float = 0.0           # P(one extra copy) per delivered message
    min_delay: float = 0.05
    max_delay: float = 1.0
    seed: int = 0


@dataclass
class NetStats:
    sent: int = 0
    delivered: int = 0
    dropped: int = 0
    duplicated: int = 0
    bytes_sent: int = 0        # structural size of all sent payloads
    by_kind: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    # link-class split (populated only under a Topology): the same byte
    # totals re-bucketed by intra / inter / wan, plus the cost-model
    # accumulator (bytes × the link's byte_cost — WAN egress is billed)
    by_class: Dict[str, int] = field(default_factory=dict)
    bytes_by_class: Dict[str, int] = field(default_factory=dict)
    link_cost: float = 0.0

    def record(self, kind: str, size: int,
               link_class: Optional[str] = None,
               byte_cost: float = 1.0) -> None:
        self.sent += 1
        self.bytes_sent += size
        self.by_kind[kind] = self.by_kind.get(kind, 0) + 1
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0) + size
        if link_class is not None:
            self.by_class[link_class] = self.by_class.get(link_class, 0) + 1
            self.bytes_by_class[link_class] = (
                self.bytes_by_class.get(link_class, 0) + size)
            self.link_cost += size * byte_cost

    def cross_zone_bytes(self) -> int:
        """Bytes shipped on links that leave the sender's zone (the
        inter + wan classes) — what hierarchical gossip exists to
        minimize, and what ``bench_topology`` compares against the flat
        mesh. Zero when no topology was attached (nothing was classed)."""
        return sum(v for cls, v in self.bytes_by_class.items()
                   if cls != "intra")

    PAYLOAD_KINDS = ("delta", "state", "handoff", "membership",
                     "digest", "digest-resp")

    def payload_atoms(self) -> int:
        """Size of all traffic a shipping policy pays for: delta / state
        / handoff / membership payloads plus BOTH halves of a digest
        exchange — requests carry per-chunk version columns that scale
        with store size, so excluding them would flatter pull policies
        in the §9 tables and policy benchmarks. Only fixed-size control
        traffic (acks) is excluded. Structural atoms for object
        messages; measured frame bytes when replicas ship through the
        wire codec."""
        return sum(v for k, v in self.bytes_by_kind.items()
                   if k in self.PAYLOAD_KINDS)

    def pull_bytes(self) -> int:
        """Total cost of digest exchanges: requests (summaries) plus
        responses (the rows the requester lacked) — what a reconnect
        catch-up pays under digest-sync, compared against one full-state
        frame in ``bench_wire``."""
        return (self.bytes_by_kind.get("digest", 0)
                + self.bytes_by_kind.get("digest-resp", 0))


class Node:
    """Base replica. Subclasses define durable/volatile state and handlers."""

    def __init__(self, node_id: str):
        self.id = node_id
        self.sim: Optional["Simulator"] = None
        self.alive = True

    # -- wiring ---------------------------------------------------------------
    def attach(self, sim: "Simulator") -> None:
        self.sim = sim

    def send(self, dst: str, msg: Any) -> None:
        assert self.sim is not None
        self.sim.send(self.id, dst, msg)

    # -- handlers (override) ----------------------------------------------------
    def on_receive(self, src: str, msg: Any) -> None:  # pragma: no cover
        raise NotImplementedError

    def on_periodic(self) -> None:  # pragma: no cover
        pass

    # -- crash model --------------------------------------------------------------
    def durable_snapshot(self) -> Any:
        """What survives a crash (atomic at each state transition)."""
        return None

    def recover(self, durable: Any) -> None:
        """Reinitialise volatile state from durable state."""

    def crash_and_recover(self) -> None:
        self.recover(self.durable_snapshot())


class Simulator:
    """Discrete-event network; ``topology`` (a :class:`repro.topology.
    Topology`) makes links non-uniform: each message's loss/dup/delay
    come from the link's class profile (falling back to ``config`` for
    classes without an override) and bytes are accounted per class.
    Without a topology every link behaves identically — the flat mesh."""

    def __init__(self, config: NetConfig = NetConfig(),
                 topology: Optional[Any] = None):
        self.cfg = config
        self.topology = topology
        self.rng = random.Random(config.seed)
        self.time = 0.0
        self._q: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.nodes: Dict[str, Node] = {}
        self.stats = NetStats()
        # partitions: list of (t_start, t_end, set_a, set_b); messages between
        # the two sides are dropped while t in [t_start, t_end).
        self.partitions: List[Tuple[float, float, frozenset, frozenset]] = []

    # -- topology ------------------------------------------------------------
    def add_node(self, node: Node) -> Node:
        node.attach(self)
        self.nodes[node.id] = node
        return node

    def add_partition(self, t_start: float, t_end: float,
                      side_a: Iterable[str], side_b: Iterable[str]) -> None:
        self.partitions.append((t_start, t_end, frozenset(side_a),
                                frozenset(side_b)))

    def add_zone_partition(self, t_start: float, t_end: float,
                           zone: str) -> None:
        """Cut one zone off from the rest of the world for a window —
        the canonical multi-region failure. Requires a topology; sides
        are computed from the nodes added so far."""
        if self.topology is None:
            raise ValueError("zone partitions need a Simulator topology")
        side_a = [i for i in self.nodes if self.topology.zone(i) == zone]
        side_b = [i for i in self.nodes if self.topology.zone(i) != zone]
        if not side_a or not side_b:
            raise ValueError(f"zone {zone!r} partition has an empty side")
        self.add_partition(t_start, t_end, side_a, side_b)

    def _partitioned(self, src: str, dst: str) -> bool:
        for t0, t1, a, b in self.partitions:
            if t0 <= self.time < t1 and (
                    (src in a and dst in b) or (src in b and dst in a)):
                return True
        return False

    # -- scheduling ------------------------------------------------------------
    def schedule(self, delay: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._q, (self.time + delay, next(self._seq), fn))

    def every(self, interval: float, fn: Callable[[], None],
              jitter: float = 0.1, until: float = float("inf")) -> None:
        def tick():
            if self.time >= until:
                return
            fn()
            self.schedule(interval * (1.0 + self.rng.uniform(-jitter, jitter)),
                          tick)
        self.schedule(self.rng.uniform(0, interval), tick)

    # -- transport ------------------------------------------------------------
    def send(self, src: str, dst: str, msg: Any) -> None:
        # encoded frames carry their traffic class as a .kind attribute
        kind = getattr(msg, "kind", None)
        if kind is None:
            kind = (msg[0] if isinstance(msg, tuple) and msg
                    else type(msg).__name__)
        # per-link-class conditions: the link's profile overrides the
        # flat NetConfig when the topology carries one for its class
        link_cls: Optional[str] = None
        loss, dup = self.cfg.loss, self.cfg.dup
        min_delay, max_delay = self.cfg.min_delay, self.cfg.max_delay
        byte_cost = 1.0
        if self.topology is not None:
            link_cls = self.topology.link_class(src, dst)
            prof = self.topology.profiles.get(link_cls)
            if prof is not None:
                loss, dup = prof.loss, prof.dup
                min_delay, max_delay = prof.min_delay, prof.max_delay
                byte_cost = prof.byte_cost
        self.stats.record(str(kind), structural_size(msg),
                          link_class=link_cls, byte_cost=byte_cost)
        if self._partitioned(src, dst) or self.rng.random() < loss:
            self.stats.dropped += 1
            return
        copies = 1
        if self.rng.random() < dup:
            copies += 1
            self.stats.duplicated += 1
        for _ in range(copies):
            delay = self.rng.uniform(min_delay, max_delay)

            def deliver(dst=dst, src=src, msg=msg):
                node = self.nodes.get(dst)
                if node is not None and node.alive:
                    self.stats.delivered += 1
                    node.on_receive(src, msg)

            self.schedule(delay, deliver)

    # -- fault injection ----------------------------------------------------------
    def crash(self, node_id: str, downtime: float) -> None:
        node = self.nodes[node_id]
        durable = node.durable_snapshot()
        node.alive = False

        def back_up():
            node.alive = True
            node.recover(durable)

        self.schedule(downtime, back_up)

    # -- run loop -------------------------------------------------------------
    def run_until(self, t_end: float) -> None:
        while self._q and self._q[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._q)
            self.time = max(self.time, t)
            fn()
        self.time = max(self.time, t_end)

    def run_for(self, dt: float) -> None:
        self.run_until(self.time + dt)
