"""Keyed δ-CRDT object store: a map of independent lattice objects that is
itself a join-semilattice.

The paper's anti-entropy algorithms replicate *one* object per replica; a
serving fleet replicates *millions* (one session table per request, one
tensor shard per model slice, one membership view…). ``LatticeStore`` lifts
any family of lattices to a keyed store with the **pointwise** order:

* join  — per key: both sides present ⇒ ``a[k].join(b[k])``; one side ⇒
          that value (the other side is implicitly at that key's ⊥);
* ⊥     — the empty store; a key bound to its own type's bottom is
          indistinguishable from an absent key (``leq``/``==`` treat them
          identically), so deltas stay sparse;
* δ     — a store containing only the touched keys, each holding a delta
          of the embedded type. Joining single-key deltas yields multi-key
          store deltas, which is how per-key delta-intervals aggregate
          into one store-level wire message in the propagation engine.

This is a semilattice because the product of semilattices under the
pointwise order is one; heterogeneous value types are fine as long as each
*key* keeps one type across its lifetime (joining a GCounter into an
AWORSet at the same key is a type error, exactly as it would be without
the store).

The join has a **batched fast path**: when both sides hold
``tensor_lattice.TensorState`` values under many keys, the per-chunk LWW
merges are stacked into one ``kernels.delta_join`` Pallas launch
(``kernels.ops.batched_delta_join``) instead of one jit dispatch per key —
the objects/sec win measured by ``benchmarks/bench_store.py``. The
per-key Python loop remains as the fallback (``batched=False``, or
automatically for keys whose tensors cannot be stacked).

**Key lifecycle** (``repro.lifecycle``): alongside each value the store
carries a per-key :data:`~repro.lifecycle.lattice.Life` ``(epoch,
expiry)`` — the lexicographic lifecycle lattice. The per-key state is the
lex product ``Life ×lex Value``: equal epochs join expiries (max) and
values (pointwise) as ever; a higher epoch wins wholesale, so a compact
*tombstone* (bumped epoch, no value) ⊥-absorbs every straggler delta
from the reaped incarnation. Keys never touched by the lifecycle
subsystem sit at ``LIFE_BOTTOM`` (canonically absent from ``life``), so
plain stores behave exactly as before.

Replica integration lives in :mod:`repro.core.propagation`: ``Replica``'s
durable state is a ``LatticeStore`` (single-object replicas are one-key
stores behind a view property), and ``StoreReplica`` exposes the keyed
API. Hash-sharded key ownership is :mod:`repro.sync.membership`
(``KeyOwnership`` / ``ShardByKey``); the expiry/reaper machinery is
:mod:`repro.lifecycle`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, FrozenSet, Iterable, List, Mapping, Tuple

from ..lifecycle.lattice import LIFE_BOTTOM, Life, life_join


def _is_bottom(value: Any) -> bool:
    """A value equal to its own type's bottom is lattice-identity."""
    return value == type(value).bottom()


@dataclass(frozen=True, eq=False)
class LatticeStore:
    """key → lattice value, itself a join-semilattice (pointwise order).

    ``life`` is the per-key lifecycle component (epoch, expiry) — see the
    module docstring; an entry's value lives *at* its key's life epoch.
    ``LIFE_BOTTOM`` entries are canonically absent.
    """

    entries: Tuple[Tuple[str, Any], ...] = ()
    life: Tuple[Tuple[str, Life], ...] = ()

    # -- construction -----------------------------------------------------------
    @staticmethod
    def bottom() -> "LatticeStore":
        return LatticeStore()

    @staticmethod
    def of(mapping: Mapping[str, Any],
           life: Mapping[str, Life] = ()) -> "LatticeStore":
        return LatticeStore(tuple(sorted(mapping.items())),
                            _canon_life(dict(life).items()))

    @staticmethod
    def key_delta(key: str, delta_value: Any) -> "LatticeStore":
        """δ-mutator lift: a store delta touching exactly one key."""
        return LatticeStore(((key, delta_value),))

    @staticmethod
    def life_delta(key: str, life: Life) -> "LatticeStore":
        """A store delta carrying only lifecycle state for ``key`` — a
        touch (expiry extension) or, with a bumped epoch, a tombstone."""
        return LatticeStore((), _canon_life([(key, life)]))

    def with_life(self, key: str, life: Life) -> "LatticeStore":
        """This store with ``life`` joined into ``key``'s lifecycle —
        how a write delta is stamped with the epoch/TTL it targets."""
        m = dict(self.life)
        m[key] = life_join(m.get(key, LIFE_BOTTOM), life)
        return LatticeStore(self.entries, _canon_life(m.items()))

    # -- views ------------------------------------------------------------------
    def as_dict(self) -> Dict[str, Any]:
        return dict(self.entries)

    def keys(self) -> FrozenSet[str]:
        return frozenset(k for k, _ in self.entries)

    def all_keys(self) -> FrozenSet[str]:
        """Keys with *any* state — a value, an expiry, or a tombstone.
        Sharding/handoff/reaping must iterate this, not ``keys()``:
        tombstones carry no value but must still route and replicate."""
        return self.keys() | frozenset(k for k, _ in self.life)

    def life_of(self, key: str) -> Life:
        return dict(self.life).get(key, LIFE_BOTTOM)

    def tombstoned(self, key: str) -> bool:
        """Reaped and not revived: a past-0 epoch holding no value."""
        return self.life_of(key)[0] > 0 and key not in self.as_dict()

    def tombstoned_keys(self) -> FrozenSet[str]:
        """All tombstoned keys in ONE pass — polling loops ("is the
        whole fleet reaped yet?") should use this instead of calling
        :meth:`tombstoned` per key, which rebuilds both dicts each
        call."""
        held = {k for k, _ in self.entries}
        return frozenset(k for k, (epoch, _) in self.life
                         if epoch > 0 and k not in held)

    def get(self, key: str, typ=None):
        """Value at ``key``; ``typ.bottom()`` (or None) when absent."""
        val = self.as_dict().get(key)
        if val is None and typ is not None:
            return typ.bottom()
        return val

    def restrict(self, keys: Iterable[str]) -> "LatticeStore":
        """Sub-store of the given keys (the ownership-sharding projection).
        Always ≤ self, so joining a restriction is always safe. Carries
        the kept keys' lifecycle state too — tombstones shard and hand
        off like values."""
        keep = set(keys)
        return LatticeStore(tuple((k, v) for k, v in self.entries
                                  if k in keep),
                            tuple((k, lv) for k, lv in self.life
                                  if k in keep))

    # -- δ-mutator lift ----------------------------------------------------------
    def apply_delta(self, key: str, typ, mutator_name: str,
                    *args) -> "LatticeStore":
        """Lift a δ-mutator of the embedded type at ``key``: the returned
        store delta contains only that key. Mirrors ``ORMap.apply_delta``
        (args include the replica id when the mutator wants one)."""
        cur = self.get(key, typ)
        sub_delta = getattr(cur, mutator_name)(*args)
        return LatticeStore.key_delta(key, sub_delta)

    def update_delta(self, key: str, typ,
                     fn: Callable[[Any], Any]) -> "LatticeStore":
        """Like ``apply_delta`` with a free-form mutator function."""
        return LatticeStore.key_delta(key, fn(self.get(key, typ)))

    # -- lattice ----------------------------------------------------------------
    def _epochs(self) -> Dict[str, int]:
        """key → nonzero life epoch (absent ⇒ 0) — the part of the
        lifecycle that decides which side's value contributes to a join."""
        return {k: lv[0] for k, lv in self.life if lv[0]}

    def join(self, other: "LatticeStore", *,
             batched: bool = True) -> "LatticeStore":
        life = _joined_life(self.life, other.life)
        if batched and self._epochs() == other._epochs():
            # identical epochs per key ⇒ every value joins pointwise, so
            # the single-launch fast paths stay valid. Order: device-
            # resident columns (one scatter/fused launch, zero host
            # traffic), then the aligned host-stacked launch, then the
            # in-place host patch for subset deltas. An epoch mismatch
            # (reap/revive) lands in the general path below — which is
            # exactly the cache invalidation the lifecycle needs.
            if self.__dict__.get("_resident_cache") is not None:
                from ..kernels import resident
                fast = resident.try_join(self, other, life)
                if fast is not None:
                    return fast
            fast = _stacked_fast_join(self, other, life)
            if fast is not None:
                return fast
            fast = _patched_fast_join(self, other, life)
            if fast is not None:
                return fast
        a, b = self.as_dict(), other.as_dict()
        la, lb = dict(self.life), dict(other.life)
        out: Dict[str, Any] = {}
        pending: List[Tuple[str, Any, Any]] = []
        for k in set(a) | set(b):
            # lex product: only values at the winning epoch contribute —
            # a higher-epoch tombstone on either side absorbs the other
            ea = la.get(k, LIFE_BOTTOM)[0]
            eb = lb.get(k, LIFE_BOTTOM)[0]
            va = a.get(k) if ea >= eb else None
            vb = b.get(k) if eb >= ea else None
            if va is None and vb is None:
                continue
            if vb is None:
                out[k] = va
            elif va is None:
                out[k] = vb
            elif batched and _both_tensorstates(va, vb):
                pending.append((k, va, vb))
            else:
                out[k] = va.join(vb)
        if pending:
            out.update(_batched_join_tensorstates(pending))
        return LatticeStore(tuple(sorted(out.items())), life)

    def leq(self, other: "LatticeStore") -> bool:
        la, lb = dict(self.life), dict(other.life)
        b = other.as_dict()
        a = self.as_dict()
        for k in set(a) | set(la):
            ea, xa = la.get(k, LIFE_BOTTOM)
            eb, xb = lb.get(k, LIFE_BOTTOM)
            if ea > eb:
                return False
            if ea < eb:
                continue          # other's epoch absorbs this key entirely
            if xa > xb:
                return False
            v = a.get(k)
            if v is None:
                continue
            if k in b:
                if not v.leq(b[k]):
                    return False
            elif not _is_bottom(v):
                return False
        return True

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, LatticeStore):
            return NotImplemented
        if dict(_canon_life(self.life)) != dict(_canon_life(other.life)):
            return False
        a, b = self.as_dict(), other.as_dict()
        for k in set(a) | set(b):
            if k not in a or k not in b:
                # absent key ≡ that key's ⊥
                if not _is_bottom(a.get(k, b.get(k))):
                    return False
            elif a[k] != b[k]:
                return False
        return True

    def __hash__(self):  # pragma: no cover
        raise TypeError("unhashable")

    def decompose(self) -> list:
        """Join-decomposition: per key, one lifecycle atom (when the key
        has non-bottom life) plus the embedded value's atoms (when it
        decomposes) each wrapped as a single-key store; else one atom per
        key. Value atoms of a past-0 epoch carry that epoch (with the
        expiry at bottom) so re-joining them lands in the right
        incarnation. Lets RemoveRedundant trim store payloads key-by-key
        (and finer, where the value supports it)."""
        atoms = []
        la = dict(self.life)
        for k, lv in self.life:
            atoms.append(LatticeStore((), ((k, lv),)))
        for k, v in self.entries:
            epoch = la.get(k, LIFE_BOTTOM)[0]
            lf = ((k, (epoch, LIFE_BOTTOM[1])),) if epoch else ()
            sub = getattr(v, "decompose", None)
            if sub is None:
                atoms.append(LatticeStore(((k, v),), lf))
            else:
                atoms.extend(LatticeStore(((k, a),), lf) for a in sub())
        return atoms

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}: {type(v).__name__}" for k, v in self.entries)
        tombs = len(self.tombstoned_keys())
        extra = f", {tombs} tombstones" if tombs else ""
        return f"LatticeStore({{{inner}}}{extra})"


def _canon_life(items) -> Tuple[Tuple[str, Life], ...]:
    """Sorted life tuple with bottoms dropped (absent ≡ LIFE_BOTTOM)."""
    return tuple(sorted((k, lv) for k, lv in items if lv != LIFE_BOTTOM))


def _joined_life(a, b) -> Tuple[Tuple[str, Life], ...]:
    if not a:
        return _canon_life(b)
    if not b:
        return _canon_life(a)
    m = dict(a)
    for k, lv in b:
        cur = m.get(k)
        m[k] = lv if cur is None else life_join(cur, lv)
    return _canon_life(m.items())


# ---------------------------------------------------------------------------
# Batched TensorState join (one Pallas launch over many keys' chunks)
# ---------------------------------------------------------------------------

_TS_CLS = None     # cached TensorState class (lazy: tensor_lattice pulls jax)


def _tensorstate_cls():
    global _TS_CLS
    if _TS_CLS is None:
        try:
            from .tensor_lattice import TensorState
        except Exception:  # pragma: no cover - jax unavailable
            return None
        _TS_CLS = TensorState
    return _TS_CLS


def _both_tensorstates(a: Any, b: Any) -> bool:
    ts = _tensorstate_cls()
    return ts is not None and isinstance(a, ts) and isinstance(b, ts)


def _stackable(act, bct) -> bool:
    if getattr(act, "is_sparse", False) or getattr(bct, "is_sparse", False):
        return False    # sparse deltas join via the gather/scatter path
    return (act.values.shape == bct.values.shape
            and act.values.dtype == bct.values.dtype)


class _StackedChunks:
    """Columnar cache of all of a store's TensorState chunk data: one
    ``[total_rows, chunk]`` values array + ``[total_rows]`` versions,
    with a ``(key, name, start, stop)`` layout. Built lazily on first
    batched join and attached to the (immutable) store, so a resident
    store that joins many deltas pays the stacking glue once; the output
    of a stacked join carries its own cache (its ChunkedTensors are views
    into the stacked result), keeping steady-state anti-entropy rounds at
    one kernel launch + O(keys) view assembly."""

    __slots__ = ("vals", "vers", "layout", "sig", "_spans")

    def __init__(self, vals, vers, layout, sig):
        self.vals = vals
        self.vers = vers
        self.layout = layout
        self.sig = sig
        self._spans = None

    @property
    def spans(self):
        """(key, name) → (start, stop) row-range lookup, built lazily —
        what the in-place patch path and the resident adopter index by."""
        if self._spans is None:
            self._spans = {(k, n): (s, e) for k, n, s, e in self.layout}
        return self._spans


def _stack_store(store: LatticeStore):
    """Fetch (or build and cache) the columnar view of ``store``. Returns
    None when the store is not stackable (non-tensor values, mixed chunk
    widths/dtypes, or empty)."""
    import numpy as np

    cached = store.__dict__.get("_stacked_cache")
    if cached is not None:
        return cached if isinstance(cached, _StackedChunks) else None
    ts_cls = _tensorstate_cls()
    result = None
    # cheap prescan first so non-tensor stores bail before any array work
    if (ts_cls is not None and store.entries
            and all(isinstance(v, ts_cls) for _, v in store.entries)):
        parts_v, parts_r, layout = [], [], []
        chunkw = dtype = vdtype = None
        row = 0
        ok = True
        for key, val in store.entries:
            for name, ct in val.chunks:
                if getattr(ct, "is_sparse", False):
                    ok = False    # sparse rows are not a dense column block
                    break
                v, r = np.asarray(ct.values), np.asarray(ct.versions)
                if chunkw is None:
                    chunkw, dtype, vdtype = v.shape[1], v.dtype, r.dtype
                elif (v.shape[1] != chunkw or v.dtype != dtype
                      or r.dtype != vdtype):
                    ok = False
                    break
                parts_v.append(v)
                parts_r.append(r)
                layout.append((key, name, row, row + v.shape[0]))
                row += v.shape[0]
            if not ok:
                break
        if ok and parts_v:
            # sig carries the full key sequence too: a key holding an
            # empty TensorState contributes no layout rows but must still
            # align between the two stores
            sig = (tuple(k for k, _ in store.entries),
                   tuple((k, n, stop - start)
                         for k, n, start, stop in layout),
                   chunkw, str(dtype), str(vdtype))
            result = _StackedChunks(np.concatenate(parts_v),
                                    np.concatenate(parts_r),
                                    tuple(layout), sig)
    object.__setattr__(store, "_stacked_cache",
                       result if result is not None else False)
    return result


def _stacked_fast_join(a_store: LatticeStore,
                       b_store: LatticeStore,
                       life: Tuple[Tuple[str, Life], ...] = ()):
    """Aligned-layout fast path: when both stores stack to the identical
    (key, name, rows) signature — the steady state of a resident store
    joining full-coverage deltas — the whole join is ONE kernel launch
    over the cached columns. Returns None when the layouts differ (the
    general per-segment path handles subsets and mismatches). ``life``
    is the pre-joined lifecycle component (the caller has already
    checked both sides agree on epochs, so values join pointwise)."""
    import numpy as np

    sa = _stack_store(a_store)
    if sa is None:
        return None
    sb = _stack_store(b_store)
    if sb is None or sa.sig != sb.sig:
        return None
    # jax-dependent imports only after stackability is established, so
    # pure-CRDT stores keep working where jax is unavailable
    from .tensor_lattice import ChunkedTensor, TensorState
    from ..kernels import ops

    if ops.use_pallas_default():
        import jax.numpy as jnp
        ovn, overn = ops.delta_join(
            jnp.asarray(sa.vals), jnp.asarray(sa.vers),
            jnp.asarray(sb.vals), jnp.asarray(sb.vers), interpret=False)
    else:
        n = sa.vals.shape[0]
        ov, over = ops.delta_join(sa.vals, sa.vers, sb.vals, sb.vers,
                                  block_n=n, interpret=True)
        ovn, overn = np.asarray(ov), np.asarray(over)

    out_entries = []
    li = 0
    layout = sa.layout
    for (key, A), (_, B) in zip(a_store.entries, b_store.entries):
        chunks = []
        for name, _ct in A.chunks:
            _, _, start, stop = layout[li]
            li += 1
            chunks.append((name, ChunkedTensor(ovn[start:stop],
                                               overn[start:stop])))
        out_entries.append((key, TensorState(tuple(chunks),
                                             max(A.lamport, B.lamport))))
    result = LatticeStore(tuple(out_entries), life)
    object.__setattr__(result, "_stacked_cache",
                       _StackedChunks(ovn, overn, layout, sa.sig))
    return result


def _patched_fast_join(a_store: LatticeStore,
                       b_store: LatticeStore,
                       life: Tuple[Tuple[str, Life], ...] = ()):
    """Host-cache patch path: ``a_store`` holds a stacked column cache
    and ``b_store`` touches a *subset* of its (key, tensor) spans with
    matching chunk counts — the single-key-write / sparse-delta case
    that previously invalidated the cache and re-``np.concatenate``'d
    the whole signature group on the next aligned join. Instead, copy
    the columns once and LWW-patch only the shipped rows in place;
    untouched keys reuse their entry objects outright. Returns None on
    any layout change (new key, new tensor, chunk-count drift) — only a
    real layout change pays the full rebuild."""
    import numpy as np

    sa = a_store.__dict__.get("_stacked_cache")
    if not isinstance(sa, _StackedChunks) or not b_store.entries:
        return None
    ts_cls = _tensorstate_cls()
    if ts_cls is None:
        return None
    from .tensor_lattice import live_rows

    chunkw = sa.sig[2]
    vdtype = np.dtype(sa.sig[3])
    rdtype = np.dtype(sa.sig[4])
    a_map = dict(a_store.entries)
    # validation pass: every shipped tensor must land in an existing span
    patches = []           # (start, local idx, vals rows, vers rows)
    for key, val in b_store.entries:
        if not isinstance(val, ts_cls) or key not in a_map:
            return None
        for name, ct in val.chunks:
            span = sa.spans.get((key, name))
            if span is None:
                return None
            n_chunks, width = ct.shape
            if n_chunks != span[1] - span[0] or width != chunkw:
                return None
            li, lv, lr = live_rows(ct)
            lv, lr = np.asarray(lv), np.asarray(lr)
            if lv.dtype != vdtype or lr.dtype != rdtype:
                return None
            if li.size:
                patches.append((span[0], li, lv, lr))

    new_vals = sa.vals.copy()
    new_vers = sa.vers.copy()
    for start, li, lv, lr in patches:
        rows = li.astype(np.int64) + start
        take = lr > new_vers[rows]
        if take.any():
            rows = rows[take]
            new_vals[rows] = lv[take]
            new_vers[rows] = lr[take]

    from .tensor_lattice import ChunkedTensor, TensorState
    touched: Dict[str, Any] = {}
    for key, B in b_store.entries:
        A = a_map[key]
        b_names = frozenset(n for n, _ in B.chunks)
        chunks = []
        for name, ct in A.chunks:
            if name in b_names:
                start, stop = sa.spans[(key, name)]
                chunks.append((name, ChunkedTensor(new_vals[start:stop],
                                                   new_vers[start:stop])))
            else:
                chunks.append((name, ct))
        touched[key] = TensorState(tuple(chunks),
                                   max(A.lamport, B.lamport))

    entries = tuple((k, touched.get(k, v)) for k, v in a_store.entries)
    result = LatticeStore(entries, life)
    object.__setattr__(result, "_stacked_cache",
                       _StackedChunks(new_vals, new_vers, sa.layout,
                                      sa.sig))
    return result


def _batched_join_tensorstates(pairs: List[Tuple[str, Any, Any]]
                               ) -> Dict[str, Any]:
    """Join many (key, TensorState, TensorState) pairs with the chunk
    merges of *all* keys stacked into one kernel launch per (chunk-width,
    dtype) group, instead of one jit dispatch per key. Keys whose tensors
    cannot be stacked (shape/dtype mismatch) fall back to the per-key
    join."""
    from .tensor_lattice import ChunkedTensor, TensorState
    from ..kernels import ops

    out: Dict[str, Any] = {}
    segments: List[Tuple[Any, Any, Any, Any]] = []
    # per key: the merged (name, ChunkedTensor-or-segment-index) plan;
    # ``TensorState.chunks`` is sorted by name, so a linear sorted-tuple
    # merge avoids dict/set construction per key on the hot path
    plans: List[Tuple[str, list, int]] = []    # (key, plan, lamport)

    for key, A, B in pairs:
        ca, cb = A.chunks, B.chunks
        ia = ib = 0
        plan: list = []
        seg_start = len(segments)
        ok = True
        while ia < len(ca) or ib < len(cb):
            if ib == len(cb) or (ia < len(ca) and ca[ia][0] < cb[ib][0]):
                plan.append(ca[ia])
                ia += 1
            elif ia == len(ca) or cb[ib][0] < ca[ia][0]:
                plan.append(cb[ib])
                ib += 1
            else:                              # same tensor on both sides
                name, act = ca[ia]
                bct = cb[ib][1]
                if not _stackable(act, bct):
                    ok = False
                    break
                plan.append((name, len(segments)))
                segments.append((act.values, act.versions,
                                 bct.values, bct.versions))
                ia += 1
                ib += 1
        if not ok:
            del segments[seg_start:]           # discard this key's segments
            out[key] = A.join(B)               # per-key fallback
            continue
        plans.append((key, plan, max(A.lamport, B.lamport)))

    results: List[Any] = []
    if segments:
        if ops.use_pallas_default():
            # TPU: stay on-device, compiled Mosaic kernel
            results = ops.batched_delta_join(segments, interpret=False)
        else:
            # CPU: host-staged numpy glue + one single-grid-step
            # interpret launch per signature (outputs are numpy views)
            results = ops.batched_delta_join(segments, interpret=True,
                                             host_stage=True)

    for key, plan, lamport in plans:
        chunks = tuple(
            (name, ChunkedTensor(*results[v]) if isinstance(v, int) else v)
            for name, v in plan)
        out[key] = TensorState(chunks, lamport)
    return out


# ---------------------------------------------------------------------------
# Store-wide digest selection (the DigestBudget policy over keyed stores)
# ---------------------------------------------------------------------------

def digest_select_store(store: LatticeStore, budget_bytes: int,
                        interpret: bool = True) -> LatticeStore:
    """Byte-budgeted chunk selection across the *whole* store: chunks from
    every ``TensorState`` value under every key enter ONE global energy
    ranking (``tensor_lattice.digest_keep_plan``, scope = store key) — so
    the budget picks *keys* by digest, not just chunks within one object.
    Non-tensor values pass through untouched (they are not
    chunk-addressable; the policy budgets tensor payload). Lifecycle
    state rides through whole — trimming a tombstone or expiry to save a
    few bytes would only delay its propagation. The result is
    ≤ ``store`` pointwise, so joining it is always safe."""
    from .tensor_lattice import (TensorState, digest_keep_plan,
                                 mask_kept_chunks)

    passthrough: Dict[str, Any] = {}
    tensor_keys: Dict[str, Any] = {}
    for key, val in store.as_dict().items():
        (tensor_keys if isinstance(val, TensorState)
         else passthrough)[key] = val

    cache = store.__dict__.get("_resident_cache")
    if cache is not None:
        # resident stores rank from the digest columns the join kernels
        # keep fresh: one top-k epilogue, no per-tensor recompute
        from ..kernels import resident
        keep = resident.keep_plan(cache, budget_bytes)
    else:
        keep = digest_keep_plan(
            ((key, name, ct) for key, val in tensor_keys.items()
             for name, ct in val.as_dict().items()), budget_bytes,
            interpret)
    if keep is None:
        return store

    out: Dict[str, Any] = dict(passthrough)
    for key, val in tensor_keys.items():
        kept = {name: mask_kept_chunks(ct, keep[(key, name)])
                for name, ct in val.as_dict().items()
                if keep.get((key, name))}
        if kept:
            out[key] = TensorState.of(kept, lamport=val.lamport)
    return LatticeStore(tuple(sorted(out.items())), store.life)
