"""Join-semilattices over JAX tensors — the δ-CRDT ⇄ training-state bridge.

Two lattices carry the framework's replicated ML state:

1. ``TensorState`` — a *versioned chunk store*: every tensor is split into
   fixed-size chunks, each tagged with a totally-ordered version
   ``(lamport_counter, writer_rank)`` packed into one int64. The join keeps,
   per chunk, the value with the larger version (pointwise LWW) — a
   join-semilattice because versions are unique per write and the order is
   total. This is the δ-CRDT the checkpointing and parameter-replication
   layers gossip: a *delta* is a TensorState containing only touched
   tensors, and the wire format (``pack_delta``) additionally drops
   untouched chunks. The hot join path (`masked version merge`, one pass
   over HBM) is the ``kernels/delta_join`` Pallas kernel on TPU; the jnp
   fallback below is the oracle and the CPU path.

2. ``DotSumStore`` — a grow-only map dot → update-pytree with join = union
   (unique dots ⇒ no conflicts): the additive lattice used for cross-pod
   pseudo-gradient aggregation (local-SGD / DiLoCo-style outer updates).
   Its value is ``sum of all dots``; duplicates and reordering are absorbed
   by the union. ``IntervalSum`` is its §7.2-style compression: under
   causal delta-interval delivery (Algorithm 2), the explicit dot cloud
   collapses to (version-vector, running sum) — property-tested equivalent
   to the reference store.

All lattice values implement ``join``/``leq``/``==`` so the generic
anti-entropy nodes in ``repro.core.antientropy`` run unchanged over them.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# version = (lamport << RANK_BITS) | writer_rank, stored in a jnp integer
# array. Without jax_enable_x64 jnp canonicalizes int64 → int32, so keep the
# rank field small enough that lamport gets ≥ 2^21 headroom (≈ 2M writes per
# tensor-chunk lifetime; checkpoints reset clocks). 1024 writer ranks covers
# pod-level replication (replicas are pods, not chips — see DESIGN.md §2).
RANK_BITS = 10
_RANK_MASK = (1 << RANK_BITS) - 1

# jnp canonical integer dtype for version arrays (int32 unless x64 enabled).
VERSION_DTYPE = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def make_version(lamport: int, rank: int) -> int:
    assert 0 <= rank < (1 << RANK_BITS)
    return (int(lamport) << RANK_BITS) | int(rank)


def version_lamport(v: int) -> int:
    return int(v) >> RANK_BITS


# ---------------------------------------------------------------------------
# Versioned chunk store
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class ChunkedTensor:
    """One tensor as [n_chunks, chunk_size] values + [n_chunks] int64 versions.

    Version 0 == ⊥ for that chunk (values must be zeros there).
    """

    values: jax.Array    # [n_chunks, chunk_size]
    versions: jax.Array  # [n_chunks] int64

    is_sparse = False

    @property
    def shape(self) -> Tuple[int, int]:
        return tuple(self.values.shape)

    def __eq__(self, other: object) -> bool:
        if isinstance(other, SparseChunks):
            return _pair_eq(self, other)
        if not isinstance(other, ChunkedTensor):
            return NotImplemented
        return (self.values.shape == other.values.shape
                and bool(np.array_equal(np.asarray(self.versions),
                                        np.asarray(other.versions)))
                and bool(np.array_equal(np.asarray(self.values),
                                        np.asarray(other.values))))

    def __hash__(self):  # pragma: no cover
        raise TypeError("unhashable")


@dataclass(frozen=True, eq=False)
class SparseChunks:
    """Sparse chunk-row set: the wire-decoded form of a tensor delta.

    Holds only the shipped rows of a logically [n_chunks, chunk] versioned
    tensor — ``idx`` are the chunk positions (sorted, unique), ``vals`` /
    ``vers`` the corresponding rows; every unlisted chunk is ⊥. Decoded
    frames keep their rows as zero-copy views into the frame buffer, and
    joining a sparse delta into a dense resident tensor is a
    gather → LWW-merge → scatter over the listed rows only — O(shipped
    chunks), never a full-size zero-padded materialization.
    """

    n_chunks: int
    idx: np.ndarray    # [rows] chunk positions, sorted strictly increasing
    vals: np.ndarray   # [rows, chunk]
    vers: np.ndarray   # [rows]

    is_sparse = True

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n_chunks, int(self.vals.shape[1]))

    def to_dense(self) -> ChunkedTensor:
        """Materialize the full [n_chunks, chunk] tensor (⊥ elsewhere),
        cached — the fallback for dense-only consumers (digest ranking,
        unchunk, checkpointing); the join/leq/eq hot paths never call
        this. A decoded value can become durable resident state (a key
        the replica never writes locally is taken wholesale by the
        join), so dense accessors must work, not crash."""
        cached = self.__dict__.get("_dense_cache")
        if cached is None:
            vals = np.zeros((self.n_chunks, self.vals.shape[1]),
                            dtype=self.vals.dtype)
            vers = np.zeros((self.n_chunks,),
                            dtype=np.asarray(self.vers).dtype)
            if self.idx.size:
                vals[self.idx] = self.vals
                vers[self.idx] = self.vers
            cached = ChunkedTensor(vals, vers)
            object.__setattr__(self, "_dense_cache", cached)
        return cached

    @property
    def values(self):
        """Dense [n_chunks, chunk] view (lazily materialized) — lets
        dense-only consumers treat any chunk tensor uniformly."""
        return self.to_dense().values

    @property
    def versions(self):
        return self.to_dense().versions

    def __eq__(self, other: object) -> bool:
        if isinstance(other, (ChunkedTensor, SparseChunks)):
            return _pair_eq(self, other)
        return NotImplemented

    def __hash__(self):  # pragma: no cover
        raise TypeError("unhashable")


def sparse_chunks(n_chunks: int, idx, vals, vers) -> SparseChunks:
    """Construct a :class:`SparseChunks`, normalizing to sorted-unique
    row order (the codec emits sorted rows; ad-hoc callers may not).
    Duplicate chunk positions keep the highest-versioned row — LWW, the
    same rule the join applies."""
    idx = np.asarray(idx)
    vals = np.asarray(vals)
    vers = np.asarray(vers)
    if idx.size and not bool(np.all(idx[1:] > idx[:-1])):
        order = np.lexsort((vers, idx))     # by position, version asc
        idx, vals, vers = idx[order], vals[order], vers[order]
        last = np.r_[idx[1:] != idx[:-1], True]
        if not bool(last.all()):
            idx, vals, vers = idx[last], vals[last], vers[last]
    return SparseChunks(int(n_chunks), idx, vals, vers)


def _max_version(ct) -> int:
    """Largest version held by a dense or sparse chunk tensor (0 == ⊥)."""
    if ct.is_sparse:
        return int(np.max(np.asarray(ct.vers))) if ct.idx.size else 0
    return int(jnp.max(ct.versions)) if ct.versions.shape[0] else 0


def _join_dense_sparse(dense: ChunkedTensor,
                       sp: SparseChunks) -> ChunkedTensor:
    """Join a sparse delta into a dense tensor: gather the resident rows
    at the shipped positions, keep the higher-versioned side, scatter the
    winners back — O(shipped rows) work plus one buffer copy."""
    if sp.idx.size == 0:
        return dense
    dv = np.asarray(dense.values)
    dr = np.asarray(dense.versions)
    take = np.asarray(sp.vers) > dr[sp.idx]
    if not bool(take.any()):
        return dense
    rows = sp.idx[take]
    out_v = np.array(dv, copy=True)
    out_r = np.array(dr, copy=True)
    out_v[rows] = np.asarray(sp.vals)[take]
    out_r[rows] = np.asarray(sp.vers)[take]
    return ChunkedTensor(out_v, out_r)


def _join_sparse_sparse(a: SparseChunks, b: SparseChunks) -> SparseChunks:
    """Union of two sparse row sets; overlapping positions keep the higher
    version (ties carry identical values by unique-write construction)."""
    if a.idx.size == 0:
        return b
    if b.idx.size == 0:
        return a
    idx = np.concatenate([np.asarray(a.idx), np.asarray(b.idx)])
    vers = np.concatenate([np.asarray(a.vers), np.asarray(b.vers)])
    vals = np.concatenate([np.asarray(a.vals), np.asarray(b.vals)], axis=0)
    order = np.lexsort((vers, idx))          # by position, version ascending
    idx, vers, vals = idx[order], vers[order], vals[order]
    last = np.r_[idx[1:] != idx[:-1], True]  # max-version row per position
    return SparseChunks(a.n_chunks, idx[last], vals[last], vers[last])


def _pair_join(a, b):
    """Join two chunk tensors of any density mix."""
    if not a.is_sparse and not b.is_sparse:
        v, vers = _join_chunked(a.values, a.versions, b.values, b.versions)
        return ChunkedTensor(v, vers)
    if a.is_sparse and b.is_sparse:
        return _join_sparse_sparse(a, b)
    return (_join_dense_sparse(b, a) if a.is_sparse
            else _join_dense_sparse(a, b))


def _pair_leq(a, b) -> bool:
    """Pointwise version order over any density mix (O(sparse rows))."""
    if not a.is_sparse and not b.is_sparse:
        return not bool(jnp.any(a.versions > b.versions))
    if a.is_sparse and not b.is_sparse:
        if a.idx.size == 0:
            return True
        return not bool(np.any(np.asarray(a.vers)
                               > np.asarray(b.versions)[a.idx]))
    if not a.is_sparse and b.is_sparse:
        av = np.asarray(a.versions)
        live_outside = av > 0
        if b.idx.size:
            live_outside = np.array(live_outside, copy=True)
            live_outside[b.idx] = False
            if bool(np.any(av[b.idx] > np.asarray(b.vers))):
                return False
        return not bool(live_outside.any())
    # sparse ≤ sparse: every live row of a must be covered by b
    live = np.asarray(a.vers) > 0
    ai, avr = a.idx[live], np.asarray(a.vers)[live]
    if ai.size == 0:
        return True
    if b.idx.size == 0:
        return False
    pos = np.searchsorted(np.asarray(b.idx), ai)
    pos_c = np.minimum(pos, b.idx.size - 1)
    found = (pos < b.idx.size) & (np.asarray(b.idx)[pos_c] == ai)
    if not bool(found.all()):
        return False
    return not bool(np.any(avr > np.asarray(b.vers)[pos_c]))


def _sp_live(sp: SparseChunks):
    live = np.asarray(sp.vers) > 0
    return sp.idx[live], np.asarray(sp.vals)[live], np.asarray(sp.vers)[live]


def live_rows(ct) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(chunk positions, values rows, versions) of a chunk tensor's live
    chunks, sorted by position — directly from sparse row sets, by mask
    for dense. The shared row extractor behind the wire codec and the
    digest-diff machinery."""
    if ct.is_sparse:
        idx, vals, vers = _sp_live(ct)
        return np.asarray(idx, dtype=np.int32), vals, vers
    vers = np.asarray(ct.versions)
    mask = vers > 0
    idx = np.nonzero(mask)[0].astype(np.int32)
    return idx, np.asarray(ct.values)[idx], vers[idx]


def dense_versions(ct) -> np.ndarray:
    """The full [n_chunks] version column of a dense or sparse chunk
    tensor (version 0 == ⊥ at unlisted sparse positions) — what a digest
    summary carries per (key, tensor)."""
    if ct.is_sparse:
        vers = np.zeros(ct.n_chunks, dtype=np.asarray(ct.vers).dtype)
        if ct.idx.size:
            vers[ct.idx] = ct.vers
        return vers
    return np.asarray(ct.versions)


def _pair_eq(a, b) -> bool:
    """Value equality over any density mix. Relies on the ⊥ invariant
    (version 0 ⇒ zero values), which every constructor maintains."""
    if a.shape != b.shape:
        return False
    if not a.is_sparse and not b.is_sparse:
        return a == b
    if a.is_sparse and b.is_sparse:
        ai, av, ar = _sp_live(a)
        bi, bv, br = _sp_live(b)
        return (np.array_equal(ai, bi) and np.array_equal(ar, br)
                and np.array_equal(av, bv))
    dense, sp = (b, a) if a.is_sparse else (a, b)
    dv, dr = np.asarray(dense.values), np.asarray(dense.versions)
    si, sv, sr = _sp_live(sp)
    dense_vers = np.zeros_like(dr)
    dense_vers[si] = sr
    if not np.array_equal(dr, dense_vers):
        return False
    if si.size and not np.array_equal(dv[si], sv):
        return False
    # unlisted rows are ⊥ on both sides (invariant: version 0 ⇒ zeros)
    return True


def _join_chunked_impl(av, avers, bv, bvers):
    """Pointwise LWW merge — the jnp oracle for kernels/delta_join."""
    take_b = bvers > avers
    out_v = jnp.where(take_b[:, None], bv, av)
    out_vers = jnp.maximum(avers, bvers)
    return out_v, out_vers


_join_chunked = jax.jit(_join_chunked_impl)


def chunk_tensor(x: np.ndarray, chunk_size: int,
                 version: int = 0) -> ChunkedTensor:
    flat = np.asarray(x).reshape(-1)
    pad = (-len(flat)) % chunk_size
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, flat.dtype)])
    vals = jnp.asarray(flat.reshape(-1, chunk_size))
    vers = jnp.full((vals.shape[0],), version, dtype=VERSION_DTYPE)
    return ChunkedTensor(vals, vers)


def unchunk(ct: ChunkedTensor, shape: Tuple[int, ...],
            dtype=None) -> jax.Array:
    n = int(np.prod(shape))
    flat = ct.values.reshape(-1)[:n]
    out = flat.reshape(shape)
    return out.astype(dtype) if dtype is not None else out


@dataclass(frozen=True, eq=False)
class TensorState:
    """The replicated-state lattice: name → ChunkedTensor (+ lamport clock).

    ``lamport`` is replica-local bookkeeping used to mint fresh versions; it
    rides along monotonically (max on join) and does not affect equality of
    the CRDT payload semantics (two replicas holding identical chunk data
    are converged regardless of their clocks — but we advance clocks on
    join so new writes always supersede everything observed).
    """

    chunks: Tuple[Tuple[str, ChunkedTensor], ...] = ()
    lamport: int = 0

    @staticmethod
    def bottom() -> "TensorState":
        return TensorState()

    @staticmethod
    def of(mapping: Mapping[str, ChunkedTensor], lamport: int = 0) -> "TensorState":
        return TensorState(tuple(sorted(mapping.items())), lamport)

    def as_dict(self) -> Dict[str, ChunkedTensor]:
        return dict(self.chunks)

    # -- lattice ----------------------------------------------------------------
    def join(self, other: "TensorState") -> "TensorState":
        a, b = self.as_dict(), other.as_dict()
        out: Dict[str, Any] = {}
        for k in set(a) | set(b):
            if k not in a:
                out[k] = b[k]
            elif k not in b:
                out[k] = a[k]
            else:
                out[k] = _pair_join(a[k], b[k])
        return TensorState.of(out, max(self.lamport, other.lamport))

    def leq(self, other: "TensorState") -> bool:
        a, b = self.as_dict(), other.as_dict()
        for k, ct in a.items():
            if k not in b:
                if _max_version(ct) > 0:
                    return False
                continue
            if not _pair_leq(ct, b[k]):
                return False
            # equal versions ⇒ equal values by construction (unique writes)
        return True

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, TensorState):
            return NotImplemented
        a, b = self.as_dict(), other.as_dict()
        keys = set(a) | set(b)
        for k in keys:
            if k not in a or k not in b:
                # missing key is equal to an all-⊥ tensor of the same shape
                present = a.get(k, b.get(k))
                if _max_version(present) > 0:
                    return False
                continue
            if not _pair_eq(a[k], b[k]):
                return False
        return True

    def __hash__(self):  # pragma: no cover
        raise TypeError("unhashable")

    # -- delta-mutator -----------------------------------------------------------
    def write_delta(self, rank: int, name: str, new_values: Any,
                    chunk_idx: Optional[np.ndarray] = None,
                    chunk_size: Optional[int] = None) -> "TensorState":
        """δ-mutator: (re)write tensor ``name`` (or a subset of its chunks).

        Returns a delta containing ONLY the touched tensor, with touched
        chunks carrying a fresh version and untouched chunks at ⊥
        (version 0, zero values) — `X ⊔ delta` applies the write.
        """
        lam = self.lamport + 1
        ver = make_version(lam, rank)
        cur = self.as_dict().get(name)
        if cur is None:
            assert chunk_idx is None, "cannot partially write unknown tensor"
            assert chunk_size is not None
            ct = chunk_tensor(np.asarray(new_values), chunk_size, version=0)
            vals, vers = ct.values, jnp.full((ct.values.shape[0],), ver,
                                             dtype=VERSION_DTYPE)
            delta_ct = ChunkedTensor(vals, vers)
        else:
            if cur.is_sparse:   # writes need the dense addressing space
                cur = cur.to_dense()
            n_chunks, csz = cur.values.shape
            if chunk_idx is None:
                ct = chunk_tensor(np.asarray(new_values), csz)
                assert ct.values.shape == cur.values.shape
                delta_ct = ChunkedTensor(
                    ct.values, jnp.full((n_chunks,), ver, dtype=VERSION_DTYPE))
            else:
                idx = jnp.asarray(chunk_idx, dtype=jnp.int32)
                new_vals = jnp.asarray(new_values).reshape(len(chunk_idx), csz)
                vals = jnp.zeros_like(cur.values).at[idx].set(new_vals)
                vers = jnp.zeros((n_chunks,), dtype=VERSION_DTYPE).at[idx].set(ver)
                delta_ct = ChunkedTensor(vals, vers)
        return TensorState.of({name: delta_ct}, lamport=lam)

    def write_full(self, rank: int, name: str, new_values: Any,
                   chunk_idx: Optional[np.ndarray] = None,
                   chunk_size: Optional[int] = None) -> "TensorState":
        return self.join(self.write_delta(rank, name, new_values, chunk_idx,
                                          chunk_size))

    def decompose(self) -> list:
        """Per-tensor atoms (coarse join-decomposition) — lets the
        RemoveRedundant shipping policy drop tensors the receiver provably
        holds. Chunk-level trimming stays in ``pack_delta`` /
        ``digest_select`` (dense masks there, not one value per chunk)."""
        return [TensorState.of({name: ct}, lamport=self.lamport)
                for name, ct in self.chunks]


# -- digest-driven chunk selection --------------------------------------------

def chunk_digest_cached(ct) -> Tuple[np.ndarray, np.ndarray]:
    """Per-chunk (max|x|, Σx²) of a chunk tensor, memoized on the
    (immutable) tensor object. Joins reuse untouched keys' ``ct``
    objects, so across anti-entropy rounds only tensors that actually
    changed recompute their digest — the rest hit this cache. Sparse
    tensors memoize on their cached dense form. Runs via
    ``ops.chunk_digest_auto`` (compiled Pallas on TPU, the jitted XLA
    oracle elsewhere — identical math)."""
    from ..kernels import ops

    if ct.is_sparse:            # the digest ranks dense chunk positions
        ct = ct.to_dense()
    cached = ct.__dict__.get("_digest_cache")
    if cached is None:
        ma, ss = ops.chunk_digest_auto(ct.values)
        cached = (np.asarray(ma), np.asarray(ss))
        object.__setattr__(ct, "_digest_cache", cached)
    return cached


def digest_keep_plan(tensors, budget_bytes: int, interpret: bool = True):
    """The shared energy-ranked greedy selection behind ``digest_select``
    and ``store.digest_select_store``.

    ``tensors`` is an iterable of ``(scope, name, ChunkedTensor)`` (scope
    is the store key, or None for a single object). Per tensor,
    :func:`chunk_digest_cached` computes (max|x|, Σx²) per chunk in one
    pass over HBM — memoized per tensor object, so untouched keys never
    recompute; live chunks are ranked globally by Σx² (energy) and
    taken greedily until ``budget_bytes`` of chunk payload is spent.
    Chunks already at ⊥ never count against the budget. Returns None when
    everything fits, else ``{(scope, name): [kept chunk indices]}``.
    ``interpret`` is kept for API compatibility; the digest now always
    runs one fused dispatch per tensor (Pallas on TPU, the XLA oracle
    elsewhere — ``interpret=True``'s per-grid-step simulation added cost
    without changing a single output bit).
    """
    del interpret
    candidates = []   # (neg_energy, scope, name, chunk_idx, chunk_bytes)
    for scope, name, ct in tensors:
        if ct.is_sparse:        # the digest ranks dense chunk positions
            ct = ct.to_dense()
        vers = np.asarray(ct.versions)
        live = vers > 0
        if not live.any():
            continue
        _, sumsq = chunk_digest_cached(ct)
        per_chunk = (ct.values.dtype.itemsize * ct.values.shape[1]
                     + np.dtype(np.int64).itemsize + np.dtype(np.int32).itemsize)
        for i in np.nonzero(live)[0]:
            candidates.append((-float(sumsq[i]), scope, name, int(i),
                               per_chunk))

    if sum(c[4] for c in candidates) <= budget_bytes:
        return None

    keep: Dict[Tuple[Any, str], list] = {}
    spent = 0
    for neg_e, scope, name, i, nbytes in sorted(candidates):
        if spent + nbytes > budget_bytes:
            continue
        spent += nbytes
        keep.setdefault((scope, name), []).append(i)
    return keep


def mask_kept_chunks(ct, idx) -> ChunkedTensor:
    """Drop every chunk not in ``idx`` to ⊥ (version 0, zero values), so
    the result is ≤ the input in the lattice order and always safe to
    join."""
    if ct.is_sparse:
        ct = ct.to_dense()
    mask = np.zeros((ct.values.shape[0],), dtype=bool)
    mask[np.asarray(idx)] = True
    m = jnp.asarray(mask)
    vals = jnp.where(m[:, None], ct.values, jnp.zeros_like(ct.values))
    vers = jnp.where(m, ct.versions, jnp.zeros_like(ct.versions))
    return ChunkedTensor(vals, vers)


def digest_select(state: TensorState, budget_bytes: int,
                  interpret: bool = True) -> TensorState:
    """Keep only the top-magnitude chunks of ``state`` under a byte budget
    (see :func:`digest_keep_plan`) — the ``DigestBudget`` shipping
    policy's payload transform for single objects. If everything fits the
    input is returned unchanged."""
    tensors = state.as_dict()
    keep = digest_keep_plan(((None, name, ct) for name, ct in
                             tensors.items()), budget_bytes, interpret)
    if keep is None:
        return state
    out = {name: mask_kept_chunks(ct, keep[(None, name)])
           for name, ct in tensors.items() if keep.get((None, name))}
    return TensorState.of(out, lamport=state.lamport)


# -- wire format --------------------------------------------------------------

def pack_delta(delta: TensorState,
               known_versions: Optional[Mapping[str, np.ndarray]] = None
               ) -> Dict[str, Any]:
    """Sparse wire encoding: per tensor, only chunks with version above ⊥
    (and above the receiver's known version when supplied). This is the
    §4.1 ``size(mᵟ(X)) ≪ size(X)`` payload."""
    out: Dict[str, Any] = {"lamport": delta.lamport, "tensors": {}}
    for name, ct in delta.chunks:
        if ct.is_sparse:
            row_idx, vals, vers = _sp_live(ct)
            shape = ct.shape
            if known_versions and name in known_versions:
                keep = vers > np.asarray(known_versions[name])[row_idx]
                row_idx, vals, vers = row_idx[keep], vals[keep], vers[keep]
            if len(row_idx) == 0:
                continue
            out["tensors"][name] = (np.asarray(row_idx, dtype=np.int32),
                                    vals, vers, shape)
            continue
        vers = np.asarray(ct.versions)
        mask = vers > 0
        if known_versions and name in known_versions:
            mask &= vers > np.asarray(known_versions[name])
        idx = np.nonzero(mask)[0]
        if len(idx) == 0:
            continue
        out["tensors"][name] = (
            idx.astype(np.int32),
            np.asarray(ct.values)[idx],
            vers[idx],
            ct.values.shape,
        )
    return out


def unpack_delta(wire: Dict[str, Any], *, sparse: bool = True) -> TensorState:
    """Decode a :func:`pack_delta` message.

    ``sparse=True`` (default) keeps each tensor as a :class:`SparseChunks`
    row set — joining it into resident state is a gather/merge/scatter
    over the shipped rows only, so ingest costs O(shipped chunks).
    ``sparse=False`` restores the legacy behavior of materializing
    full-size zero-padded tensors (kept for dense-only consumers)."""
    chunks: Dict[str, Any] = {}
    for name, (idx, vals, vers, shape) in wire["tensors"].items():
        if sparse:
            chunks[name] = sparse_chunks(shape[0], idx, vals, vers)
            continue
        dense_v = np.zeros(shape, dtype=vals.dtype)
        dense_ver = np.zeros((shape[0],), dtype=np.int64)
        dense_v[idx] = vals
        dense_ver[idx] = vers
        chunks[name] = ChunkedTensor(jnp.asarray(dense_v),
                                     jnp.asarray(dense_ver))
    return TensorState.of(chunks, lamport=wire["lamport"])


def packed_size_bytes(wire: Dict[str, Any]) -> int:
    total = 8
    for name, (idx, vals, vers, _shape) in wire["tensors"].items():
        total += len(name) + idx.nbytes + vals.nbytes + vers.nbytes
    return total


# ---------------------------------------------------------------------------
# Additive dot-store (pseudo-gradient aggregation) + §7.2-style compression
# ---------------------------------------------------------------------------

def _tree_equal(a, b) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


@dataclass(frozen=True, eq=False)
class DotSumStore:
    """Grow-only map (producer, seq) → update pytree; join = union.

    The lattice of cross-pod additive updates. ``total()`` — the quantity
    the optimizer consumes — is the sum over all dots; because the store
    is a *set* of uniquely-tagged contributions, duplicated or reordered
    delivery cannot double-count (the paper's counter argument, §4.2).
    """

    dots: Tuple[Tuple[Tuple[str, int], Any], ...] = ()

    @staticmethod
    def bottom() -> "DotSumStore":
        return DotSumStore()

    def as_dict(self) -> Dict[Tuple[str, int], Any]:
        return dict(self.dots)

    def contribute_delta(self, producer: str, update: Any) -> "DotSumStore":
        """δ-mutator: a fresh uniquely-dotted contribution."""
        seq = 1 + max((s for (p, s), _ in self.dots if p == producer),
                      default=0)
        return DotSumStore((((producer, seq), update),))

    def contribute_full(self, producer: str, update: Any) -> "DotSumStore":
        return self.join(self.contribute_delta(producer, update))

    def join(self, other: "DotSumStore") -> "DotSumStore":
        merged = self.as_dict()
        for dot, upd in other.dots:
            if dot in merged:
                continue  # unique dots ⇒ identical payload
            merged[dot] = upd
        return DotSumStore(tuple(sorted(merged.items(),
                                        key=lambda kv: kv[0])))

    def decompose(self) -> list:
        """One atom per dot — RemoveRedundant trims re-gossiped dots the
        receiver has already acked."""
        return [DotSumStore((entry,)) for entry in self.dots]

    def leq(self, other: "DotSumStore") -> bool:
        od = other.as_dict()
        return all(dot in od for dot, _ in self.dots)

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, DotSumStore):
            return NotImplemented
        a, b = self.as_dict(), other.as_dict()
        return set(a) == set(b) and all(_tree_equal(a[k], b[k]) for k in a)

    def __hash__(self):  # pragma: no cover
        raise TypeError("unhashable")

    def total(self) -> Any:
        if not self.dots:
            return None
        acc = jax.tree_util.tree_map(lambda x: jnp.asarray(x),
                                     self.dots[0][1])
        for _, upd in self.dots[1:]:
            acc = jax.tree_util.tree_map(lambda a, b: a + jnp.asarray(b),
                                         acc, upd)
        return acc

    def version_vector(self) -> Dict[str, int]:
        vv: Dict[str, int] = {}
        for (p, s), _ in self.dots:
            vv[p] = max(vv.get(p, 0), s)
        return vv


class IntervalSum:
    """§7.2-compressed DotSumStore: (per-producer contiguous prefix, sum).

    NOT a free-standing semilattice — the sum cannot deduplicate arbitrary
    overlaps — but under Algorithm-2 delivery (delta-intervals aligned with
    the receiver's acked prefix: the causal delta-merging condition) it is
    an exact, O(1)-memory encoding of the dot store. ``apply_interval``
    enforces the condition and is idempotent for re-delivered intervals.
    """

    def __init__(self):
        self.prefix: Dict[str, int] = {}
        self.sum: Any = None

    def apply_interval(self, producer: str, start_seq: int,
                       updates: Iterable[Any]) -> bool:
        """Apply contributions ``start_seq .. start_seq+len-1`` from
        ``producer``. Returns True if applied; False if rejected (gap —
        the merging condition X ⊒ Xʲᵃ does not hold) or fully stale."""
        updates = list(updates)
        have = self.prefix.get(producer, 0)
        if start_seq - 1 > have:
            return False                      # gap: would skip dots
        end = start_seq + len(updates) - 1
        if end <= have:
            return True                       # duplicate: already absorbed
        fresh = updates[have - (start_seq - 1):]  # drop already-applied prefix
        for upd in fresh:
            if self.sum is None:
                self.sum = jax.tree_util.tree_map(
                    lambda x: jnp.asarray(x).copy(), upd)
            else:
                self.sum = jax.tree_util.tree_map(
                    lambda a, b: a + jnp.asarray(b), self.sum, upd)
        self.prefix[producer] = end
        return True

    def matches(self, ref: DotSumStore, atol: float = 1e-6) -> bool:
        """Exactness check against the reference dot store."""
        if ref.version_vector() != {p: n for p, n in self.prefix.items()
                                    if n > 0}:
            return False
        t = ref.total()
        if t is None or self.sum is None:
            return t is None and self.sum is None
        la = jax.tree_util.tree_leaves(t)
        lb = jax.tree_util.tree_leaves(self.sum)
        return all(np.allclose(np.asarray(a), np.asarray(b), atol=atol)
                   for a, b in zip(la, lb))
