"""Join-semilattices over JAX tensors — the δ-CRDT ⇄ training-state bridge.

Two lattices carry the framework's replicated ML state:

1. ``TensorState`` — a *versioned chunk store*: every tensor is split into
   fixed-size chunks, each tagged with a totally-ordered version
   ``(lamport_counter, writer_rank)`` packed into one int64. The join keeps,
   per chunk, the value with the larger version (pointwise LWW) — a
   join-semilattice because versions are unique per write and the order is
   total. This is the δ-CRDT the checkpointing and parameter-replication
   layers gossip: a *delta* is a TensorState containing only touched
   tensors, and the wire format (``pack_delta``) additionally drops
   untouched chunks. The hot join path (`masked version merge`, one pass
   over HBM) is the ``kernels/delta_join`` Pallas kernel on TPU; the jnp
   fallback below is the oracle and the CPU path.

2. ``DotSumStore`` — a grow-only map dot → update-pytree with join = union
   (unique dots ⇒ no conflicts): the additive lattice used for cross-pod
   pseudo-gradient aggregation (local-SGD / DiLoCo-style outer updates).
   Its value is ``sum of all dots``; duplicates and reordering are absorbed
   by the union. ``IntervalSum`` is its §7.2-style compression: under
   causal delta-interval delivery (Algorithm 2), the explicit dot cloud
   collapses to (version-vector, running sum) — property-tested equivalent
   to the reference store.

All lattice values implement ``join``/``leq``/``==`` so the generic
anti-entropy nodes in ``repro.core.antientropy`` run unchanged over them.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# version = (lamport << RANK_BITS) | writer_rank, stored in a jnp integer
# array. Without jax_enable_x64 jnp canonicalizes int64 → int32, so keep the
# rank field small enough that lamport gets ≥ 2^21 headroom (≈ 2M writes per
# tensor-chunk lifetime; checkpoints reset clocks). 1024 writer ranks covers
# pod-level replication (replicas are pods, not chips — see DESIGN.md §2).
RANK_BITS = 10
_RANK_MASK = (1 << RANK_BITS) - 1

# jnp canonical integer dtype for version arrays (int32 unless x64 enabled).
VERSION_DTYPE = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def make_version(lamport: int, rank: int) -> int:
    assert 0 <= rank < (1 << RANK_BITS)
    return (int(lamport) << RANK_BITS) | int(rank)


def version_lamport(v: int) -> int:
    return int(v) >> RANK_BITS


# ---------------------------------------------------------------------------
# Versioned chunk store
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class ChunkedTensor:
    """One tensor as [n_chunks, chunk_size] values + [n_chunks] int64 versions.

    Version 0 == ⊥ for that chunk (values must be zeros there).
    """

    values: jax.Array    # [n_chunks, chunk_size]
    versions: jax.Array  # [n_chunks] int64

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, ChunkedTensor):
            return NotImplemented
        return (self.values.shape == other.values.shape
                and bool(np.array_equal(np.asarray(self.versions),
                                        np.asarray(other.versions)))
                and bool(np.array_equal(np.asarray(self.values),
                                        np.asarray(other.values))))

    def __hash__(self):  # pragma: no cover
        raise TypeError("unhashable")


def _join_chunked_impl(av, avers, bv, bvers):
    """Pointwise LWW merge — the jnp oracle for kernels/delta_join."""
    take_b = bvers > avers
    out_v = jnp.where(take_b[:, None], bv, av)
    out_vers = jnp.maximum(avers, bvers)
    return out_v, out_vers


_join_chunked = jax.jit(_join_chunked_impl)


def chunk_tensor(x: np.ndarray, chunk_size: int,
                 version: int = 0) -> ChunkedTensor:
    flat = np.asarray(x).reshape(-1)
    pad = (-len(flat)) % chunk_size
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, flat.dtype)])
    vals = jnp.asarray(flat.reshape(-1, chunk_size))
    vers = jnp.full((vals.shape[0],), version, dtype=VERSION_DTYPE)
    return ChunkedTensor(vals, vers)


def unchunk(ct: ChunkedTensor, shape: Tuple[int, ...],
            dtype=None) -> jax.Array:
    n = int(np.prod(shape))
    flat = ct.values.reshape(-1)[:n]
    out = flat.reshape(shape)
    return out.astype(dtype) if dtype is not None else out


@dataclass(frozen=True, eq=False)
class TensorState:
    """The replicated-state lattice: name → ChunkedTensor (+ lamport clock).

    ``lamport`` is replica-local bookkeeping used to mint fresh versions; it
    rides along monotonically (max on join) and does not affect equality of
    the CRDT payload semantics (two replicas holding identical chunk data
    are converged regardless of their clocks — but we advance clocks on
    join so new writes always supersede everything observed).
    """

    chunks: Tuple[Tuple[str, ChunkedTensor], ...] = ()
    lamport: int = 0

    @staticmethod
    def bottom() -> "TensorState":
        return TensorState()

    @staticmethod
    def of(mapping: Mapping[str, ChunkedTensor], lamport: int = 0) -> "TensorState":
        return TensorState(tuple(sorted(mapping.items())), lamport)

    def as_dict(self) -> Dict[str, ChunkedTensor]:
        return dict(self.chunks)

    # -- lattice ----------------------------------------------------------------
    def join(self, other: "TensorState") -> "TensorState":
        a, b = self.as_dict(), other.as_dict()
        out: Dict[str, ChunkedTensor] = {}
        for k in set(a) | set(b):
            if k not in a:
                out[k] = b[k]
            elif k not in b:
                out[k] = a[k]
            else:
                v, vers = _join_chunked(a[k].values, a[k].versions,
                                        b[k].values, b[k].versions)
                out[k] = ChunkedTensor(v, vers)
        return TensorState.of(out, max(self.lamport, other.lamport))

    def leq(self, other: "TensorState") -> bool:
        a, b = self.as_dict(), other.as_dict()
        for k, ct in a.items():
            if k not in b:
                if int(jnp.max(ct.versions)) > 0:
                    return False
                continue
            if bool(jnp.any(ct.versions > b[k].versions)):
                return False
            # equal versions ⇒ equal values by construction (unique writes)
        return True

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, TensorState):
            return NotImplemented
        a, b = self.as_dict(), other.as_dict()
        keys = set(a) | set(b)
        for k in keys:
            if k not in a or k not in b:
                # missing key is equal to an all-⊥ tensor of the same shape
                present = a.get(k, b.get(k))
                if int(jnp.max(present.versions)) > 0:
                    return False
                continue
            if a[k] != b[k]:
                return False
        return True

    def __hash__(self):  # pragma: no cover
        raise TypeError("unhashable")

    # -- delta-mutator -----------------------------------------------------------
    def write_delta(self, rank: int, name: str, new_values: Any,
                    chunk_idx: Optional[np.ndarray] = None,
                    chunk_size: Optional[int] = None) -> "TensorState":
        """δ-mutator: (re)write tensor ``name`` (or a subset of its chunks).

        Returns a delta containing ONLY the touched tensor, with touched
        chunks carrying a fresh version and untouched chunks at ⊥
        (version 0, zero values) — `X ⊔ delta` applies the write.
        """
        lam = self.lamport + 1
        ver = make_version(lam, rank)
        cur = self.as_dict().get(name)
        if cur is None:
            assert chunk_idx is None, "cannot partially write unknown tensor"
            assert chunk_size is not None
            ct = chunk_tensor(np.asarray(new_values), chunk_size, version=0)
            vals, vers = ct.values, jnp.full((ct.values.shape[0],), ver,
                                             dtype=VERSION_DTYPE)
            delta_ct = ChunkedTensor(vals, vers)
        else:
            n_chunks, csz = cur.values.shape
            if chunk_idx is None:
                ct = chunk_tensor(np.asarray(new_values), csz)
                assert ct.values.shape == cur.values.shape
                delta_ct = ChunkedTensor(
                    ct.values, jnp.full((n_chunks,), ver, dtype=VERSION_DTYPE))
            else:
                idx = jnp.asarray(chunk_idx, dtype=jnp.int32)
                new_vals = jnp.asarray(new_values).reshape(len(chunk_idx), csz)
                vals = jnp.zeros_like(cur.values).at[idx].set(new_vals)
                vers = jnp.zeros((n_chunks,), dtype=VERSION_DTYPE).at[idx].set(ver)
                delta_ct = ChunkedTensor(vals, vers)
        return TensorState.of({name: delta_ct}, lamport=lam)

    def write_full(self, rank: int, name: str, new_values: Any,
                   chunk_idx: Optional[np.ndarray] = None,
                   chunk_size: Optional[int] = None) -> "TensorState":
        return self.join(self.write_delta(rank, name, new_values, chunk_idx,
                                          chunk_size))

    def decompose(self) -> list:
        """Per-tensor atoms (coarse join-decomposition) — lets the
        RemoveRedundant shipping policy drop tensors the receiver provably
        holds. Chunk-level trimming stays in ``pack_delta`` /
        ``digest_select`` (dense masks there, not one value per chunk)."""
        return [TensorState.of({name: ct}, lamport=self.lamport)
                for name, ct in self.chunks]


# -- digest-driven chunk selection --------------------------------------------

def digest_keep_plan(tensors, budget_bytes: int, interpret: bool = True):
    """The shared energy-ranked greedy selection behind ``digest_select``
    and ``store.digest_select_store``.

    ``tensors`` is an iterable of ``(scope, name, ChunkedTensor)`` (scope
    is the store key, or None for a single object). Per tensor,
    ``kernels.ops.chunk_digest`` computes (max|x|, Σx²) per chunk in one
    pass over HBM; live chunks are ranked globally by Σx² (energy) and
    taken greedily until ``budget_bytes`` of chunk payload is spent.
    Chunks already at ⊥ never count against the budget. Returns None when
    everything fits, else ``{(scope, name): [kept chunk indices]}``.
    """
    from ..kernels.ops import chunk_digest

    candidates = []   # (neg_energy, scope, name, chunk_idx, chunk_bytes)
    for scope, name, ct in tensors:
        vers = np.asarray(ct.versions)
        live = vers > 0
        if not live.any():
            continue
        _, sumsq = chunk_digest(ct.values, interpret=interpret)
        sumsq = np.asarray(sumsq)
        per_chunk = (ct.values.dtype.itemsize * ct.values.shape[1]
                     + np.dtype(np.int64).itemsize + np.dtype(np.int32).itemsize)
        for i in np.nonzero(live)[0]:
            candidates.append((-float(sumsq[i]), scope, name, int(i),
                               per_chunk))

    if sum(c[4] for c in candidates) <= budget_bytes:
        return None

    keep: Dict[Tuple[Any, str], list] = {}
    spent = 0
    for neg_e, scope, name, i, nbytes in sorted(candidates):
        if spent + nbytes > budget_bytes:
            continue
        spent += nbytes
        keep.setdefault((scope, name), []).append(i)
    return keep


def mask_kept_chunks(ct: ChunkedTensor, idx) -> ChunkedTensor:
    """Drop every chunk not in ``idx`` to ⊥ (version 0, zero values), so
    the result is ≤ the input in the lattice order and always safe to
    join."""
    mask = np.zeros((ct.values.shape[0],), dtype=bool)
    mask[np.asarray(idx)] = True
    m = jnp.asarray(mask)
    vals = jnp.where(m[:, None], ct.values, jnp.zeros_like(ct.values))
    vers = jnp.where(m, ct.versions, jnp.zeros_like(ct.versions))
    return ChunkedTensor(vals, vers)


def digest_select(state: TensorState, budget_bytes: int,
                  interpret: bool = True) -> TensorState:
    """Keep only the top-magnitude chunks of ``state`` under a byte budget
    (see :func:`digest_keep_plan`) — the ``DigestBudget`` shipping
    policy's payload transform for single objects. If everything fits the
    input is returned unchanged."""
    tensors = state.as_dict()
    keep = digest_keep_plan(((None, name, ct) for name, ct in
                             tensors.items()), budget_bytes, interpret)
    if keep is None:
        return state
    out = {name: mask_kept_chunks(ct, keep[(None, name)])
           for name, ct in tensors.items() if keep.get((None, name))}
    return TensorState.of(out, lamport=state.lamport)


# -- wire format --------------------------------------------------------------

def pack_delta(delta: TensorState,
               known_versions: Optional[Mapping[str, np.ndarray]] = None
               ) -> Dict[str, Any]:
    """Sparse wire encoding: per tensor, only chunks with version above ⊥
    (and above the receiver's known version when supplied). This is the
    §4.1 ``size(mᵟ(X)) ≪ size(X)`` payload."""
    out: Dict[str, Any] = {"lamport": delta.lamport, "tensors": {}}
    for name, ct in delta.chunks:
        vers = np.asarray(ct.versions)
        mask = vers > 0
        if known_versions and name in known_versions:
            mask &= vers > np.asarray(known_versions[name])
        idx = np.nonzero(mask)[0]
        if len(idx) == 0:
            continue
        out["tensors"][name] = (
            idx.astype(np.int32),
            np.asarray(ct.values)[idx],
            vers[idx],
            ct.values.shape,
        )
    return out


def unpack_delta(wire: Dict[str, Any]) -> TensorState:
    chunks: Dict[str, ChunkedTensor] = {}
    for name, (idx, vals, vers, shape) in wire["tensors"].items():
        dense_v = np.zeros(shape, dtype=vals.dtype)
        dense_ver = np.zeros((shape[0],), dtype=np.int64)
        dense_v[idx] = vals
        dense_ver[idx] = vers
        chunks[name] = ChunkedTensor(jnp.asarray(dense_v),
                                     jnp.asarray(dense_ver))
    return TensorState.of(chunks, lamport=wire["lamport"])


def packed_size_bytes(wire: Dict[str, Any]) -> int:
    total = 8
    for name, (idx, vals, vers, _shape) in wire["tensors"].items():
        total += len(name) + idx.nbytes + vals.nbytes + vers.nbytes
    return total


# ---------------------------------------------------------------------------
# Additive dot-store (pseudo-gradient aggregation) + §7.2-style compression
# ---------------------------------------------------------------------------

def _tree_equal(a, b) -> bool:
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    if ta != tb or len(la) != len(lb):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


@dataclass(frozen=True, eq=False)
class DotSumStore:
    """Grow-only map (producer, seq) → update pytree; join = union.

    The lattice of cross-pod additive updates. ``total()`` — the quantity
    the optimizer consumes — is the sum over all dots; because the store
    is a *set* of uniquely-tagged contributions, duplicated or reordered
    delivery cannot double-count (the paper's counter argument, §4.2).
    """

    dots: Tuple[Tuple[Tuple[str, int], Any], ...] = ()

    @staticmethod
    def bottom() -> "DotSumStore":
        return DotSumStore()

    def as_dict(self) -> Dict[Tuple[str, int], Any]:
        return dict(self.dots)

    def contribute_delta(self, producer: str, update: Any) -> "DotSumStore":
        """δ-mutator: a fresh uniquely-dotted contribution."""
        seq = 1 + max((s for (p, s), _ in self.dots if p == producer),
                      default=0)
        return DotSumStore((((producer, seq), update),))

    def contribute_full(self, producer: str, update: Any) -> "DotSumStore":
        return self.join(self.contribute_delta(producer, update))

    def join(self, other: "DotSumStore") -> "DotSumStore":
        merged = self.as_dict()
        for dot, upd in other.dots:
            if dot in merged:
                continue  # unique dots ⇒ identical payload
            merged[dot] = upd
        return DotSumStore(tuple(sorted(merged.items(),
                                        key=lambda kv: kv[0])))

    def decompose(self) -> list:
        """One atom per dot — RemoveRedundant trims re-gossiped dots the
        receiver has already acked."""
        return [DotSumStore((entry,)) for entry in self.dots]

    def leq(self, other: "DotSumStore") -> bool:
        od = other.as_dict()
        return all(dot in od for dot, _ in self.dots)

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, DotSumStore):
            return NotImplemented
        a, b = self.as_dict(), other.as_dict()
        return set(a) == set(b) and all(_tree_equal(a[k], b[k]) for k in a)

    def __hash__(self):  # pragma: no cover
        raise TypeError("unhashable")

    def total(self) -> Any:
        if not self.dots:
            return None
        acc = jax.tree_util.tree_map(lambda x: jnp.asarray(x),
                                     self.dots[0][1])
        for _, upd in self.dots[1:]:
            acc = jax.tree_util.tree_map(lambda a, b: a + jnp.asarray(b),
                                         acc, upd)
        return acc

    def version_vector(self) -> Dict[str, int]:
        vv: Dict[str, int] = {}
        for (p, s), _ in self.dots:
            vv[p] = max(vv.get(p, 0), s)
        return vv


class IntervalSum:
    """§7.2-compressed DotSumStore: (per-producer contiguous prefix, sum).

    NOT a free-standing semilattice — the sum cannot deduplicate arbitrary
    overlaps — but under Algorithm-2 delivery (delta-intervals aligned with
    the receiver's acked prefix: the causal delta-merging condition) it is
    an exact, O(1)-memory encoding of the dot store. ``apply_interval``
    enforces the condition and is idempotent for re-delivered intervals.
    """

    def __init__(self):
        self.prefix: Dict[str, int] = {}
        self.sum: Any = None

    def apply_interval(self, producer: str, start_seq: int,
                       updates: Iterable[Any]) -> bool:
        """Apply contributions ``start_seq .. start_seq+len-1`` from
        ``producer``. Returns True if applied; False if rejected (gap —
        the merging condition X ⊒ Xʲᵃ does not hold) or fully stale."""
        updates = list(updates)
        have = self.prefix.get(producer, 0)
        if start_seq - 1 > have:
            return False                      # gap: would skip dots
        end = start_seq + len(updates) - 1
        if end <= have:
            return True                       # duplicate: already absorbed
        fresh = updates[have - (start_seq - 1):]  # drop already-applied prefix
        for upd in fresh:
            if self.sum is None:
                self.sum = jax.tree_util.tree_map(
                    lambda x: jnp.asarray(x).copy(), upd)
            else:
                self.sum = jax.tree_util.tree_map(
                    lambda a, b: a + jnp.asarray(b), self.sum, upd)
        self.prefix[producer] = end
        return True

    def matches(self, ref: DotSumStore, atol: float = 1e-6) -> bool:
        """Exactness check against the reference dot store."""
        if ref.version_vector() != {p: n for p, n in self.prefix.items()
                                    if n > 0}:
            return False
        t = ref.total()
        if t is None or self.sum is None:
            return t is None and self.sum is None
        la = jax.tree_util.tree_leaves(t)
        lb = jax.tree_util.tree_leaves(self.sum)
        return all(np.allclose(np.asarray(a), np.asarray(b), atol=atol)
                   for a, b in zip(la, lb))
