"""Deterministic synthetic data pipeline.

Stateless token generation (counter-based hashing): batch ``i`` of a
stream is a pure function of ``(seed, i, rank)``, so

* every data-parallel rank reads a disjoint shard with no coordination;
* exact resume after crash/restart needs only the step counter already
  carried by the checkpoint (the paper's durable ``cᵢ``) — no loader
  state to persist;
* duplicated replays (at-least-once delivery after recovery) reproduce
  byte-identical batches, keeping replayed training deterministic.
"""

from .synthetic import ShardedTokenStream, SyntheticLMStream

__all__ = ["ShardedTokenStream", "SyntheticLMStream"]
