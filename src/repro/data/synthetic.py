"""Counter-based synthetic LM token streams (stateless, shardable)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def _philox_tokens(seed: int, stream: int, n: int, vocab: int) -> np.ndarray:
    """Deterministic tokens from a counter-based RNG (no sequential state)."""
    gen = np.random.Generator(np.random.Philox(key=seed, counter=[stream, 0, 0, 0]))
    return gen.integers(0, vocab, size=n, dtype=np.int64).astype(np.int32)


@dataclass
class SyntheticLMStream:
    """Markov-flavoured synthetic LM data: tokens with local structure so a
    model can actually reduce loss (pure uniform noise cannot be learned).

    token[t] = (token[t-1] + 1 + token[t-1] mod 7) mod vocab with sparse
    random resets — the next token is a DETERMINISTIC function of the
    previous one except at resets (P ≈ 1/97), so the achievable loss is
    ≈ ln(vocab)/97 ≈ 0.1 and a small model's curve visibly plunges within
    tens of steps (examples/train_delta_sync.py).
    """

    vocab: int
    seq: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int, rank: int = 0) -> Dict[str, np.ndarray]:
        stream = (step << 16) | rank
        raw = _philox_tokens(self.seed, stream,
                             self.batch * (self.seq + 1), self.vocab)
        raw = raw.reshape(self.batch, self.seq + 1)
        reset = (raw % 97) == 0              # occasional random jumps
        toks = np.zeros_like(raw)
        toks[:, 0] = raw[:, 0] % self.vocab
        for t in range(1, self.seq + 1):
            prev = toks[:, t - 1]
            stepped = (prev + 1 + prev % 7) % self.vocab
            toks[:, t] = np.where(reset[:, t], raw[:, t] % self.vocab, stepped)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class ShardedTokenStream:
    """Per-rank disjoint shard of a global stream: rank r of W reads
    global batch rows [r·b/W, (r+1)·b/W) — same data layout the sharded
    train_step consumes, generated locally with zero coordination."""

    base: SyntheticLMStream
    rank: int
    world: int

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        full = self.base.batch_at(step)
        b = self.base.batch
        assert b % self.world == 0
        lo = self.rank * (b // self.world)
        hi = lo + b // self.world
        return {k: v[lo:hi] for k, v in full.items()}
