"""Distribution layer: sharding assignment, HLO collective accounting,
roofline arithmetic.

Tier-0 of the two-tier distribution story (DESIGN.md §2): *inside* a pod,
synchronous SPMD over a jax mesh — this package maps logical parameter
axes to mesh axes (``shardings``), audits the collectives the partitioner
actually emitted (``hlo``), and turns compiled cost analyses into
per-chip roofline terms (``roofline``). Tier-1 — *across* pods — is the
δ-CRDT propagation runtime in ``repro.core`` / ``repro.sync``.
"""

from .hlo import collective_bytes, collective_count, cross_pod_bytes
from .roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, RooflineReport,
                       roofline)
from .shardings import (MeshRules, batch_pspecs, make_rules, named,
                        param_pspecs, spec_for)

__all__ = [
    "collective_bytes", "collective_count", "cross_pod_bytes",
    "HBM_BW", "ICI_BW", "PEAK_FLOPS", "RooflineReport", "roofline",
    "MeshRules", "batch_pspecs", "make_rules", "named", "param_pspecs",
    "spec_for",
]
