"""HLO collective parsing + ring-cost accounting.

Walks compiled-HLO text (``compiled.as_text()``) and, for every collective
op, derives the per-chip wire bytes from the result shape and the replica
group size under the standard ring algorithms:

    all-gather          result · (G-1)/G
    reduce-scatter      result · (G-1)        (input = result · G)
    all-reduce          2 · size · (G-1)/G    (reduce-scatter + all-gather)
    all-to-all          size · (G-1)/G
    collective-permute  size                  (one hop)

``-start`` variants count as the op; ``-done`` halves are skipped.

Replica groups come in two syntaxes:

* explicit   ``replica_groups={{0,1,2,3},{4,5,6,7}}``
* iota       ``replica_groups=[32,16]<=[512]`` or
             ``[16,32]<=[32,16]T(1,0)`` — reshape ``arange(prod)`` to the
             source shape, apply the transpose, flatten, regroup.

``cross_pod_bytes`` materializes the device lists and charges only
collectives whose groups span a pod boundary (device // pod_size differs
within a group) — the §Perf "cross-pod traffic" accounting.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")

_OP_RE = re.compile(
    r"\b(" + "|".join(sorted(_COLLECTIVES, key=len, reverse=True))
    + r")(-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([0-9,]*)\]")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")


def _result_bytes(line: str) -> Optional[float]:
    """Bytes of the first (result) shape on the line."""
    m = _SHAPE_RE.search(line)
    if not m or m.group(1) not in _DTYPE_BYTES:
        # tuple results like (f32[...], u32[...]): scan for the first
        # known dtype on the line
        for m in _SHAPE_RE.finditer(line):
            if m.group(1) in _DTYPE_BYTES:
                break
        else:
            return None
    dims = [int(d) for d in m.group(2).split(",") if d] or [1]
    return float(np.prod(dims)) * _DTYPE_BYTES[m.group(1)]


def _parse_groups(line: str, n_devices: int) -> Optional[np.ndarray]:
    """[n_groups, group_size] device array, or None for 'all devices'."""
    m = _IOTA_RE.search(line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        src = [int(d) for d in m.group(3).split(",")]
        devs = np.arange(int(np.prod(src))).reshape(src)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            devs = devs.transpose(perm)
        return devs.reshape(n_groups, group_size)
    m = _EXPLICIT_RE.search(line)
    if m:
        groups = [[int(d) for d in g.split(",") if d]
                  for g in re.findall(r"\{([^}]*)\}", m.group(1))]
        groups = [g for g in groups if g]
        if not groups:
            return None
        width = max(len(g) for g in groups)
        return np.asarray([g + g[-1:] * (width - len(g)) for g in groups])
    return None


def _group_size(line: str, n_devices: int) -> int:
    groups = _parse_groups(line, n_devices)
    if groups is None:
        return max(1, n_devices)
    return max(1, groups.shape[1])


def _ring_cost(kind: str, size: float, g: int) -> float:
    if g <= 1 and kind != "collective-permute":
        return 0.0
    if kind == "all-gather":
        return size * (g - 1) / g
    if kind == "reduce-scatter":
        return size * (g - 1)
    if kind == "all-reduce":
        return 2.0 * size * (g - 1) / g
    if kind == "all-to-all":
        return size * (g - 1) / g
    if kind in ("collective-permute", "collective-broadcast"):
        return size
    return 0.0  # pragma: no cover


def _iter_collectives(hlo: str):
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group(2) == "-done":
            continue
        yield m.group(1), line


def collective_bytes(hlo: str, n_devices: int) -> Tuple[float, Dict[str, float]]:
    """(total per-chip wire bytes, per-kind breakdown) for an HLO module."""
    per_kind: Dict[str, float] = {}
    total = 0.0
    for kind, line in _iter_collectives(hlo):
        size = _result_bytes(line)
        if size is None:
            continue
        cost = _ring_cost(kind, size, _group_size(line, n_devices))
        per_kind[kind] = per_kind.get(kind, 0.0) + cost
        total += cost
    return total, per_kind


def collective_count(hlo: str) -> Dict[str, int]:
    """Number of collective ops by kind (async pairs counted once)."""
    counts: Dict[str, int] = {}
    for kind, _line in _iter_collectives(hlo):
        counts[kind] = counts.get(kind, 0) + 1
    return counts


def cross_pod_bytes(hlo: str, n_devices: int, pod_size: int) -> float:
    """Per-chip wire bytes of collectives whose replica groups span a pod
    boundary (membership-aware: a group entirely inside one pod is free)."""
    total = 0.0
    for kind, line in _iter_collectives(hlo):
        size = _result_bytes(line)
        if size is None:
            continue
        groups = _parse_groups(line, n_devices)
        if groups is None:
            spans = n_devices > pod_size
            g = max(1, n_devices)
        else:
            pods = groups // pod_size
            spans = bool((pods != pods[:, :1]).any())
            g = groups.shape[1]
        if spans:
            total += _ring_cost(kind, size, g)
    return total
