"""Roofline arithmetic for the dry-run cells.

Three per-chip time terms from the compiled module's cost analysis:

    compute_s     HLO flops / PEAK_FLOPS
    memory_s      HLO bytes-accessed / HBM_BW
    collective_s  ring wire bytes (dist.hlo) / ICI_BW

The step is bound by the largest term; ``useful_frac`` is the model-flops
share of executed flops (rematerialization, padding, and fallback gathers
dilute it); ``roofline_frac`` is useful compute time over the bound time —
the headline "fraction of the roofline we reach".

Hardware constants are one TPU-v4-class chip; override per call if
modelling different silicon.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12   # bf16 FLOP/s per chip
HBM_BW = 819e9        # HBM bytes/s per chip
ICI_BW = 50e9         # interconnect bytes/s per chip


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    useful_frac: float
    roofline_frac: float
    step_s: float
    tokens_per_s: float
    peak_memory_gb: Optional[float] = None
    collective_breakdown_s: Dict[str, float] = field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


def roofline(arch: str, shape: str, mesh: str, chips: int,
             cost: Dict[str, float], wire_bytes: float,
             per_kind: Dict[str, float], model_flops_total: float,
             tokens: int,
             peak_memory: Optional[float] = None,
             peak_flops: float = PEAK_FLOPS,
             hbm_bw: float = HBM_BW,
             ici_bw: float = ICI_BW) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / peak_flops
    memory_s = bytes_accessed / hbm_bw
    collective_s = float(wire_bytes) / ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bound = max(terms, key=lambda k: terms[k])
    step_s = terms[bound]
    useful_frac = (model_flops_total / (flops * chips)
                   if flops > 0 and chips > 0 else 0.0)
    roofline_frac = (compute_s * useful_frac / step_s) if step_s > 0 else 0.0
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bound=bound, useful_frac=useful_frac, roofline_frac=roofline_frac,
        step_s=step_s,
        tokens_per_s=(tokens / step_s) if step_s > 0 else 0.0,
        peak_memory_gb=(peak_memory / 1e9
                        if peak_memory is not None else None),
        collective_breakdown_s={k: v / ici_bw
                                for k, v in (per_kind or {}).items()},
    )
