"""Logical-axis → mesh-axis assignment with divisibility fallbacks.

Models annotate every parameter dimension with a *logical* name
("embed", "mlp", "heads", "kv", "vocab", "expert", "lora", …; see
``repro.models.layers``). ``MeshRules`` maps each name to an ordered list
of candidate mesh-axis tuples; ``spec_for`` greedily assigns, per tensor:

* dims are visited left-to-right; each mesh axis is used at most once per
  tensor;
* a candidate is taken only when the dim size is divisible by the product
  of the candidate's mesh-axis sizes (GSPMD would otherwise pad);
* when no candidate fits, the dim replicates and the miss is recorded in
  ``rules.fallbacks`` (surfaced in the dry-run artifacts).

``make_rules`` builds the production rule table for a mesh (FSDP embed
over the batch axes; tensor-parallel model axis for vocab/mlp/heads/kv/
expert; MLA latents replicated). ``serve=True`` empties the FSDP
candidates so parameters replicate over the batch axes at inference (used
when the model-sharded copy fits per chip — see launch.dryrun).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass
class MeshRules:
    mesh: Any                                     # needs .shape mapping
    batch_axes: Tuple[str, ...]
    candidates: Dict[str, List[Tuple[str, ...]]]
    fallbacks: List[str] = field(default_factory=list)


def _axes_size(mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    return size


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             rules: MeshRules) -> P:
    """Greedy one-axis-per-tensor assignment for one parameter."""
    used: set = set()
    entries: List[Any] = []
    for dim, name in zip(shape, logical):
        cands = rules.candidates.get(name, []) if name else []
        assigned: Optional[Tuple[str, ...]] = None
        missed = False
        for cand in cands:
            axes = tuple(cand)
            if any(a in used for a in axes):
                continue             # axis already carries another dim
            if dim % _axes_size(rules.mesh, axes) != 0:
                missed = True        # GSPMD would pad — try the next
                continue
            assigned = axes
            break
        if assigned is None:
            if missed:
                rules.fallbacks.append(
                    f"{name}{tuple(shape)}: dim {dim} not divisible — "
                    f"replicated")
            entries.append(None)
            continue
        if missed:
            rules.fallbacks.append(
                f"{name}{tuple(shape)}: dim {dim} fell back to "
                f"{assigned}")
        used.update(assigned)
        entries.append(assigned[0] if len(assigned) == 1 else assigned)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def make_rules(mesh, serve: bool = False) -> MeshRules:
    """The production rule table for ``mesh`` (axes: [pod,] data, model)."""
    multi_pod = "pod" in mesh.shape
    batch = ("pod", "data") if multi_pod else ("data",)
    fsdp: List[Tuple[str, ...]] = [] if serve else (
        [("pod", "data"), ("data",)] if multi_pod else [("data",)])
    return MeshRules(
        mesh=mesh,
        batch_axes=batch,
        candidates={
            "vocab": [("model",)],
            "embed": fsdp,
            "mlp": [("model",)],
            "heads": [("model",)],
            "kv": [("model",)],
            "expert": [("model",)],
            "lora": [],
            "layers": [],
        },
    )


def param_pspecs(params: Any, logical: Any, rules: MeshRules) -> Any:
    """PartitionSpec tree for a parameter tree + its logical-name tree."""

    def one(p, names):
        shape = tuple(p.shape)
        names = tuple(names) if names is not None else ()
        if len(names) < len(shape):
            names = names + (None,) * (len(shape) - len(names))
        return spec_for(shape, names[:len(shape)], rules)

    return jax.tree_util.tree_map(one, params, logical)


def batch_pspecs(batch: Any, rules: MeshRules) -> Any:
    """Shard the leading (batch) dim of every input leaf over the batch
    axes; anything not divisible (or scalar) replicates."""
    total = _axes_size(rules.mesh, rules.batch_axes)
    ax = (rules.batch_axes[0] if len(rules.batch_axes) == 1
          else tuple(rules.batch_axes))

    def one(x):
        shape = tuple(getattr(x, "shape", ()))
        if not shape or shape[0] % total != 0:
            return P()
        return P(ax)

    return jax.tree_util.tree_map(one, batch)


def named(pspecs: Any, mesh) -> Any:
    """Wrap a PartitionSpec tree in NamedShardings on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))
