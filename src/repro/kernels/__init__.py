"""Pallas TPU kernels for the framework's compute hot-spots.

* ``flash_attention`` — fused online-softmax attention (train/prefill fwd
  + ring-cache decode with explicit slot positions). VMEM-tiled BlockSpecs,
  GQA head mapping in the index maps, static skipping of fully-masked
  tiles.
* ``delta_join`` / ``chunk_digest`` — the δ-CRDT tensor-lattice join
  (versioned-chunk LWW merge, the paper's hot loop at TPU scale: purely
  bandwidth-bound, fused to ONE pass over HBM) and the per-chunk digests
  the anti-entropy layer uses to pick delta contents.

``ops`` carries the jit'd public wrappers (``interpret=`` for CPU
validation); ``ref`` the pure-jnp oracles every kernel is swept against in
tests/test_kernels_*.py.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
