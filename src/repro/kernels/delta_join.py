"""δ-CRDT versioned-chunk join + chunk digest (Pallas TPU kernels).

These are the paper's hot loops at TPU scale. When a pod joins a received
delta (possibly multi-GB of parameter chunks) into resident state, the
naive XLA lowering is a compare → broadcast-select → max chain, i.e. three
passes over HBM. The join is purely bandwidth-bound (arithmetic intensity
≈ 0), so fusing it into ONE tiled pass over HBM is the whole optimization:

* ``delta_join``   — out[i] = b[i] if b_ver[i] > a_ver[i] else a[i];
                     out_ver = max(a_ver, b_ver). One load of each operand
                     tile into VMEM, one store. Tiles (block_n × chunk) are
                     (8·k, 128·m)-aligned.
* ``chunk_digest`` — per-chunk max|x| and Σx² in one pass; the anti-entropy
                     layer uses digests to pick which chunks enter the next
                     delta (top-magnitude shipping) without a second sweep
                     over the tensor.
* ``fused_join_digest`` — join + digest of the merged result in the same
                     pass: the merged tile is already in VMEM, so the next
                     round's chunk ranking costs no extra HBM traffic.
* ``scatter_join``  — sparse ingest: a prefetched index column drives the
                     grid over *delta* rows, merging each shipped row into
                     the resident stacked columns (and refreshing its
                     digest row) in place via ``input_output_aliases`` —
                     O(shipped rows) touched, O(1) launches, regardless of
                     store size. The device half of ``kernels/resident``.

jnp oracles in ``ref.py``; jit'd wrappers with ``interpret=`` in ``ops.py``.
"""

from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad_rows(x: jax.Array, pad: int) -> jax.Array:
    """Zero-pad the leading (chunk-count) axis by ``pad`` rows. Padded
    versions are 0 == ⊥, so padded rows never win a merge and the digest
    of a padded row is 0; outputs are sliced back to the true length."""
    if pad == 0:
        return x
    return jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))


def _join_kernel(av_ref, aver_ref, bv_ref, bver_ref, ov_ref, over_ref):
    a_ver = aver_ref[...]              # [bn]
    b_ver = bver_ref[...]
    take_b = b_ver > a_ver
    ov_ref[...] = jnp.where(take_b[:, None], bv_ref[...], av_ref[...])
    over_ref[...] = jnp.maximum(a_ver, b_ver)


def delta_join(a_vals: jax.Array, a_vers: jax.Array,
               b_vals: jax.Array, b_vers: jax.Array,
               block_n: int = 256,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """a_vals, b_vals [n, chunk]; a_vers, b_vers [n] int32.

    ``n`` need not be a multiple of the block size: ragged chunk counts
    are zero-padded to the block boundary (⊥ versions) and sliced back.
    """
    n, chunk = a_vals.shape
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        a_vals, a_vers, b_vals, b_vers = (
            _pad_rows(x, pad) for x in (a_vals, a_vers, b_vals, b_vers))
    np_ = n + pad
    grid = (np_ // bn,)
    ov, over = pl.pallas_call(
        _join_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, chunk), a_vals.dtype),
            jax.ShapeDtypeStruct((np_,), a_vers.dtype),
        ],
        interpret=interpret,
    )(a_vals, a_vers, b_vals, b_vers)
    return (ov[:n], over[:n]) if pad else (ov, over)


def batched_delta_join(segments: Sequence[Tuple[jax.Array, jax.Array,
                                                jax.Array, jax.Array]],
                       block_n: int = 256, interpret: bool = False,
                       join_fn=None, host_stage: bool = False,
                       host_join_fn=None
                       ) -> List[Tuple[jax.Array, jax.Array]]:
    """Join many independent versioned-chunk segments in as few kernel
    launches as possible.

    ``segments`` is a sequence of ``(a_vals, a_vers, b_vals, b_vers)``
    tuples (each ``[n_s, chunk_s]`` / ``[n_s]``). Segments sharing a
    (chunk width, value dtype, version dtype) signature are concatenated
    along the chunk axis into ONE stacked launch — the merge is pointwise
    per chunk, so stacking chunks from many ``TensorState`` objects is
    exact — and the outputs are split back per segment. This replaces one
    jit dispatch *per object* with one launch *per signature*, which is
    the objects/sec win for keyed stores holding thousands of tensors.

    ``host_stage=True`` routes the glue through host numpy — near
    zero-copy for CPU-backed arrays, where ``jnp.concatenate`` over
    thousands of operands dominates — runs ONE single-grid-step launch
    per signature (``host_join_fn(a_vals, a_vers, b_vals, b_vers, rows)``,
    default: :func:`delta_join` with ``block_n=rows``) and returns the
    per-segment outputs as numpy views into the stacked result. Use on
    CPU; keep the default on-device path on TPU.

    ``join_fn`` overrides the two-operand join of the on-device path
    (e.g. the jit'd wrapper in ``kernels.ops``); defaults to
    :func:`delta_join`. Returns ``(out_vals, out_vers)`` per segment, in
    input order.
    """
    import numpy as np

    if join_fn is None:
        join_fn = functools.partial(delta_join, block_n=block_n,
                                    interpret=interpret)
    if host_join_fn is None:
        host_join_fn = lambda av, avr, bv, bvr, rows: delta_join(
            av, avr, bv, bvr, block_n=rows, interpret=interpret)
    results: List[Tuple[jax.Array, jax.Array]] = [None] * len(segments)
    groups = {}
    for i, (av, avr, bv, bvr) in enumerate(segments):
        sig = (av.shape[1], jnp.dtype(av.dtype), jnp.dtype(avr.dtype))
        groups.setdefault(sig, []).append(i)
    for sig, idxs in groups.items():
        if len(idxs) == 1 and not host_stage:
            results[idxs[0]] = join_fn(*segments[idxs[0]])
            continue
        sizes = [segments[i][0].shape[0] for i in idxs]
        if host_stage:
            cat = [np.concatenate([np.asarray(segments[i][j])
                                   for i in idxs], axis=0)
                   for j in range(4)]
            ov, over = host_join_fn(*cat, cat[0].shape[0])
            ov, over = np.asarray(ov), np.asarray(over)
        else:
            cat = [jnp.concatenate([segments[i][j] for i in idxs], axis=0)
                   for j in range(4)]
            ov, over = join_fn(*cat)
        start = 0
        for i, n_s in zip(idxs, sizes):
            results[i] = (ov[start:start + n_s], over[start:start + n_s])
            start += n_s
    return results


def _fused_join_digest_kernel(av_ref, aver_ref, bv_ref, bver_ref,
                              ov_ref, over_ref, ma_ref, ss_ref):
    a_ver = aver_ref[...]              # [bn]
    b_ver = bver_ref[...]
    take_b = b_ver > a_ver
    merged = jnp.where(take_b[:, None], bv_ref[...], av_ref[...])
    ov_ref[...] = merged
    over_ref[...] = jnp.maximum(a_ver, b_ver)
    mf = merged.astype(jnp.float32)
    ma_ref[...] = jnp.max(jnp.abs(mf), axis=-1)
    ss_ref[...] = jnp.sum(mf * mf, axis=-1)


def fused_join_digest(a_vals: jax.Array, a_vers: jax.Array,
                      b_vals: jax.Array, b_vers: jax.Array,
                      block_n: int = 256, interpret: bool = False
                      ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """:func:`delta_join` and :func:`chunk_digest` of the merged result in
    ONE pass over HBM: ``(out_vals, out_vers, max|out| per chunk,
    Σout² per chunk)``. The anti-entropy hot loop needs the digest of the
    state it just joined (to pick the next delta's chunks), and the merged
    tile is already in VMEM — a separate digest launch would re-read the
    whole store from HBM for two scalars per row. Ragged ``n`` is
    zero-padded (⊥ versions ⇒ zero digest) and sliced back."""
    n, chunk = a_vals.shape
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        a_vals, a_vers, b_vals, b_vers = (
            _pad_rows(x, pad) for x in (a_vals, a_vers, b_vals, b_vers))
    np_ = n + pad
    ov, over, ma, ss = pl.pallas_call(
        _fused_join_digest_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, chunk), a_vals.dtype),
            jax.ShapeDtypeStruct((np_,), a_vers.dtype),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(a_vals, a_vers, b_vals, b_vers)
    if pad:
        return ov[:n], over[:n], ma[:n], ss[:n]
    return ov, over, ma, ss


def _scatter_join_kernel(idx_ref, dv_ref, dver_ref, av_ref, aver_ref,
                         ama_ref, ass_ref, ov_ref, over_ref, oma_ref,
                         oss_ref):
    del idx_ref, ama_ref, ass_ref      # consumed by the index maps/aliases
    a_ver = aver_ref[0]
    b_ver = dver_ref[0]
    take = b_ver > a_ver
    merged = jnp.where(take, dv_ref[...], av_ref[...])   # [1, chunk]
    ov_ref[...] = merged
    over_ref[0] = jnp.maximum(a_ver, b_ver)
    mf = merged.astype(jnp.float32)
    oma_ref[0] = jnp.max(jnp.abs(mf))
    oss_ref[0] = jnp.sum(mf * mf)


def scatter_join(vals: jax.Array, vers: jax.Array,
                 maxabs: jax.Array, sumsq: jax.Array,
                 idx: jax.Array, d_vals: jax.Array, d_vers: jax.Array,
                 interpret: bool = False
                 ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Scatter-merge ``r`` sparse delta rows into resident columns and
    refresh the touched rows' digest, all in ONE launch.

    ``vals [n, chunk]`` / ``vers [n]`` are the resident stacked columns,
    ``maxabs`` / ``sumsq`` ``[n] f32`` their per-chunk digest columns;
    ``idx [r] int32`` are the (unique) target row positions and
    ``d_vals [r, chunk]`` / ``d_vers [r]`` the shipped rows. The grid
    walks the *delta* rows — the prefetched ``idx`` drives the resident
    block index maps, so the kernel touches O(r) rows of state no matter
    how large the store is — and ``input_output_aliases`` carries every
    untouched row through unchanged (on TPU the update happens in the
    resident buffers; no O(n) copy). Duplicate positions are permitted
    only when their merged content is identical (the pad-row convention:
    ⊥-versioned pad rows re-write a row's existing content)."""
    n, chunk = vals.shape
    r = int(idx.shape[0])
    if r == 0:
        return vals, vers, maxabs, sumsq
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, idx: (i, 0)),        # d_vals
            pl.BlockSpec((1,), lambda i, idx: (i,)),                # d_vers
            pl.BlockSpec((1, chunk), lambda i, idx: (idx[i], 0)),   # vals
            pl.BlockSpec((1,), lambda i, idx: (idx[i],)),           # vers
            pl.BlockSpec((1,), lambda i, idx: (idx[i],)),           # maxabs
            pl.BlockSpec((1,), lambda i, idx: (idx[i],)),           # sumsq
        ],
        out_specs=[
            pl.BlockSpec((1, chunk), lambda i, idx: (idx[i], 0)),
            pl.BlockSpec((1,), lambda i, idx: (idx[i],)),
            pl.BlockSpec((1,), lambda i, idx: (idx[i],)),
            pl.BlockSpec((1,), lambda i, idx: (idx[i],)),
        ],
    )
    return pl.pallas_call(
        _scatter_join_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, chunk), vals.dtype),
            jax.ShapeDtypeStruct((n,), vers.dtype),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        # operand order counts the prefetched idx as input 0: vals=3,
        # vers=4, maxabs=5, sumsq=6 alias onto the four outputs so rows
        # no grid step covers keep their resident values
        input_output_aliases={3: 0, 4: 1, 5: 2, 6: 3},
        interpret=interpret,
    )(idx, d_vals, d_vers, vals, vers, maxabs, sumsq)


def _digest_kernel(x_ref, maxabs_ref, sumsq_ref):
    x = x_ref[...].astype(jnp.float32)          # [bn, chunk]
    maxabs_ref[...] = jnp.max(jnp.abs(x), axis=-1)
    sumsq_ref[...] = jnp.sum(x * x, axis=-1)


def chunk_digest(x: jax.Array, block_n: int = 256,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x [n, chunk] → (max|x| per chunk [n], Σx² per chunk [n]).
    Ragged ``n`` is zero-padded to the block boundary and sliced back."""
    n, chunk = x.shape
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        x = _pad_rows(x, pad)
    np_ = n + pad
    ma, ss = pl.pallas_call(
        _digest_kernel,
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((bn, chunk), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_,), jnp.float32),
            jax.ShapeDtypeStruct((np_,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return (ma[:n], ss[:n]) if pad else (ma, ss)
