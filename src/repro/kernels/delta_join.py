"""δ-CRDT versioned-chunk join + chunk digest (Pallas TPU kernels).

These are the paper's hot loops at TPU scale. When a pod joins a received
delta (possibly multi-GB of parameter chunks) into resident state, the
naive XLA lowering is a compare → broadcast-select → max chain, i.e. three
passes over HBM. The join is purely bandwidth-bound (arithmetic intensity
≈ 0), so fusing it into ONE tiled pass over HBM is the whole optimization:

* ``delta_join``   — out[i] = b[i] if b_ver[i] > a_ver[i] else a[i];
                     out_ver = max(a_ver, b_ver). One load of each operand
                     tile into VMEM, one store. Tiles (block_n × chunk) are
                     (8·k, 128·m)-aligned.
* ``chunk_digest`` — per-chunk max|x| and Σx² in one pass; the anti-entropy
                     layer uses digests to pick which chunks enter the next
                     delta (top-magnitude shipping) without a second sweep
                     over the tensor.

jnp oracles in ``ref.py``; jit'd wrappers with ``interpret=`` in ``ops.py``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _join_kernel(av_ref, aver_ref, bv_ref, bver_ref, ov_ref, over_ref):
    a_ver = aver_ref[...]              # [bn]
    b_ver = bver_ref[...]
    take_b = b_ver > a_ver
    ov_ref[...] = jnp.where(take_b[:, None], bv_ref[...], av_ref[...])
    over_ref[...] = jnp.maximum(a_ver, b_ver)


def delta_join(a_vals: jax.Array, a_vers: jax.Array,
               b_vals: jax.Array, b_vers: jax.Array,
               block_n: int = 256,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """a_vals, b_vals [n, chunk]; a_vers, b_vers [n] int32."""
    n, chunk = a_vals.shape
    bn = min(block_n, n)
    assert n % bn == 0, (n, bn)
    grid = (n // bn,)
    return pl.pallas_call(
        _join_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn, chunk), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, chunk), a_vals.dtype),
            jax.ShapeDtypeStruct((n,), a_vers.dtype),
        ],
        interpret=interpret,
    )(a_vals, a_vers, b_vals, b_vers)


def _digest_kernel(x_ref, maxabs_ref, sumsq_ref):
    x = x_ref[...].astype(jnp.float32)          # [bn, chunk]
    maxabs_ref[...] = jnp.max(jnp.abs(x), axis=-1)
    sumsq_ref[...] = jnp.sum(x * x, axis=-1)


def chunk_digest(x: jax.Array, block_n: int = 256,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x [n, chunk] → (max|x| per chunk [n], Σx² per chunk [n])."""
    n, chunk = x.shape
    bn = min(block_n, n)
    assert n % bn == 0
    return pl.pallas_call(
        _digest_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, chunk), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
