"""Flash attention for TPU (Pallas): fused online-softmax attention.

Two kernels:

* ``flash_attention_fwd`` — train/prefill: causal (+ optional sliding
  window, logit softcap) attention over [b, h, s, hd] with GQA head
  mapping done in the BlockSpec index maps (no materialized kv repeat).
  Grid = (b, q_heads, nq, nk); the innermost nk dimension iterates
  sequentially on TPU, carrying the online-softmax state (m, l, acc) in
  VMEM scratch. Fully-masked (q-block, k-block) tiles are skipped — for
  causal attention that's ~half the tiles, and with a sliding window all
  tiles outside the band.

* ``flash_decode_fwd`` — single-token decode against a (ring-buffer) KV
  cache with *explicit per-slot positions* (supports full caches and SWA
  ring caches uniformly, matching ``models/attention.py`` semantics).

VMEM budget per grid step (defaults bq=bk=128, hd=128, fp32 scratch):
q/k/v tiles ≈ 3·128·128·2B = 96 KiB + acc/m/l ≈ 66 KiB — comfortably
inside the ~16 MiB VMEM of a TPU core, with room for double-buffering.
Block sizes are multiples of (8, 128) so the MXU/VPU tiles are aligned.

The pure-jnp oracle lives in ``ref.py``; ``ops.py`` exposes jit'd wrappers
with an ``interpret=`` switch (CPU validation — this container has no TPU).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# Train / prefill kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, window: Optional[int],
                softcap: Optional[float], bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: tile entirely above the diagonal, or entirely
    # outside the sliding-window band
    q_start = iq * bq
    k_start = ik * bk
    not_above = k_start <= q_start + (bq - 1)           # some k ≤ some q
    in_band = True if window is None else \
        (q_start - (k_start + bk - 1)) < window          # some q-k < window
    live = jnp.logical_and(not_above, in_band) if window is not None \
        else not_above

    @pl.when(live)
    def _work():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos <= qpos
        if window is not None:
            mask = jnp.logical_and(mask, (qpos - kpos) < window)
        s_masked = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s_masked, axis=-1))
        alpha = jnp.exp(m_prev - m_new)                  # ≤ 1, no NaN (finite)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0, :, :] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: Optional[float] = None,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q [b, h, sq, hd]; k, v [b, kv, sk, hd] (GQA: h % kv == 0). Causal."""
    b, h, sq, hd = q.shape
    _, kv, sk, _ = k.shape
    assert h % kv == 0
    G = h // kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    nq, nk = sq // bq, sk // bk
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)

    kernel = functools.partial(_fwd_kernel, scale=scale, window=window,
                               softcap=softcap, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda ib, ih, iq, ik: (ib, ih // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda ib, ih, iq, ik: (ib, ih // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Decode kernel (explicit per-slot positions — ring caches)
# ---------------------------------------------------------------------------

def _decode_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, window: Optional[int],
                   softcap: Optional[float], bk: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                  # [1, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = qpos_ref[0, 0]                                # scalar int32
    kpos = kpos_ref[0]                                   # [bk]
    mask = jnp.logical_and(kpos >= 0, kpos <= qpos)
    if window is not None:
        mask = jnp.logical_and(mask, (qpos - kpos) < window)
    mask = mask[None, :]
    s_masked = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_masked, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0, :, :] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_decode_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_pos: jax.Array, k_pos: jax.Array, *,
                     scale: Optional[float] = None,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     block_k: int = 128,
                     interpret: bool = False) -> jax.Array:
    """q [b, h, 1, hd]; k, v [b, kv, C, hd]; q_pos [b, 1]; k_pos [b, C]."""
    b, h, one, hd = q.shape
    assert one == 1
    _, kv, C, _ = k.shape
    G = h // kv
    bk = min(block_k, C)
    assert C % bk == 0
    nk = C // bk
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               softcap=softcap, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, 0)),
            pl.BlockSpec((1, bk), lambda ib, ih, ik: (ib, ik)),
            pl.BlockSpec((1, 1, 1, hd), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda ib, ih, ik: (ib, ih // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda ib, ih, ik: (ib, ih // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd),
                               lambda ib, ih, ik: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
