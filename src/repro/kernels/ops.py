"""jit'd public wrappers for the Pallas kernels.

``interpret=`` selects Pallas interpret mode (CPU validation; this
container has no TPU). On TPU hardware call with ``interpret=False``.
``use_pallas_default()`` is consulted by the model stack: XLA fallbacks
(the same math, from the oracles) are used for the 512-device dry-run,
because a TPU Mosaic kernel does not compile on the CPU backend. The new
resident-store wrappers (:func:`fused_join_digest`, :func:`scatter_join`,
:func:`chunk_digest_auto`) bake that dispatch in: ``interpret=None``
means "compiled Pallas on TPU, the jitted XLA oracle elsewhere" — the
oracle is the identical math in one fused XLA dispatch, so the CPU path
keeps the launch-count story honest without paying interpret mode's
per-grid-step simulation cost on the hot path.

Every wrapper also feeds :data:`counters` — process-wide accounting of
kernel launches and host↔device staging bytes. A numpy operand handed to
a launch models one host→device upload of its ``nbytes`` (on a real
accelerator that is exactly what happens; on the CPU backend it is the
same bytes crossing the staging boundary); a jax.Array operand counts
zero, which is what makes the device-resident store measurable: its
steady-state rounds launch O(1) kernels over arrays that never leave the
device. Benchmarks snapshot/diff the counters around each round.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .delta_join import batched_delta_join as _batched_delta_join
from .delta_join import chunk_digest as _chunk_digest
from .delta_join import delta_join as _delta_join
from .delta_join import fused_join_digest as _fused_join_digest
from .delta_join import scatter_join as _scatter_join
from .flash_attention import flash_attention_fwd as _flash_fwd
from .flash_attention import flash_decode_fwd as _flash_decode


def use_pallas_default() -> bool:
    """Whether the Mosaic Pallas kernels compile on the current backend.
    On TPU call the kernels with ``interpret=False``; elsewhere (this
    container: CPU) use interpret mode / the XLA oracles."""
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Launch / transfer accounting
# ---------------------------------------------------------------------------

class KernelCounters:
    """Process-wide kernel-launch and host↔device byte accounting.

    ``launches`` counts wrapper-level kernel dispatches (one fused
    pipeline == one launch, however many outputs it writes).
    ``h2d_bytes`` counts bytes staged host→device: the ``nbytes`` of
    every *numpy* operand handed to a launch (device-resident jax.Array
    operands cost nothing — that is the resident store's whole claim).
    ``d2h_bytes`` counts bytes explicitly pulled back to host
    (:meth:`count_d2h` — spills, ranking results).

    The counters are monotone for the process lifetime and are read by
    **snapshot-and-diff only** (:meth:`snapshot` / :meth:`since`): a
    global reset would race every other measurement window sharing the
    process — two ``GossipNode`` tick handlers interleaved on one event
    loop, a bench suite wrapping a cluster — silently corrupting
    whichever window the reset landed inside. Diffing two snapshots is
    interleaving-safe (each window sees exactly its own delta plus
    launches genuinely concurrent with it), so there deliberately is no
    ``reset()``; ``benchmarks/run.py --json`` records per-suite launch
    totals this way.
    """

    __slots__ = ("launches", "h2d_bytes", "d2h_bytes")

    def __init__(self):
        self.launches = 0
        self.h2d_bytes = 0
        self.d2h_bytes = 0

    def snapshot(self) -> dict:
        return {"launches": self.launches, "h2d_bytes": self.h2d_bytes,
                "d2h_bytes": self.d2h_bytes}

    def since(self, snap: dict) -> dict:
        return {k: getattr(self, k) - v for k, v in snap.items()}

    def count_h2d(self, *arrays) -> None:
        """Record host→device staging for every numpy operand."""
        for a in arrays:
            if isinstance(a, np.ndarray):
                self.h2d_bytes += a.nbytes

    def count_d2h(self, *arrays) -> None:
        """Record an explicit device→host fetch of each array."""
        for a in arrays:
            nb = getattr(a, "nbytes", None)
            if nb is not None:
                self.d2h_bytes += int(nb)


counters = KernelCounters()

# optional process-wide launch observer (repro.obs.trace installs one):
# called (op_name, h2d_bytes_this_launch) after the counters update
_launch_hook: Optional[Callable[[str, int], None]] = None


def set_launch_hook(fn: Optional[Callable[[str, int], None]]) -> None:
    """Install (or clear, with None) the process-wide launch observer."""
    global _launch_hook
    _launch_hook = fn


def record_launch(name: str, *operands) -> None:
    """Account one named kernel dispatch: bump the counters and notify
    the launch hook. Every wrapper (and any out-of-module launch site,
    e.g. the resident store's ranking epilogue) routes through here so
    launches are observable by name, not just as a bare count."""
    counters.launches += 1
    before = counters.h2d_bytes
    counters.count_h2d(*operands)
    if _launch_hook is not None:
        _launch_hook(name, counters.h2d_bytes - before)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

_flash_attention_jit = functools.partial(
    jax.jit, static_argnames=("scale", "window", "softcap", "block_q",
                              "block_k", "interpret"))(_flash_fwd)
_flash_decode_jit = functools.partial(
    jax.jit, static_argnames=("scale", "window", "softcap", "block_k",
                              "interpret"))(_flash_decode)


def flash_attention(q, k, v, *, scale: Optional[float] = None,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """Causal flash attention. q [b,h,s,hd]; k,v [b,kv,s,hd]."""
    record_launch("flash_attention", q, k, v)
    return _flash_attention_jit(q, k, v, scale=scale, window=window,
                                softcap=softcap, block_q=block_q,
                                block_k=block_k, interpret=interpret)


def flash_decode(q, k, v, q_pos, k_pos, *, scale: Optional[float] = None,
                 window: Optional[int] = None,
                 softcap: Optional[float] = None,
                 block_k: int = 128, interpret: bool = False):
    """One-token decode against a (ring) KV cache with slot positions."""
    record_launch("flash_decode", q, k, v, q_pos, k_pos)
    return _flash_decode_jit(q, k, v, q_pos, k_pos, scale=scale,
                             window=window, softcap=softcap,
                             block_k=block_k, interpret=interpret)


# ---------------------------------------------------------------------------
# δ-CRDT joins and digests
# ---------------------------------------------------------------------------

_delta_join_jit = functools.partial(
    jax.jit, static_argnames=("block_n", "interpret"))(_delta_join)


def delta_join(a_vals, a_vers, b_vals, b_vers, *, block_n: int = 256,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused versioned-chunk LWW merge (the δ-CRDT tensor join hot loop)."""
    record_launch("delta_join", a_vals, a_vers, b_vals, b_vers)
    return _delta_join_jit(a_vals, a_vers, b_vals, b_vers, block_n=block_n,
                           interpret=interpret)


def batched_delta_join(segments, *, block_n: int = 256,
                       interpret: bool = False, host_stage: bool = False):
    """Stacked versioned-chunk merge over many objects' chunks: segments
    sharing a (chunk-width, dtype) signature run as ONE kernel launch
    (via the jit'd :func:`delta_join`, so repeated stacked shapes hit the
    dispatch cache). ``host_stage=True`` selects the numpy-staged CPU
    glue (single-grid-step launch, numpy-view outputs). Returns
    (out_vals, out_vers) per segment."""
    return _batched_delta_join(
        segments, block_n=block_n, interpret=interpret,
        host_stage=host_stage,
        join_fn=lambda av, avr, bv, bvr: delta_join(
            av, avr, bv, bvr, block_n=block_n, interpret=interpret),
        host_join_fn=lambda av, avr, bv, bvr, rows: delta_join(
            av, avr, bv, bvr, block_n=rows, interpret=interpret))


_chunk_digest_jit = functools.partial(
    jax.jit, static_argnames=("block_n", "interpret"))(_chunk_digest)
_chunk_digest_ref_jit = jax.jit(ref.chunk_digest_ref)


def chunk_digest(x, *, block_n: int = 256,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Per-chunk (max|x|, Σx²) in one pass — delta-selection digests."""
    record_launch("chunk_digest", x)
    return _chunk_digest_jit(x, block_n=block_n, interpret=interpret)


def chunk_digest_auto(x, *, block_n: int = 256
                      ) -> Tuple[jax.Array, jax.Array]:
    """:func:`chunk_digest` on the best backend available: compiled
    Pallas on TPU, the jitted XLA oracle elsewhere (identical math, one
    fused dispatch either way). The digest-selection hot path calls this
    instead of paying interpret mode's per-grid-step simulation cost per
    tensor."""
    record_launch("chunk_digest", x)
    if use_pallas_default():
        return _chunk_digest_jit(x, block_n=block_n, interpret=False)
    return _chunk_digest_ref_jit(x)


_fused_join_digest_jit = functools.partial(
    jax.jit, static_argnames=("block_n", "interpret"))(_fused_join_digest)
_fused_join_digest_ref_jit = jax.jit(ref.fused_join_digest_ref)


def fused_join_digest(a_vals, a_vers, b_vals, b_vers, *,
                      block_n: int = 256,
                      interpret: Optional[bool] = None
                      ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Join + digest-of-the-merge in ONE launch: ``(out_vals, out_vers,
    max|out| per chunk, Σout² per chunk)``. ``interpret=None`` (default)
    auto-dispatches — compiled Pallas on TPU, the jitted XLA oracle
    elsewhere; pass True/False to force a Pallas mode (parity tests)."""
    record_launch("fused_join_digest", a_vals, a_vers, b_vals, b_vers)
    if interpret is None:
        if use_pallas_default():
            return _fused_join_digest_jit(a_vals, a_vers, b_vals, b_vers,
                                          block_n=block_n, interpret=False)
        return _fused_join_digest_ref_jit(a_vals, a_vers, b_vals, b_vers)
    return _fused_join_digest_jit(a_vals, a_vers, b_vals, b_vers,
                                  block_n=block_n, interpret=interpret)


_scatter_join_jit = functools.partial(
    jax.jit, static_argnames=("interpret",))(_scatter_join)
_scatter_join_ref_jit = jax.jit(ref.scatter_join_ref)


def scatter_join(vals, vers, maxabs, sumsq, idx, d_vals, d_vers, *,
                 interpret: Optional[bool] = None
                 ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Scatter-merge sparse delta rows into resident stacked columns and
    refresh the touched rows' digest — the one-launch ingest behind
    ``kernels.resident``. ``interpret=None`` auto-dispatches like
    :func:`fused_join_digest`. ``idx`` empty is a no-op (no launch)."""
    if int(idx.shape[0]) == 0:
        return vals, vers, maxabs, sumsq
    record_launch("scatter_join", vals, vers, maxabs, sumsq, idx, d_vals, d_vers)
    if interpret is None:
        if use_pallas_default():
            return _scatter_join_jit(vals, vers, maxabs, sumsq, idx,
                                     d_vals, d_vers, interpret=False)
        return _scatter_join_ref_jit(vals, vers, maxabs, sumsq, idx,
                                     d_vals, d_vers)
    return _scatter_join_jit(vals, vers, maxabs, sumsq, idx, d_vals,
                             d_vers, interpret=interpret)


# re-export the oracles for convenience
attention_ref = ref.attention_ref
decode_ref = ref.decode_ref
delta_join_ref = ref.delta_join_ref
batched_delta_join_ref = ref.batched_delta_join_ref
chunk_digest_ref = ref.chunk_digest_ref
fused_join_digest_ref = ref.fused_join_digest_ref
scatter_join_ref = ref.scatter_join_ref
