"""jit'd public wrappers for the Pallas kernels.

``interpret=`` selects Pallas interpret mode (CPU validation; this
container has no TPU). On TPU hardware call with ``interpret=False``.
``use_pallas_default()`` is consulted by the model stack: XLA fallbacks
(the same math, from the oracles) are used for the 512-device dry-run,
because a TPU Mosaic kernel does not compile on the CPU backend.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .delta_join import batched_delta_join as _batched_delta_join
from .delta_join import chunk_digest as _chunk_digest
from .delta_join import delta_join as _delta_join
from .flash_attention import flash_attention_fwd as _flash_fwd
from .flash_attention import flash_decode_fwd as _flash_decode


def use_pallas_default() -> bool:
    """Whether the Mosaic Pallas kernels compile on the current backend.
    On TPU call the kernels with ``interpret=False``; elsewhere (this
    container: CPU) use interpret mode / the XLA oracles."""
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, scale: Optional[float] = None,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """Causal flash attention. q [b,h,s,hd]; k,v [b,kv,s,hd]."""
    return _flash_fwd(q, k, v, scale=scale, window=window, softcap=softcap,
                      block_q=block_q, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "window", "softcap",
                                             "block_k", "interpret"))
def flash_decode(q, k, v, q_pos, k_pos, *, scale: Optional[float] = None,
                 window: Optional[int] = None,
                 softcap: Optional[float] = None,
                 block_k: int = 128, interpret: bool = False):
    """One-token decode against a (ring) KV cache with slot positions."""
    return _flash_decode(q, k, v, q_pos, k_pos, scale=scale, window=window,
                         softcap=softcap, block_k=block_k,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def delta_join(a_vals, a_vers, b_vals, b_vers, *, block_n: int = 256,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused versioned-chunk LWW merge (the δ-CRDT tensor join hot loop)."""
    return _delta_join(a_vals, a_vers, b_vals, b_vers, block_n=block_n,
                       interpret=interpret)


def batched_delta_join(segments, *, block_n: int = 256,
                       interpret: bool = False, host_stage: bool = False):
    """Stacked versioned-chunk merge over many objects' chunks: segments
    sharing a (chunk-width, dtype) signature run as ONE kernel launch
    (via the jit'd :func:`delta_join`, so repeated stacked shapes hit the
    dispatch cache). ``host_stage=True`` selects the numpy-staged CPU
    glue (single-grid-step launch, numpy-view outputs). Returns
    (out_vals, out_vers) per segment."""
    return _batched_delta_join(
        segments, block_n=block_n, interpret=interpret,
        host_stage=host_stage,
        join_fn=lambda av, avr, bv, bvr: delta_join(
            av, avr, bv, bvr, block_n=block_n, interpret=interpret),
        host_join_fn=lambda av, avr, bv, bvr, rows: delta_join(
            av, avr, bv, bvr, block_n=rows, interpret=interpret))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def chunk_digest(x, *, block_n: int = 256,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Per-chunk (max|x|, Σx²) in one pass — delta-selection digests."""
    return _chunk_digest(x, block_n=block_n, interpret=interpret)


# re-export the oracles for convenience
attention_ref = ref.attention_ref
decode_ref = ref.decode_ref
delta_join_ref = ref.delta_join_ref
batched_delta_join_ref = ref.batched_delta_join_ref
chunk_digest_ref = ref.chunk_digest_ref
