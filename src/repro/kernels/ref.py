"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0 ** 30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  scale: Optional[float] = None,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None) -> jax.Array:
    """Causal attention oracle. q [b,h,sq,hd]; k,v [b,kv,sk,hd]."""
    b, h, sq, hd = q.shape
    _, kv, sk, _ = k.shape
    G = h // kv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)) \
        .astype(q.dtype)


def decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
               q_pos: jax.Array, k_pos: jax.Array, *,
               scale: Optional[float] = None,
               window: Optional[int] = None,
               softcap: Optional[float] = None) -> jax.Array:
    """Decode oracle with explicit slot positions (ring caches).
    q [b,h,1,hd]; k,v [b,kv,C,hd]; q_pos [b,1]; k_pos [b,C]."""
    b, h, _, hd = q.shape
    _, kv, C, _ = k.shape
    G = h // kv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = (k_pos[:, None, :] >= 0) & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        mask &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    # rows with no valid slot → zeros (matches kernel's safe-divide)
    any_valid = jnp.any(mask, axis=-1)[:, None, :, None]
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)


def delta_join_ref(a_vals, a_vers, b_vals, b_vers) -> Tuple[jax.Array, jax.Array]:
    take_b = b_vers > a_vers
    return (jnp.where(take_b[:, None], b_vals, a_vals),
            jnp.maximum(a_vers, b_vers))


def batched_delta_join_ref(segments) -> list:
    """Per-segment oracle for the stacked batched join."""
    return [delta_join_ref(*s) for s in segments]


def chunk_digest_ref(x) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    return jnp.max(jnp.abs(xf), axis=-1), jnp.sum(xf * xf, axis=-1)


def fused_join_digest_ref(a_vals, a_vers, b_vals, b_vers
                          ) -> Tuple[jax.Array, jax.Array,
                                     jax.Array, jax.Array]:
    """Join + digest-of-the-merge oracle (kernels fuse these into one
    HBM pass)."""
    ov, over = delta_join_ref(a_vals, a_vers, b_vals, b_vers)
    ma, ss = chunk_digest_ref(ov)
    return ov, over, ma, ss


def scatter_join_ref(vals, vers, maxabs, sumsq, idx, d_vals, d_vers
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sparse scatter-ingest oracle: merge ``r`` delta rows into resident
    stacked columns at positions ``idx`` and refresh those rows' digest;
    every other row is untouched. Duplicate positions are only legal when
    their merged content is identical (the kernel's pad-row convention),
    so write order cannot matter."""
    if int(idx.shape[0]) == 0:
        return vals, vers, maxabs, sumsq
    cur_v = vals[idx]
    cur_r = vers[idx]
    take = d_vers > cur_r
    merged = jnp.where(take[:, None], d_vals, cur_v)
    mf = merged.astype(jnp.float32)
    return (vals.at[idx].set(merged),
            vers.at[idx].set(jnp.maximum(cur_r, d_vers)),
            maxabs.at[idx].set(jnp.max(jnp.abs(mf), axis=-1)),
            sumsq.at[idx].set(jnp.sum(mf * mf, axis=-1)))
