"""Device-resident store columns: the accelerator-side half of the store.

``core.store``'s host ``_StackedChunks`` cache made the batched join one
*launch*, but every launch still staged the whole signature group's
columns host→device, and the digest/energy machinery re-read the store
from scratch. This module makes the stacked columns **persistent device
buffers** so a steady-state anti-entropy round never moves the store at
all:

* :class:`ResidentColumns` owns one signature group's stacked
  ``[rows, chunk]`` values + ``[rows]`` versions as jax.Arrays, **plus**
  the per-chunk digest columns (max|x|, Σx²) the selection policy ranks
  by, kept fresh by the kernels themselves, and a host mirror of the
  version column so digest *summaries* (``core.digest.store_digest``)
  are served with zero device traffic.
* :func:`adopt` builds the cache once from a stackable store (one upload
  + one digest launch) and attaches it to the (immutable) store object;
  :func:`ensure` is the idempotent entry the replica engine calls each
  round.
* :func:`try_join` is the join fast path ``core.store`` consults first:
  a sparse wire delta becomes ONE ``scatter_join`` launch (grid over the
  shipped rows, resident columns aliased in place, digest rows refreshed
  in the same pass); two resident stores with identical layout become
  ONE ``fused_join_digest`` launch. The result store carries the new
  cache, so rounds chain without ever rebuilding columns.
* :func:`keep_plan` turns the maintained Σx² column into the
  ``DigestBudget`` energy selection with one top-k epilogue — no
  per-tensor digest recompute.

Ownership and invalidation: a cache belongs to exactly one immutable
``LatticeStore`` value and is never mutated — joins produce fresh
(functionally-updated) columns for the result store, so old snapshots
stay valid. Anything that changes the column *layout* — a new key, a new
tensor, a chunk-count change, a reap/revive epoch bump, a rebalance that
drops keys — simply fails the fast-path checks: the join falls back to
the host paths (which stay property-test-parity with the oracles) and
the next :func:`ensure` re-adopts from the new layout. There is no dirty
bit to get wrong; epoch equality and signature equality *are* the dirty
tracking. :func:`spill` materializes the columns back to a host
``_StackedChunks`` (counted device→host) when a store must leave the
device, e.g. before a signature-changing rewrite.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ops

VVIEW = "_resident_cache"      # attribute slot on LatticeStore objects


class ResidentColumns:
    """One signature group's device-resident stacked columns + digest.

    ``vals [rows, chunk]`` / ``vers [rows]`` are the chunk data,
    ``maxabs`` / ``sumsq`` ``[rows] f32`` the per-chunk digest columns
    (always fresh: every join kernel writes them alongside the merge).
    ``layout`` / ``sig`` / ``spans`` mirror the host ``_StackedChunks``
    bookkeeping; ``vers_host`` is a host copy of the version column kept
    in lockstep by O(shipped rows) numpy work, so digest summaries never
    read the device."""

    __slots__ = ("vals", "vers", "maxabs", "sumsq", "layout", "sig",
                 "vers_host", "spans")

    def __init__(self, vals, vers, maxabs, sumsq, layout, sig, vers_host,
                 spans=None):
        self.vals = vals
        self.vers = vers
        self.maxabs = maxabs
        self.sumsq = sumsq
        self.layout = layout
        self.sig = sig
        self.vers_host = vers_host
        self.spans = spans if spans is not None else {
            (k, n): (s, e) for k, n, s, e in layout}

    @property
    def rows(self) -> int:
        return int(self.vals.shape[0])

    def nbytes_device(self) -> int:
        return sum(int(x.nbytes) for x in
                   (self.vals, self.vers, self.maxabs, self.sumsq))


def resident_of(store) -> Optional[ResidentColumns]:
    return store.__dict__.get(VVIEW)


def _upload(x: np.ndarray) -> jax.Array:
    ops.counters.count_h2d(x)
    return jnp.asarray(x)


def _stack_densified(store):
    """``core.store._stack_store`` with sparse tensors densified first: a
    replica whose state arrived entirely as wire deltas holds
    ``SparseChunks`` values (not host-stackable), but their dense form is
    exactly what the resident columns hold anyway. Builds the columnar
    view without attaching a host cache; returns None when the store is
    not tensor-only / signature-uniform / non-empty."""
    from ..core.store import _StackedChunks, _tensorstate_cls
    ts_cls = _tensorstate_cls()
    if (ts_cls is None or not store.entries
            or not all(isinstance(v, ts_cls) for _, v in store.entries)):
        return None
    parts_v, parts_r, layout = [], [], []
    chunkw = dtype = vdtype = None
    row = 0
    for key, val in store.entries:
        for name, ct in val.chunks:
            if getattr(ct, "is_sparse", False):
                ct = ct.to_dense()
            v, r = np.asarray(ct.values), np.asarray(ct.versions)
            if chunkw is None:
                chunkw, dtype, vdtype = v.shape[1], v.dtype, r.dtype
            elif (v.shape[1] != chunkw or v.dtype != dtype
                  or r.dtype != vdtype):
                return None
            parts_v.append(v)
            parts_r.append(r)
            layout.append((key, name, row, row + v.shape[0]))
            row += v.shape[0]
    if not parts_v:
        return None
    sig = (tuple(k for k, _ in store.entries),
           tuple((k, n, stop - start) for k, n, start, stop in layout),
           chunkw, str(dtype), str(vdtype))
    return _StackedChunks(np.concatenate(parts_v), np.concatenate(parts_r),
                          tuple(layout), sig)


def adopt(store) -> Optional[ResidentColumns]:
    """Build (or fetch) the resident cache for ``store``: one host stack
    scan, one upload of the columns, one digest launch. Sparse tensors
    (wire-decoded state) densify into the columns. Returns None when the
    store is not stackable (non-tensor values, mixed signatures,
    empty)."""
    cached = resident_of(store)
    if cached is not None:
        return cached
    from ..core.store import _stack_store
    sa = _stack_store(store)
    if sa is None:
        sa = _stack_densified(store)
    if sa is None:
        return None
    vals = _upload(sa.vals)
    vers = _upload(sa.vers)
    ma, ss = ops.chunk_digest_auto(vals)
    cache = ResidentColumns(vals, vers, ma, ss, sa.layout, sa.sig,
                            np.asarray(sa.vers))
    object.__setattr__(store, VVIEW, cache)
    return cache


def ensure(store) -> Optional[ResidentColumns]:
    """Idempotent :func:`adopt` — what the replica engine calls once per
    anti-entropy round so layout changes re-resident lazily."""
    return adopt(store)


def spill(store):
    """Materialize the resident columns back into a host
    ``_StackedChunks`` (attached as the store's host cache) — the exit
    path when a store must leave the device. Counted device→host."""
    cache = resident_of(store)
    if cache is None:
        return None
    from ..core.store import _StackedChunks
    ops.counters.count_d2h(cache.vals, cache.vers)
    sc = _StackedChunks(np.asarray(cache.vals), np.asarray(cache.vers),
                        cache.layout, cache.sig)
    object.__setattr__(store, "_stacked_cache", sc)
    return sc


# ---------------------------------------------------------------------------
# The join fast path
# ---------------------------------------------------------------------------

def try_join(a_store, b_store, life):
    """Resident fast path for ``a_store.join(b_store)`` (caller has
    already verified epoch agreement and pre-joined ``life``). Returns
    the joined store carrying a fresh resident cache, or None when the
    delta does not map onto the resident layout (fall back to the host
    paths)."""
    ra = resident_of(a_store)
    if ra is None:
        return None
    rb = resident_of(b_store)
    if rb is not None and rb.sig == ra.sig:
        return _aligned_join(ra, rb, a_store, b_store, life)
    plan = _scatter_plan(ra, b_store)
    if plan is None:
        return None
    return _scatter_ingest(ra, a_store, b_store, life, plan)


def _aligned_join(ra: ResidentColumns, rb: ResidentColumns,
                  a_store, b_store, life):
    """Two resident stores with the identical stacked layout: the whole
    join (and the next round's digest) is ONE fused launch."""
    ov, over, ma, ss = ops.fused_join_digest(ra.vals, ra.vers,
                                             rb.vals, rb.vers)
    entries, li = [], 0
    from ..core.tensor_lattice import ChunkedTensor, TensorState
    for (key, A), (_, B) in zip(a_store.entries, b_store.entries):
        chunks = []
        for name, _ct in A.chunks:
            _, _, start, stop = ra.layout[li]
            li += 1
            chunks.append((name, ChunkedTensor(ov[start:stop],
                                               over[start:stop])))
        entries.append((key, TensorState(tuple(chunks),
                                         max(A.lamport, B.lamport))))
    from ..core.store import LatticeStore
    result = LatticeStore(tuple(entries), life)
    cache = ResidentColumns(ov, over, ma, ss, ra.layout, ra.sig,
                            np.maximum(ra.vers_host, rb.vers_host),
                            ra.spans)
    object.__setattr__(result, VVIEW, cache)
    return result


def _scatter_plan(ra: ResidentColumns, b_store):
    """Validate that every tensor of ``b_store`` lands inside the
    resident layout (same key/tensor/chunk-count/dtype) and assemble the
    global scatter rows: ``(idx [r] int32 np, d_vals, d_vers, lamports)``
    where d_vals/d_vers are host numpy (counted as staging at launch) or
    already-device columns from a ``decode_store(..., to_device=True)``
    payload (zero staging). Returns None on any layout mismatch."""
    from ..core.tensor_lattice import TensorState, live_rows

    chunkw = ra.sig[2]
    vdtype = np.dtype(ra.sig[3])
    rdtype = np.dtype(ra.sig[4])
    a_keys = frozenset(ra.sig[0])
    for key, val in b_store.entries:
        if not isinstance(val, TensorState) or key not in a_keys:
            return None
        for name, ct in val.chunks:
            span = ra.spans.get((key, name))
            if span is None:
                return None
            n_chunks, width = ct.shape
            if (n_chunks != span[1] - span[0] or width != chunkw):
                return None

    dev = b_store.__dict__.get("_device_cols")
    if dev is not None:
        got = _device_plan(ra, b_store, dev, chunkw, vdtype, rdtype)
        if got is not None:
            return got

    idx_parts: List[np.ndarray] = []
    val_parts: List[np.ndarray] = []
    ver_parts: List[np.ndarray] = []
    for key, val in b_store.entries:
        for name, ct in val.chunks:
            start, _stop = ra.spans[(key, name)]
            li, lv, lr = live_rows(ct)
            if li.size == 0:
                continue
            lv = np.asarray(lv)
            lr = np.asarray(lr)
            if lv.dtype != vdtype or lr.dtype != rdtype:
                return None
            idx_parts.append(li.astype(np.int32) + np.int32(start))
            val_parts.append(lv)
            ver_parts.append(lr)
    if not idx_parts:
        empty = np.zeros((0,), np.int32)
        return (empty, np.zeros((0, chunkw), vdtype),
                np.zeros((0,), rdtype))
    return (np.concatenate(idx_parts),
            np.concatenate(val_parts, axis=0),
            np.concatenate(ver_parts))


def _device_plan(ra, b_store, dev_groups, chunkw, vdtype, rdtype):
    """Scatter plan over columns a decode-to-device payload already put
    on the accelerator: only the small int32 row-index column is built on
    host; values/versions never re-stage. Requires the payload to be one
    signature group matching the resident signature."""
    if len(dev_groups) != 1:
        return None
    g = dev_groups[0]
    if (g.chunk_w != chunkw or np.dtype(g.dstr) != vdtype
            or np.dtype(g.vstr) != rdtype):
        return None
    idx_parts: List[np.ndarray] = []
    row = 0
    for key, name, n_chunks, rows in g.members:
        span = ra.spans.get((key, name))
        if span is None or n_chunks != span[1] - span[0]:
            return None
        idx_parts.append(g.idx_col[row:row + rows].astype(np.int32)
                         + np.int32(span[0]))
        row += rows
    idx = (np.concatenate(idx_parts) if idx_parts
           else np.zeros((0,), np.int32))
    return (idx, g.vals_dev, g.vers_dev)


def _pad_bucket(r: int) -> int:
    """Round the scatter grid up to a power-of-two bucket (min 8) so the
    per-``r`` jit retrace cost is amortized across rounds of varying
    delta sizes."""
    b = 8
    while b < r:
        b <<= 1
    return b


def _scatter_ingest(ra: ResidentColumns, a_store, b_store, life, plan):
    """One ``scatter_join`` launch applies the whole delta to the
    resident columns; the result store reuses every untouched key's entry
    object and views the touched segments out of the new columns."""
    from ..core.store import LatticeStore
    from ..core.tensor_lattice import ChunkedTensor, TensorState

    idx, d_vals, d_vers = plan
    r = int(idx.shape[0])
    n = ra.rows
    d_vers_host = np.asarray(d_vers) if isinstance(d_vers, np.ndarray) \
        else None

    if r and r < n:
        # pad the grid to a bucket so repeated rounds share one trace:
        # pad rows target a row no real row touches, with ⊥ versions, so
        # they re-write existing content (a no-op even when duplicated)
        bucket = _pad_bucket(r)
        pad = min(bucket, n) - r if bucket > r else 0
        if pad > 0:
            # a row no real delta row targets (idx is unique): first gap
            # in the sorted positions, or r itself when they are 0..r-1
            s = np.sort(idx)
            gap = np.flatnonzero(s != np.arange(r, dtype=s.dtype))
            free = int(gap[0]) if gap.size else r
            idx = np.concatenate([idx, np.full(pad, free, np.int32)])
            zpad_v = jnp.zeros((pad,) + tuple(d_vals.shape[1:]),
                               d_vals.dtype)
            zpad_r = jnp.zeros((pad,), d_vers.dtype)
            if isinstance(d_vals, np.ndarray):
                d_vals = np.concatenate(
                    [d_vals, np.asarray(zpad_v)], axis=0)
                d_vers = np.concatenate([d_vers, np.asarray(zpad_r)])
            else:
                d_vals = jnp.concatenate([d_vals, zpad_v], axis=0)
                d_vers = jnp.concatenate([d_vers, zpad_r])

    ov, over, ma, ss = ops.scatter_join(ra.vals, ra.vers, ra.maxabs,
                                        ra.sumsq, idx, d_vals, d_vers)

    # host mirror of the version column: O(r) numpy, no device read
    if r:
        vh = ra.vers_host.copy()
        real_idx = idx[:r]
        if d_vers_host is None:
            d_vers_host = np.asarray(d_vers)[:r]
            ops.counters.count_d2h(d_vers_host)
        take = d_vers_host[:r] > vh[real_idx]
        vh[real_idx[take]] = d_vers_host[:r][take]
    else:
        vh = ra.vers_host

    touched: Dict[str, Any] = {}
    a_map = dict(a_store.entries)
    for key, B in b_store.entries:
        A = a_map[key]
        b_names = frozenset(n for n, _ in B.chunks)
        chunks = []
        for name, ct in A.chunks:
            if name in b_names:
                start, stop = ra.spans[(key, name)]
                chunks.append((name, ChunkedTensor(ov[start:stop],
                                                   over[start:stop])))
            else:
                chunks.append((name, ct))
        touched[key] = TensorState(tuple(chunks),
                                   max(A.lamport, B.lamport))

    entries = tuple((k, touched.get(k, v)) for k, v in a_store.entries)
    result = LatticeStore(entries, life)
    cache = ResidentColumns(ov, over, ma, ss, ra.layout, ra.sig, vh,
                            ra.spans)
    object.__setattr__(result, VVIEW, cache)
    return result


# ---------------------------------------------------------------------------
# Energy selection from the maintained digest columns
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k",))
def _topk_live(sumsq, live, k):
    masked = jnp.where(live, sumsq, -1.0)
    return jax.lax.top_k(masked, k)[1]


def keep_plan(cache: ResidentColumns, budget_bytes: int
              ) -> Optional[Dict[Tuple[str, str], list]]:
    """``tensor_lattice.digest_keep_plan`` served from the resident
    digest columns: per-chunk payload bytes are constant within a
    signature group, so the greedy energy ranking is exactly a top-k
    prefix over the maintained Σx² column — one device epilogue instead
    of one digest recompute per tensor. Returns None when every live
    chunk fits the budget, else ``{(key, name): [kept chunk indices]}``
    (identical contract, identical tie order: ``lax.top_k`` prefers
    lower indices and the column order is (key, name, chunk) ascending,
    the same order the host greedy sorts ties by)."""
    per_chunk = (np.dtype(cache.sig[3]).itemsize * cache.sig[2]
                 + np.dtype(np.int64).itemsize
                 + np.dtype(np.int32).itemsize)
    live = cache.vers_host > 0
    n_live = int(live.sum())
    if n_live * per_chunk <= budget_bytes:
        return None
    k = min(int(budget_bytes // per_chunk), cache.rows)
    keep: Dict[Tuple[str, str], list] = {}
    if k <= 0:
        return keep
    ops.record_launch("keep_plan")      # the ranking epilogue
    rows = np.asarray(_topk_live(cache.sumsq, jnp.asarray(live), k))
    ops.counters.count_d2h(rows)
    starts = np.fromiter((s for _, _, s, _ in cache.layout), np.int64,
                         len(cache.layout))
    seg = np.searchsorted(starts, rows, side="right") - 1
    for row, si in zip(rows.tolist(), seg.tolist()):
        key, name, start, _stop = cache.layout[si]
        keep.setdefault((key, name), []).append(int(row) - start)
    return keep
