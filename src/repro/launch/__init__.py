"""Launchers: make_production_mesh (mesh.py), the 512-device multi-pod
dry-run (dryrun.py — import sets XLA_FLAGS first), training and serving
CLIs (train.py / serve.py), and the EXPERIMENTS.md table generator
(report.py)."""
