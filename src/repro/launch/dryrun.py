"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the placeholder-device flag before ANY other import (jax locks the
device count at first init)."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import (ARCH_IDS, SHAPE_CASES, applicable, get_config,
                           input_specs)  # noqa: E402
from repro.dist import (collective_bytes, make_rules, param_pspecs,
                        roofline)  # noqa: E402
from repro.dist.hlo import collective_count  # noqa: E402
from repro.dist.shardings import batch_pspecs, named  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import init_model  # noqa: E402
from repro.models.hints import activation_rules, default_rules  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.optim.adamw import opt_state_pspecs  # noqa: E402
from repro.runtime import (TrainConfig, make_decode_fn, make_prefill_fn,
                           make_train_step)  # noqa: E402


def abstract_model(cfg) -> Tuple[Any, Any]:
    """Parameter ShapeDtypeStructs + logical-axis tree, zero allocation.

    init_model runs under eval_shape (abstract); the logical-spec tree is
    captured through a side channel during tracing."""
    side: Dict[str, Any] = {}

    def build(key):
        params, specs = init_model(cfg, key)
        side["specs"] = specs
        return params

    params_sds = jax.eval_shape(build, jax.random.PRNGKey(0))
    return params_sds, side["specs"]


def abstract_opt_state(params_sds) -> Any:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(f32, params_sds),
        "v": jax.tree_util.tree_map(f32, params_sds),
        "master": jax.tree_util.tree_map(f32, params_sds),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _normalize_cost(cost) -> Dict[str, float]:
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return dict(cost) if cost else {}


def _memory_summary(compiled) -> Optional[Dict[str, float]]:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return None
        out = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                out[k] = float(getattr(ma, k))
        return out or None
    except Exception:
        return None


def _lower_and_compile(cfg, case, mesh, multi_pod: bool, rules,
                       microbatches: int = 1):
    """Shared lowering path for the full model and the cost probes.

    Buffer donation mirrors production training: params/opt-state are
    donated in train_step and caches in serve_step, so the live-buffer
    analysis reflects in-place updates."""
    serve_mode = False
    if case.step != "train" and rules.candidates.get("embed"):
        # Replicate params over the batch axes at inference ONLY if
        # (i) the model-axis-sharded copy fits per chip (bf16, 12 GB
        # headroom) and (ii) the batch actually occupies the data axes
        # (at batch 1 the FSDP gathers are negligible and replication
        # just multiplies HBM reads — measured on jamba long_500k).
        total_params, _ = cfg.param_counts()
        n_devices = int(len(mesh.devices.flat))
        dp = n_devices // mesh.shape["model"]
        if (total_params * 2 / mesh.shape["model"] <= 12e9
                and case.batch >= dp):
            rules = make_rules(mesh, serve=True)
            serve_mode = True
    params_sds, logical = abstract_model(cfg)
    p_pspecs = param_pspecs(params_sds, logical, rules)
    p_sh = named(p_pspecs, mesh)
    batch_sds = input_specs(cfg, case)
    b_pspecs = batch_pspecs(batch_sds, rules)
    b_sh = named(b_pspecs, mesh)

    with mesh, activation_rules(mesh, default_rules(multi_pod,
                                                     serve=serve_mode)):
        if case.step == "train":
            step_fn = make_train_step(cfg, TrainConfig(
                optimizer=AdamWConfig(), microbatches=microbatches))
            opt_sds = abstract_opt_state(params_sds)
            o_sh = named(opt_state_pspecs(p_pspecs), mesh)
            met_sh = {k: NamedSharding(mesh, P())
                      for k in ("loss", "grad_norm", "lr")}
            jitted = jax.jit(step_fn,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, met_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif case.step == "prefill":
            step_fn = make_prefill_fn(cfg, max_len=case.seq)
            jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_sds, batch_sds)
        else:
            step_fn = make_decode_fn(cfg)
            jitted = jax.jit(step_fn,
                             in_shardings=(p_sh, b_sh["tokens"],
                                           b_sh["pos"], b_sh["caches"]),
                             donate_argnums=(3,))
            lowered = jitted.lower(params_sds, batch_sds["tokens"],
                                   batch_sds["pos"], batch_sds["caches"])
        return lowered, lowered.compile()


def _cell_costs(compiled, chips: int):
    cost = _normalize_cost(compiled.cost_analysis())
    hlo = compiled.as_text()
    wire, per_kind = collective_bytes(hlo, chips)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes accessed": float(cost.get("bytes accessed", 0.0)),
            "wire": wire, "per_kind": per_kind,
            "counts": collective_count(hlo)}


def _probe_corrected_costs(cfg, case, mesh, multi_pod, chips,
                           microbatches) -> Dict[str, Any]:
    """XLA counts a scan body once regardless of trip count, so the full
    compile under-reports flops/bytes/collectives for scanned layer stacks
    (verified empirically: an 8-iteration scan of 512³ matmuls reports one
    matmul). Reconstruct compositionally:

        total = C0 + Σ_groups repeats_i · (C_only-group-i − C0)

    where every probe has trip count 1 (counted exactly once = exact)."""
    import dataclasses
    from repro.models.config import layout_groups as _lg

    groups = _lg(cfg.default_layout())
    rules0 = make_rules(mesh)
    cfg0 = dataclasses.replace(cfg, layout=(), n_layers=0)
    _, comp0 = _lower_and_compile(cfg0, case, mesh, multi_pod, rules0,
                                  microbatches)
    C0 = _cell_costs(comp0, chips)

    total = {"flops": C0["flops"], "bytes accessed": C0["bytes accessed"],
             "wire": C0["wire"],
             "per_kind": dict(C0["per_kind"])}
    for block, repeats in groups:
        cfg_i = dataclasses.replace(cfg, layout=tuple(block),
                                    n_layers=len(block))
        _, comp_i = _lower_and_compile(cfg_i, case, mesh, multi_pod,
                                       make_rules(mesh), microbatches)
        Ci = _cell_costs(comp_i, chips)
        for k in ("flops", "bytes accessed", "wire"):
            total[k] += repeats * max(0.0, Ci[k] - C0[k])
        for kind, v in Ci["per_kind"].items():
            base = C0["per_kind"].get(kind, 0.0)
            total["per_kind"][kind] = total["per_kind"].get(kind, 0.0) + \
                repeats * max(0.0, v - base)
    if microbatches > 1 and case.step == "train":
        # the gradient-accumulation scan body is also counted once; scale
        # by the trip count (overcounts the outside-the-scan optimizer by
        # (μ-1)·opt — ~1-2% at these scales, noted in EXPERIMENTS.md)
        for k in ("flops", "bytes accessed", "wire"):
            total[k] *= microbatches
        total["per_kind"] = {k: v * microbatches
                             for k, v in total["per_kind"].items()}
    return total


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_dir: Optional[str] = None,
             microbatches: int = 1,
             save_hlo: bool = False,
             overrides: Optional[Dict[str, Any]] = None,
             tag_suffix: str = "") -> Dict[str, Any]:
    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    case = SHAPE_CASES[shape]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, reason = applicable(cfg, case)
    if not ok:
        res = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skipped", "reason": reason}
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            tag = f"{arch}_{shape}_{mesh_name}{tag_suffix}".replace("/", "-")
            with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1)
        return res

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(len(mesh.devices.flat))
    rules = make_rules(mesh)

    total_params, active_params = cfg.param_counts()
    if case.step == "train":
        tokens = case.batch * case.seq
        model_flops = 6.0 * active_params * tokens
    elif case.step == "prefill":
        tokens = case.batch * case.seq
        model_flops = 2.0 * active_params * tokens
    else:
        tokens = case.batch
        model_flops = 2.0 * active_params * tokens

    if case.step == "train" and microbatches > 1:
        dp = chips // mesh.shape["model"]
        assert case.batch % microbatches == 0 and \
            (case.batch // microbatches) % dp == 0, (
            f"microbatches={microbatches}: per-microbatch batch "
            f"{case.batch // microbatches} must divide the {dp}-way "
            f"data-parallel axes (max valid mu = {case.batch // dp})")

    # 1. full-depth compile: proves sharding coherence + memory fit
    lowered, compiled = _lower_and_compile(cfg, case, mesh, multi_pod,
                                           rules, microbatches)
    mem = _memory_summary(compiled)
    hlo = compiled.as_text()
    raw = _cell_costs(compiled, chips)

    # 2. scan-corrected flops/bytes/collectives via trip-1 probes
    corrected = _probe_corrected_costs(cfg, case, mesh, multi_pod, chips,
                                       microbatches)
    cost = {"flops": corrected["flops"],
            "bytes accessed": corrected["bytes accessed"]}
    wire, per_kind = corrected["wire"], corrected["per_kind"]
    counts = raw["counts"]

    rep = roofline(arch, shape, mesh_name, chips, cost, wire, per_kind,
                   model_flops, tokens,
                   peak_memory=(mem or {}).get("temp_size_in_bytes"))
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "chips": chips,
        "compile_s": round(time.time() - t0, 1),
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "transcendentals")
                          if k in cost},
        "cost_analysis_raw_scan_body_once": {
            "flops": raw["flops"], "bytes accessed": raw["bytes accessed"],
            "wire": raw["wire"]},
        "memory_analysis": mem,
        "collective_wire_bytes_per_chip": wire,
        "collective_breakdown": per_kind,
        "collective_counts": counts,
        "params_total": total_params,
        "params_active": active_params,
        "model_flops_total": model_flops,
        "roofline": json.loads(rep.to_json()),
        "sharding_fallbacks": sorted(set(rules.fallbacks)),
    }
    if overrides:
        result["overrides"] = {k: str(v) for k, v in overrides.items()}
    result["microbatches"] = microbatches
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape}_{mesh_name}{tag_suffix}".replace("/", "-")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
                f.write(hlo)
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_IDS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPE_CASES)} or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    overrides = {}
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl

    arches = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPE_CASES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in arches:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch} × {shape} × {'2x16x16' if multi else '16x16'}"
                try:
                    res = run_cell(arch, shape, multi, out_dir=args.out,
                                   microbatches=args.microbatches,
                                   save_hlo=args.save_hlo,
                                   overrides=overrides or None,
                                   tag_suffix=args.tag)
                    if res["status"] == "skipped":
                        print(f"[skip] {tag}: {res['reason']}", flush=True)
                    else:
                        r = res["roofline"]
                        print(f"[ ok ] {tag} compile={res['compile_s']}s "
                              f"c={r['compute_s']:.3e}s m={r['memory_s']:.3e}s "
                              f"n={r['collective_s']:.3e}s bound={r['bound']} "
                              f"useful={r['useful_frac']:.2%}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}",
                          flush=True)
                    traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
