"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use,
and everything else (smoke tests, benches) sees the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1×1 mesh on whatever single device exists — smoke-scale runs."""
    return jax.make_mesh((1, 1), ("data", "model"))
