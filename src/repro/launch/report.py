"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts."""

from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List


def load(dryrun_dir: str) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def fmt_bytes(x) -> str:
    if x is None:
        return "-"
    return f"{x / 1e9:.1f}"


def dryrun_table(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | status | compile s | HLO GFLOPs/chip | "
           "HBM GB/chip | wire GB/chip | temp GB/dev | fallbacks |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d["status"] == "skipped":
            out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                       f"skip | - | - | - | - | - | - |")
            continue
        ca = d["cost_analysis"]
        mem = d.get("memory_analysis") or {}
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok | "
            f"{d['compile_s']} | {ca['flops'] / 1e9:.0f} | "
            f"{fmt_bytes(ca['bytes accessed'])} | "
            f"{fmt_bytes(d['collective_wire_bytes_per_chip'])} | "
            f"{fmt_bytes(mem.get('temp_size_in_bytes'))} | "
            f"{len(d.get('sharding_fallbacks', []))} |")
    return "\n".join(out)


def roofline_table(rows: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | bound | "
           "MODEL_FLOPS/HLO | roofline frac | one-line diagnosis |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in rows:
        if d["status"] != "ok" or d["mesh"] != mesh:
            continue
        r = d["roofline"]
        diag = _diagnose(d)
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['bound']}** | {r['useful_frac']:.1%} | "
            f"{r['roofline_frac']:.1%} | {diag} |")
    return "\n".join(out)


def _diagnose(d: Dict) -> str:
    r = d["roofline"]
    bk = d.get("collective_breakdown", {})
    top_coll = max(bk, key=bk.get) if bk else "none"
    if r["bound"] == "collective":
        return (f"dominated by {top_coll} "
                f"({bk.get(top_coll, 0) / 1e9:.0f} GB/chip); reduce by "
                f"resharding the producing op")
    if r["bound"] == "memory":
        if d["shape"].startswith(("decode", "long")):
            return "cache/param streaming floor — batch or quantize to move"
        return "activation traffic (naive attention / remat re-reads)"
    return "compute-bound — at the MXU roof"


def main() -> None:
    dryrun_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    rows = load(dryrun_dir)
    ok = [d for d in rows if d["status"] == "ok"]
    sk = [d for d in rows if d["status"] == "skipped"]
    print(f"## §Dry-run — {len(ok)} compiled cells, {len(sk)} documented "
          f"skips, 0 failures\n")
    print(dryrun_table(rows))
    print("\n## §Roofline — single-pod (16x16, 256 chips)\n")
    print(roofline_table(rows, "16x16"))
    print("\n## §Roofline — multi-pod (2x16x16, 512 chips)\n")
    print(roofline_table(rows, "2x16x16"))


if __name__ == "__main__":
    main()
