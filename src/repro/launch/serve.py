"""Serving driver: batched prefill → decode with KV caches.

Smoke-scale on CPU (reduced configs), production shapes via the dry-run.
Demonstrates the serving runtime end to end: batched requests, prefill,
iterative decode over ring caches (SWA archs keep O(window) state), and
greedy sampling. ``--replicate N`` additionally replicates the session
table as an ORMap δ-CRDT across N gateway replicas over a lossy network —
request metadata survives gateway failover with no coordinator (the
serving-side use of the paper).

``--ship-policy`` selects what the gateway gossip ships each round —
push policies (``bp+rr``, ``every:k``) and the pull exchange
(``digest-sync``, or the hybrid ``bp+rr+digest-sync:8``): gateways
periodically trade compact digest frames and receive back only the
session rows they are missing, so a reconnecting gateway catches up
without a full-state round.

``--sessions N`` is the scale-out version of the same story: N independent
session objects live in a keyed ``LatticeStore`` replicated across the
gateways, with rendezvous-hashed key ownership (``KeyOwnership`` +
``ShardByKey``) so each gateway only buffers and ships the sessions it
owns or replicates — bytes per anti-entropy round scale with a gateway's
shard, not with the whole fleet's session count. Any gateway accepts any
request (writes for non-owned keys forward to the owners through the
same gossip).

``--listen HOST:PORT --peers a,b,c`` leaves the simulator entirely: this
process becomes ONE member of a real gossip cluster (``repro.net``),
shipping the same δ-wire frames over actual UDP or TCP sockets. Each
process writes its share of the ``--sessions`` keys and gossips under
``--ship-policy`` until the cluster converges; ``--status-file`` publishes
a JSON heartbeat (semantic session fingerprint + byte counters) so an
external harness — the ``net`` benchmark suite, the CI ``net-smoke``
job — can assert cross-process convergence without any coordinator.
Socket mode requires the wire codec (``--no-wire`` is rejected) and
members may be named ``id@host:port`` to keep replica ids logical."""

from __future__ import annotations

import argparse
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import (AWORSet, Compose, MVRegister, NetConfig, ORMap,
                        POLICY_SPECS, Replica, Simulator, StoreReplica,
                        causal_policy_spec, converged, make_policy,
                        run_to_convergence)
from repro.models import decode_step, init_model, prefill


def _policy_spec(s: str) -> str:
    try:                 # fail at arg parsing, not after the model ran
        return causal_policy_spec(s, "the session-table gossip")
    except ValueError as e:
        raise argparse.ArgumentTypeError(str(e))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicate", type=int, default=0,
                    help="N gateway replicas for the δ-CRDT session table")
    ap.add_argument("--ship-policy", default="bp+rr", type=_policy_spec,
                    help="shipping policy for --replicate/--sessions "
                         f"gossip (e.g. {', '.join(POLICY_SPECS)}, "
                         "bp+rr+digest-sync:8)")
    ap.add_argument("--sessions", type=int, default=0,
                    help="N keyed session objects spread across the "
                         "gateways (LatticeStore + hash-sharded ownership; "
                         "implies 3 gateways unless --replicate is set)")
    ap.add_argument("--session-replication", type=int, default=2,
                    help="replicas per session key under --sessions")
    ap.add_argument("--session-ttl", type=float, default=None,
                    metavar="SECONDS",
                    help="key lifecycle for --sessions: every session "
                         "key expires SECONDS after its last write, and "
                         "the owner-driven reaper drops it to a tombstone "
                         "once the whole replica set acks the expiry "
                         "(repro.lifecycle). Works in the simulator (sim "
                         "time) and in socket mode (wall time, reap "
                         "frames over real UDP/TCP)")
    ap.add_argument("--no-wire", dest="wire", action="store_false",
                    help="gossip Python objects instead of binary δ-wire "
                         "frames (frames are the default: gateways move "
                         "bytes, and reported traffic is measured frame "
                         "lengths; incompatible with socket mode)")
    ap.add_argument("--listen", metavar="[ID@]HOST:PORT[@ZONE]",
                    default=None,
                    help="socket mode: gossip over real sockets as one "
                         "member of an OS-process cluster (repro.net); "
                         "requires --peers. An @ZONE suffix (zone or "
                         "region/zone) places this member in a failure "
                         "domain: byte accounting splits by link class "
                         "and gossip goes hierarchical (intra-zone push, "
                         "relay-batched cross-zone digest-sync)")
    ap.add_argument("--peers", metavar="[ID@]H:P[@ZONE],...", default=None,
                    help="socket mode: the other cluster members (zone "
                         "annotations must cover every member or none)")
    ap.add_argument("--transport", default="udp", choices=("udp", "tcp"),
                    help="socket-mode channel (UDP datagrams with "
                         "MTU splitting/batching, or TCP streams with "
                         "reconnect)")
    ap.add_argument("--udp-loss", type=float, default=0.0,
                    help="socket mode, UDP only: injected datagram loss "
                         "probability on the send path (reproducible "
                         "lossy-mesh runs over loopback)")
    ap.add_argument("--tick", type=float, default=0.1,
                    help="socket-mode anti-entropy period, seconds")
    ap.add_argument("--run-for", type=float, default=45.0,
                    help="socket mode: exit after this many seconds")
    ap.add_argument("--status-file", default=None,
                    help="socket mode: publish a JSON heartbeat "
                         "(fingerprint, key count, byte counters) here "
                         "for the external convergence harness")
    ap.add_argument("--metrics", action="store_true",
                    help="socket mode: export the observability registry "
                         "(repro.obs — replication lag, delta-buffer "
                         "depth, per-link-class byte rates, kernel "
                         "launches) on a loopback HTTP sidecar serving "
                         "Prometheus text at /metrics and JSON at "
                         "/metrics.json; --status-file heartbeats gain "
                         "the full snapshot")
    args = ap.parse_args()

    if args.listen or args.peers:
        from repro.net import validate_net_args
        try:
            spec = validate_net_args(
                args.listen, args.peers, transport=args.transport,
                wire=args.wire, udp_loss=args.udp_loss,
                session_ttl=args.session_ttl)
        except ValueError as e:
            ap.error(str(e))
        _socket_sessions(args, spec)
        return

    cfg = get_config(args.arch, reduced=True)
    params, _ = init_model(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    b = args.batch
    max_len = args.prompt_len + args.gen
    if cfg.ssm is not None:
        # SSD prefill wants chunk-aligned prompt lengths
        args.prompt_len = max(cfg.ssm.chunk,
                              (args.prompt_len // cfg.ssm.chunk)
                              * cfg.ssm.chunk)
        max_len = args.prompt_len + args.gen

    if cfg.input_mode == "embeds":
        prompt = {"embeds": jnp.asarray(rng.normal(
            size=(b, args.prompt_len, cfg.d_model)).astype(np.float32),
            jnp.dtype(cfg.dtype))}
    elif cfg.input_mode == "tokens+prefix":
        tl = args.prompt_len - cfg.prefix_len
        assert tl > 0, "prompt shorter than the vision prefix"
        prompt = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, tl)),
                                  jnp.int32),
            "prefix_embeds": jnp.asarray(rng.normal(
                size=(b, cfg.prefix_len, cfg.d_model)).astype(np.float32),
                jnp.dtype(cfg.dtype)),
        }
    else:
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (b, args.prompt_len)), jnp.int32)}

    t0 = time.time()
    prefill_jit = jax.jit(lambda p, x: prefill(cfg, p, x, max_len=max_len))
    logits, caches = prefill_jit(params, prompt)
    t_prefill = time.time() - t0

    decode_jit = jax.jit(lambda p, t, pos, c: decode_step(cfg, p, t, pos, c))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for k in range(args.gen - 1):
        pos = jnp.full((b, 1), args.prompt_len + k, jnp.int32)
        if cfg.input_mode == "embeds":
            step_in = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model))
                                  .astype(np.float32), jnp.dtype(cfg.dtype))
        else:
            step_in = tok
        logits, caches = decode_jit(params, step_in, pos, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(np.asarray(tok))
    dt = time.time() - t0
    toks = b * (args.gen - 1)
    print(f"[serve] arch={cfg.name} batch={b} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"  prefill: {t_prefill:.2f}s   decode: {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s on CPU smoke config)")
    print(f"  sample continuation (req 0): "
          f"{[int(g[0, 0]) for g in generated[:8]]}")

    if args.replicate:
        _replicated_sessions(args, b)
    if args.sessions:
        _keyed_sessions(args)


def _replicated_sessions(args, b: int) -> None:
    """Session table as ORMap(request → LWW status) across gateways,
    gossiped by the unified propagation runtime under --ship-policy."""
    wire = _wire_codec(args)
    sim = Simulator(NetConfig(loss=0.25, dup=0.1, seed=args.seed))
    ids = [f"gw{k}" for k in range(args.replicate)]
    nodes = [sim.add_node(Replica(i, ORMap.bottom(),
                                  [j for j in ids if j != i], causal=True,
                                  policy=make_policy(args.ship_policy),
                                  rng=random.Random(args.seed + k),
                                  wire=wire))
             for k, i in enumerate(ids)]
    for r in range(b):
        gw = nodes[r % len(nodes)]   # each request owned by one gateway →
        for status in ("queued", "prefilling", "decoding", "done"):
            # sequential writes per key: MVRegister holds a single value
            gw.operation(lambda X, r=r, s=status: X.apply_delta(
                gw.id, f"req{r}", MVRegister, "write_delta", s))
        sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0)
    assert converged(nodes)
    table = nodes[0].X
    statuses = {k: next(iter(table.get_value(k, MVRegister).read()))
                for k in sorted(table.keys())}
    payload = sim.stats.payload_atoms()
    unit = "frame_bytes" if wire is not None else "payload_atoms"
    print(f"  [δ-CRDT] session table replicated over {args.replicate} "
          f"gateways (25% loss, policy={args.ship_policy}, "
          f"{unit}={payload}): {statuses}")
    assert all(v == "done" for v in statuses.values())


def _wire_codec(args):
    """The binary frame codec gateways gossip through (None = objects)."""
    if not args.wire:
        return None
    from repro.wire import WireCodec
    return WireCodec()


def _keyed_sessions(args) -> None:
    """N session objects in a keyed LatticeStore across gateways, with
    rendezvous-hash-sharded ownership: gossip ships each session only to
    the gateways that replicate it. Under ``--session-ttl`` each key
    also carries an expiry touched on every write, and the owner-driven
    reaper tombstones it once the whole replica set acks the expiry —
    the store *shrinks* again after the sessions complete."""
    from repro.sync import KeyOwnership, ShardByKey

    wire = _wire_codec(args)
    n_gw = max(args.replicate, 2) if args.replicate else 3
    ids = [f"gw{k}" for k in range(n_gw)]
    ownership = KeyOwnership(ids, replication=min(args.session_replication,
                                                  n_gw))
    sim = Simulator(NetConfig(loss=0.25, dup=0.1, seed=args.seed))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=Compose(make_policy(args.ship_policy), ShardByKey(ownership)),
        rng=random.Random(args.seed + k), ownership=ownership, wire=wire,
        ttl=args.session_ttl or None))    # 0 ⇒ lifecycle off, like unset
        for k, i in enumerate(ids)]

    # gossip runs concurrently with ingest: register the periodic
    # anti-entropy (and GC) ticks before the first write
    for n in nodes:
        if args.session_ttl:
            from repro.lifecycle import ReaperProtocol
            ReaperProtocol(n, ownership, grace=1.0, retry=2.0)
        sim.every(1.0, n.on_periodic)
        sim.every(7.0, n.gc_deltas)

    for s in range(args.sessions):
        key = f"sess{s}"
        gw = nodes[s % len(nodes)]   # ingress gateway; may not own the key
        for status in ("queued", "prefilling", "decoding", "done"):
            gw.update(key, MVRegister, "write_delta", gw.id, status)
        if s % 8 == 7:
            sim.run_for(0.5)

    # then drive until every session's replica set agrees
    keys = [f"sess{s}" for s in range(args.sessions)]
    by_id = {n.id: n for n in nodes}

    def settled() -> bool:
        for key in keys:
            states = [by_id[w].get(key, MVRegister)
                      for w in ownership.owners(key)]
            if any(s != states[0] for s in states[1:]):
                return False
            if states[0].read() != frozenset({"done"}):
                return False
        return True

    t0 = sim.time
    while sim.time - t0 < 10_000:
        sim.run_for(2.0)
        if settled():
            break
    assert settled(), "sharded session store failed to settle"

    payload = sim.stats.payload_atoms()
    per_gw = {i: len([k for k in keys if ownership.replicates(i, k)])
              for i in ids}
    unit = "frame_bytes" if wire is not None else "payload_atoms"
    print(f"  [δ-CRDT store] {args.sessions} sessions sharded over "
          f"{n_gw} gateways (replication={ownership.replication}, 25% loss, "
          f"policy={args.ship_policy}+shard"
          f"{', binary δ-wire frames' if wire is not None else ''}): "
          f"all owner replicas settled to 'done'")
    print(f"    keys per gateway: {per_gw}   {unit}={payload}")

    if args.session_ttl:
        # every session saw its last write above; run the clock past the
        # TTL and let the acked reaper drain the store back down

        def all_reaped() -> bool:
            tombs = {i: by_id[i].X.tombstoned_keys() for i in ids}
            return all(key in tombs[w]
                       for key in keys for w in ownership.owners(key))

        t0 = sim.time
        while sim.time - t0 < args.session_ttl + 10_000:
            sim.run_for(5.0)
            if all_reaped():
                break
        tombs = {i: by_id[i].X.tombstoned_keys() for i in ids}
        reaped = {i: sum(1 for key in keys if key in tombs[i])
                  for i in ids}
        resident = {i: len(by_id[i].X.entries) for i in ids}
        assert all_reaped(), "sessions past their TTL were not reaped"
        print(f"  [lifecycle] ttl={args.session_ttl}s: all {args.sessions} "
              f"sessions expired and were reaped by their owners' ack "
              f"quorum; tombstones per gateway: {reaped}, resident "
              f"values left: {resident}")


def _session_fingerprint(replica, keys) -> str:
    """Semantic fingerprint of the session table: blake2b over the sorted
    ``(key, sorted read set)`` pairs. Representation-blind on purpose —
    a locally-written MVRegister and its wire-decoded columnar twin are
    semantically equal but structurally different objects, so hashing
    the *read values* is what lets N processes agree they converged."""
    import hashlib
    acc = hashlib.blake2b(digest_size=16)
    for key in sorted(keys):
        val = replica.get(key, MVRegister)
        reads = sorted(repr(v) for v in val.read()) if val is not None \
            else []
        acc.update(repr((key, reads)).encode("utf-8"))
    return acc.hexdigest()


def _socket_replica_factory(args, spec, topo):
    """The socket-mode replica factory: ``--ship-policy`` (composed with
    :class:`HierarchicalGossip` when the members carry zones), plus —
    under ``--session-ttl`` — full-replication key ownership and the
    acked reaper, so the tombstone quorum runs over real UDP/TCP.

    Ownership is the *whole static cluster* (replication = member
    count): every process derives the identical owner map from the same
    ``--peers`` list with no membership gossip, every replica holds
    every key (the cross-process fingerprint check stays meaningful),
    and a reap commits only once every member acked the expiry."""
    from repro.core.hiergossip import HierarchicalGossip
    from repro.core.propagation import stable_seed
    from repro.wire import WireCodec

    ownership = None
    if spec.session_ttl:
        from repro.sync import KeyOwnership
        ids = spec.cluster_ids
        ownership = KeyOwnership(ids, replication=len(ids), topology=topo)

    def make(node_id, neighbors):
        pol = make_policy(args.ship_policy)
        if topo is not None:
            pol = Compose(pol, HierarchicalGossip(topo))
        replica = StoreReplica(
            node_id, list(neighbors), causal=True, policy=pol,
            rng=random.Random(stable_seed(node_id)), wire=WireCodec(),
            ownership=ownership, ttl=spec.session_ttl)
        if spec.session_ttl:
            from repro.lifecycle import ReaperProtocol
            # grace/retry scale with the tick: proposals should survive
            # a couple of lost datagrams but not stall the reap for long
            ReaperProtocol(replica, ownership,
                           grace=max(2 * args.tick, 0.5),
                           retry=max(6 * args.tick, 1.0))
        return replica

    return make


def _socket_sessions(args, spec) -> None:
    """One member of a real socket gossip cluster (``repro.net``): write
    this process's share of the session keys, gossip frames until the
    run window closes, publish convergence heartbeats."""
    import asyncio

    async def run() -> None:
        from repro.net import GossipNode

        n_sessions = args.sessions if args.sessions else 12
        topo = spec.topology
        node = GossipNode(spec.node_id, spec.listen,
                          transport=spec.transport, peers=spec.peers,
                          replica_factory=_socket_replica_factory(
                              args, spec, topo),
                          topology=topo, tick=args.tick,
                          loss=args.udp_loss, seed=args.seed)
        await node.start()
        if args.metrics:
            node.export_metrics()
            maddr = await node.serve_metrics()
            print(f"[serve.net] {spec.node_id} metrics at "
                  f"http://{maddr}/metrics")
        ids = spec.cluster_ids
        rank, n = ids.index(spec.node_id), len(ids)
        mine = [s for s in range(n_sessions) if s % n == rank]
        print(f"[serve.net] {spec.node_id} listening on {node.addr} "
              f"({spec.transport}, policy={args.ship_policy}"
              f"{'+hier' if topo is not None else ''}, "
              f"{len(spec.peers)} peers, udp_loss={args.udp_loss}"
              f"{f', zone={node.zone}' if node.zone else ''}"
              f"{f', ttl={spec.session_ttl}s' if spec.session_ttl else ''}"
              f"); writing {len(mine)}/{n_sessions} sessions")
        for s in mine:
            for status in ("queued", "prefilling", "decoding", "done"):
                node.update(f"sess{s}", MVRegister, "write_delta",
                            node.id, status)
            await asyncio.sleep(args.tick / 4)   # interleave with gossip
        keys = [f"sess{s}" for s in range(n_sessions)]
        deadline = node.time + args.run_for
        while node.time < deadline:
            node.check_healthy()
            if args.status_file:
                _write_status(args.status_file, node, keys, n_sessions)
            await asyncio.sleep(min(0.25, args.tick))
        if args.status_file:
            _write_status(args.status_file, node, keys, n_sessions)
        print(f"[serve.net] {spec.node_id} done: "
              f"{len(node.X.keys())}/{n_sessions} keys resident, "
              f"frame_bytes_by_kind={node.stats.bytes_by_kind}, "
              f"{node.stats.summary()}")
        await node.stop()

    asyncio.run(run())


def _write_status(path: str, node, keys, n_sessions: int) -> None:
    """Atomic heartbeat write (tmp + rename) so the harness never reads
    a torn JSON."""
    import json
    import os
    resident = node.X.keys()
    done = all(k in resident and node.replica.get(k, MVRegister) is not None
               and node.replica.get(k, MVRegister).read()
               == frozenset({"done"}) for k in keys)
    payload = {
        "id": node.id,
        "keys": len(resident),
        "expect": n_sessions,
        "all_done": done,
        "fingerprint": _session_fingerprint(node.replica, keys),
        "bytes_by_kind": node.stats.bytes_by_kind,
        "stats": node.stats.summary(),
        # zoned observability: where this member sits and how many of
        # its bytes were local vs cross-zone (empty/None on a flat mesh)
        "zone": node.zone,
        "bytes_by_class": node.stats.bytes_by_class,
        "recv_bytes_by_class": node.stats.recv_bytes_by_class,
        "tombstones": len(node.X.tombstoned_keys()),
    }
    if node.metrics_registry is not None:
        # --metrics: the harness gets the whole registry without having
        # to scrape the sidecar (and the sidecar address in case it does)
        payload["metrics_addr"] = node.metrics_addr
        payload["metrics"] = node.metrics_registry.snapshot()
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)


if __name__ == "__main__":
    main()
