"""End-to-end training driver.

Two modes (DESIGN.md §2):

* ``--mode sync``  — single-replica (or lockstep-SPMD) training with
  delta-interval checkpointing: snapshot every ``--snap-every`` steps,
  idempotent delta appends in between; crash at any point → restore =
  snapshot ⊔ deltas (Algorithm 2's durable-state discipline on disk).

* ``--mode delta`` — the paper's contribution end-to-end: ``--pods N``
  δ-CRDT replicas train local steps and gossip uniquely-dotted
  pseudo-gradient deltas over a lossy simulated network (loss/dup/reorder
  configurable); convergence is Prop. 1, not exactly-once delivery.

Defaults are smoke-scale; ``--arch qwen1.5-0.5b --steps 300 --seq 256``
exercises a ~0.5B-param model for a few hundred steps on CPU (the
assignment's end-to-end driver; see examples/train_delta_sync.py for the
scripted version)."""

from __future__ import annotations

import argparse
import os
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (DeltaCheckpointStore, pytree_from_state,
                              state_from_pytree)
from repro.configs import ARCH_IDS, get_config
from repro.core import (NetConfig, POLICY_SPECS, Simulator,
                        causal_policy_spec, converged, make_policy,
                        run_to_convergence)
from repro.data import SyntheticLMStream
from repro.models import init_model, train_loss
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, make_train_step
from repro.sync import DeltaSyncPod, TopKCompressor


def _init(cfg, seed):
    params, _ = init_model(cfg, jax.random.PRNGKey(seed))
    return params


def run_sync(args) -> None:
    cfg = get_config(args.arch, reduced=args.reduced)
    stream = SyntheticLMStream(vocab=cfg.vocab, seq=args.seq,
                               batch=args.batch, seed=args.seed)
    params = _init(cfg, args.seed)
    from repro.optim.adamw import init_opt_state
    opt_state = init_opt_state(params)
    tcfg = TrainConfig(optimizer=AdamWConfig(
        lr=args.lr, warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps))
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    store = DeltaCheckpointStore(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if store is not None and store.seq >= 0:
        state, seq = store.restore()
        if state.chunks:
            spec_state, spec = state_from_pytree(
                {"params": params, "opt": opt_state}, args.chunk, rank=0)
            restored = pytree_from_state(state, spec)
            params, opt_state = restored["params"], restored["opt"]
            start_step = int(np.asarray(opt_state["step"]))
            print(f"[restore] resumed at step {start_step} (ckpt seq {seq})")

    t0 = time.time()
    ck_seq = store.seq if store is not None else -1
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 stream.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if store is not None and (step + 1) % args.ckpt_every == 0:
            full, _spec = state_from_pytree(
                {"params": params, "opt": opt_state}, args.chunk, rank=0,
                lamport=step + 1)
            ck_seq += 1
            if ck_seq % args.snap_every == 0:
                store.save_snapshot(full, seq=ck_seq)
            else:
                store.append_delta(full, seq=ck_seq)  # idempotent join on restore
            store.gc(keep_snapshots=2)
    print(f"[done] {args.steps} steps in {time.time() - t0:.1f}s")


def run_delta(args) -> None:
    cfg = get_config(args.arch, reduced=True)  # delta demo is smoke-scale
    stream = SyntheticLMStream(vocab=cfg.vocab, seq=args.seq,
                               batch=args.batch, seed=args.seed)
    init_params = _init(cfg, args.seed)
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=args.lr,
                                             warmup_steps=5,
                                             total_steps=args.steps))
    from repro.optim.adamw import init_opt_state
    step_jit = jax.jit(make_train_step(cfg, tcfg))

    def local_update(params, round_idx, pod_id):
        # K local steps on this pod's data shard (fresh opt state per round
        # — pseudo-gradient outer loop)
        opt = init_opt_state(params)
        rank = int(pod_id.split("pod")[-1])
        p = params
        for k in range(args.local_steps):
            b = stream.batch_at(round_idx * args.local_steps + k, rank=rank)
            p, opt, m = step_jit(p, opt, {k2: jnp.asarray(v)
                                          for k2, v in b.items()})
        print(f"  [{pod_id}] round {round_idx} loss "
              f"{float(m['loss']):.4f}", flush=True)
        return p

    sim = Simulator(NetConfig(loss=args.net_loss, dup=0.1, seed=args.seed))
    ids = [f"pod{k}" for k in range(args.pods)]
    policy_spec = getattr(args, "ship_policy", "all")
    pods = [sim.add_node(DeltaSyncPod(
        i, [j for j in ids if j != i], init_params, local_update,
        num_pods=args.pods,
        compressor=(TopKCompressor(args.topk) if args.topk else None),
        rng=random.Random(args.seed + n),
        policy=make_policy(policy_spec)))
        for n, i in enumerate(ids)]

    rounds = max(1, args.steps // args.local_steps)
    for r in range(rounds):
        for p in pods:
            p.do_round()
        sim.run_for(5.0)  # anti-entropy gossip between rounds
    run_to_convergence(sim, pods, interval=1.0, max_time=50_000)
    assert converged(pods), "pods failed to converge"
    payload = sim.stats.payload_atoms()
    print(f"[done] {rounds} rounds × {args.local_steps} local steps on "
          f"{args.pods} pods over a lossy network (loss={args.net_loss}, "
          f"ship-policy={policy_spec}, payload_atoms={payload}); "
          f"all pods converged to identical outer params "
          f"({len(pods[0].X.dots)} dots merged)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--mode", default="sync", choices=["sync", "delta"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    # checkpointing
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--snap-every", type=int, default=5,
                    help="every Nth checkpoint is a full snapshot")
    ap.add_argument("--chunk", type=int, default=65536)
    # delta mode
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--net-loss", type=float, default=0.2)
    ap.add_argument("--topk", type=float, default=None,
                    help="top-k compression rate (e.g. 0.1)")
    def _policy_spec(s):
        try:             # fail at arg parsing, not after N training steps
            return causal_policy_spec(s, "delta-mode gossip")
        except ValueError as e:
            raise argparse.ArgumentTypeError(str(e))

    ap.add_argument("--ship-policy", default="all", type=_policy_spec,
                    help="delta-mode gossip shipping policy "
                         f"(e.g. {', '.join(POLICY_SPECS)})")
    args = ap.parse_args()
    if args.mode == "sync":
        run_sync(args)
    else:
        run_delta(args)


if __name__ == "__main__":
    main()
