"""Key lifecycle subsystem: TTL/expiry lattice, acked reaper GC, and the
helpers behind read-replica subscriptions.

The first subsystem that makes system-level state **non-monotone** while
every individual join stays a lattice join:

* ``lattice`` — the per-key ``(epoch, expiry)`` lifecycle lattice that
  :class:`~repro.core.store.LatticeStore` folds in next to each value
  (lex order: epochs totally ordered, expiry max-joined within an
  epoch). A *tombstone* is a bumped epoch with no value — compact, and
  ⊥-absorbing for every straggler delta of the reaped incarnation.
* ``reaper`` — the owner-driven reap protocol: the key's rendezvous
  owner proposes a reap once the expiry passes, collects ``reap-ack``
  frames from the key's whole write replica set, and only then commits
  the tombstone as an ordinary δ-mutation that gossips through the
  normal anti-entropy machinery.

Read replicas ride on :class:`~repro.sync.membership.KeyOwnership`'s
``reads()``/``subscribe()`` surface (write set vs the wider read set):
a subscriber pulls a hot key's rows via digest-sync without joining the
write replica set — or the reap quorum.
"""

from .lattice import (LIFE_BOTTOM, Life, NO_EXPIRY, expired, is_live,
                      life_join, tombstone, touch)
from .reaper import ReaperProtocol

__all__ = [
    "LIFE_BOTTOM", "Life", "NO_EXPIRY", "expired", "is_live",
    "life_join", "tombstone", "touch",
    "ReaperProtocol",
]
