"""The per-key lifecycle lattice: (tombstone epoch, LWW expiry).

A production keyed store must let keys *leave* as well as join, but the
paper's join-semilattice states only grow — Almeida et al.'s journal
version names state growth/GC as the price of monotone joins. This module
is the smallest lattice that buys non-monotone *system* behaviour from
monotone *joins*: every key of a :class:`~repro.core.store.LatticeStore`
carries a lifecycle value

    Life = (epoch: int, expiry: float)

ordered **lexicographically** — epochs are a total order, and within one
epoch the expiry is a monotone max (LWW extend-on-write). The per-key
store state is then the lexicographic product ``Life ×lex Value``:

* equal epochs   → expiries max-join and values join pointwise (normal
                   CRDT life; a ``touch`` extends the expiry, never
                   shrinks it);
* higher epoch   → wins wholesale: the winner's (expiry, value) replace
                   the loser's entirely. A *tombstone* is epoch ``e+1``
                   with a ⊥ value — one compact ``(key, epoch, expiry)``
                   triple that absorbs every straggler delta still at
                   epoch ``e`` (the ⊥-absorption the reaper relies on).

Lexicographic products of a chain with a lattice are lattices, so every
individual join is still a join: idempotent, commutative, associative,
safe under loss/duplication/reordering. What is *not* monotone is the
system-level resident size — joining a tombstone makes the store smaller.

Keys never touched by the lifecycle subsystem sit at ``LIFE_BOTTOM =
(0, -inf)`` (canonically absent), so stores that never expire anything
are byte- and semantics-identical to the pre-lifecycle format.

The reaper protocol that *produces* tombstones (owner proposal + replica
set ack quorum) lives in :mod:`repro.lifecycle.reaper`; this module is
deliberately dependency-free so :mod:`repro.core.store` can import it.
"""

from __future__ import annotations

from typing import Tuple

# Life = (epoch, expiry). Plain tuples: Python tuple comparison IS the
# lexicographic order, so join = max() and leq = <= need no wrapper class.
Life = Tuple[int, float]

NO_EXPIRY = float("-inf")          # "no TTL set": the expiry bottom
LIFE_BOTTOM: Life = (0, NO_EXPIRY)  # epoch 0, no expiry — the default


def life_join(a: Life, b: Life) -> Life:
    """Lex max: higher epoch wins wholesale; equal epochs max expiries.
    (``max`` on tuples is exactly this; the store's life joins and the
    digest filters go through here so the order has one home.)"""
    return a if a >= b else b


def is_live(life: Life) -> bool:
    """A life value that has an expiry to enforce (reap-eligible once it
    passes). Epoch alone does not make a key mortal."""
    return life[1] != NO_EXPIRY


def expired(life: Life, now: float) -> bool:
    """True iff the key has a TTL and it has passed."""
    return is_live(life) and now >= life[1]


def touch(life: Life, now: float, ttl: float) -> Life:
    """Extend-on-write: the new expiry within the current epoch. Always
    ≥ the old life (monotone), so concurrent touches merge to the latest
    deadline."""
    return (life[0], max(life[1], now + ttl))


def tombstone(life: Life, reaped_at: float) -> Life:
    """The life value a commit writes: next epoch, stamped with the acked
    expiry (kept for observability; a revival's touch supersedes it)."""
    return (life[0] + 1, reaped_at)
