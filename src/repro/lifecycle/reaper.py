"""Owner-driven reaper: acked tombstone GC for expired keys.

Dropping a key from a replicated store is the one operation a join
cannot express — so it must be *agreed*, not gossiped. The protocol
keeps the agreement surface as small as possible:

1. **Propose.** The key's rendezvous owner (``KeyOwnership.owner``)
   notices the key's expiry has passed (plus a ``grace`` slack for clock
   skew and in-flight touches) and sends a ``reap`` frame
   ``(key, epoch, expiry)`` to every *other* member of the key's write
   replica set. Read replicas subscribe to the key's gossip but are
   **not** in the quorum — they never gate a reap.
2. **Ack.** A member acks (``reap-ack … ok=1``) iff its own lifecycle
   state agrees the incarnation is dead: same epoch, no extension beyond
   the proposed expiry, and the expiry has passed on its clock too. A
   member that has seen a *later* epoch acks as well (the reap is
   already moot — committing ``epoch+1 ≤`` its epoch is absorbed). A
   member holding a fresher expiry nacks, which cancels the proposal
   until the new deadline passes.
3. **Commit.** Once the owner holds acks from the whole current replica
   set (re-derived every step, so departed workers never wedge the
   quorum), it re-checks its own agreement and commits the tombstone —
   ``LatticeStore.life_delta(key, (epoch+1, expiry))`` — as an ordinary
   δ-mutation through the engine. From there the tombstone propagates
   by the normal push/pull anti-entropy machinery, idempotently, and
   ⊥-absorbs every straggler delta still at the reaped epoch.

The quorum is what makes the drop safe under the paper's network model:
an un-acked member might hold (or later receive and forward) a write
the owner never saw; with its ack in hand, any such write is provably
bounded by the acked expiry, so absorbing it loses nothing the TTL
contract had promised to keep. A write that races the commit *after*
acking is the inherent TTL race — the ack window narrows it to the
commit round-trip, and a revived key starts a fresh incarnation above
the tombstone (``StoreReplica`` bumps the epoch on writes to a
tombstoned key), so late reaps can never kill a revival.

All protocol state is volatile (proposals restart after a crash — the
durable expiry makes them re-derivable), and per-peer ack state is
registered with the replica's peer-state registry so departed peers are
pruned in the same place as every other per-peer map.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, FrozenSet, Optional, Set, Tuple

from .lattice import expired, tombstone


@dataclass
class _Proposal:
    """One in-flight reap: the (epoch, expiry) snapshot it is valid for,
    the peers that acked it, the retransmit clock, and whether a member
    vetoed it (uncommittable until the next throttled retransmit)."""

    epoch: int
    expiry: float
    acks: Set[str] = field(default_factory=set)
    last_sent: float = float("-inf")
    nacked: bool = False


class ReaperProtocol:
    """The proposer half of acked tombstone GC, attached to one replica.

    Construction wires the protocol into the engine: ``replica.reaper``
    routes incoming ``reap``/``reap-ack`` messages here,
    ``Replica.on_periodic`` drives :meth:`step` every anti-entropy
    round, and the ack sets join the replica's per-peer state registry
    (pruned with departed peers, reset on crash recovery).

    Only the write replica set participates: proposals go to
    ``ownership.owners(key)``, and only the primary owner proposes.
    Replicas that merely *read* a key (``ownership.reads``) see the
    tombstone arrive through gossip like any other delta.
    """

    def __init__(self, replica: Any, ownership: Any, *,
                 grace: float = 0.0, retry: float = 3.0,
                 evict_foreign: bool = True,
                 clock: Optional[Callable[[], float]] = None):
        from ..core.store import LatticeStore   # lazy: core imports us

        if not isinstance(replica.X, LatticeStore):
            raise TypeError("ReaperProtocol needs a keyed replica "
                            "(StoreReplica / LatticeStore bottom)")
        self.replica = replica
        self.ownership = ownership
        self.grace = grace
        self.retry = retry
        self.evict_foreign = evict_foreign
        self._clock = clock
        self._pending: Dict[str, _Proposal] = {}
        self.reaped = 0                  # committed tombstones (stats)
        self.evicted = 0                 # dropped foreign copies (stats)
        replica.reaper = self
        replica.track_peer_state(self._prune_peers)

    # -- clock ------------------------------------------------------------------
    def now(self) -> float:
        return self._clock() if self._clock is not None else self.replica.now()

    # -- the periodic drive (from Replica.on_periodic) ---------------------------
    def step(self) -> int:
        """Scan for reap-eligible keys this replica owns, retransmit
        outstanding proposals, commit fully-acked ones, and drop
        *foreign* expired copies (keys this replica neither write- nor
        read-replicates — e.g. an ingress gateway's local copy of a
        session it forwarded to the owners; the tombstone never routes
        here, so without local eviction those copies linger forever).
        Foreign eviction is purely local and best-effort: the key's
        convergence obligations rest entirely on its replica set, and
        the causal delta buffer — not ``X`` — is what re-ships an
        undelivered write. Returns the number of tombstones committed
        this step."""
        store = self.replica.X
        # one dict materialization for the whole scan (life_of/tombstoned
        # per key would rebuild these tuples O(keys) times per round)
        life = dict(store.life)
        values = store.as_dict()
        now = self.now()
        committed = 0
        evict = []
        for key in sorted(store.all_keys()):
            epoch, expiry = life.get(key, (0, float("-inf")))
            tombstoned = epoch > 0 and key not in values
            if tombstoned or not expired((epoch, expiry),
                                         now - self.grace):
                self._pending.pop(key, None)
                if tombstoned and self.evict_foreign and self._foreign(key):
                    evict.append(key)    # someone else's tombstone: shed
                continue
            if self.replica.id not in self.ownership.owners(key):
                self._pending.pop(key, None)
                if self.evict_foreign and self._foreign(key):
                    evict.append(key)    # expired ingress copy: shed
                continue
            if self.ownership.owner(key) != self.replica.id:
                self._pending.pop(key, None)   # member, but not proposer
                continue
            prop = self._pending.get(key)
            if prop is None or (prop.epoch, prop.expiry) != (epoch, expiry):
                # fresh proposal (or the key was touched: start over —
                # stale acks must not commit against a newer expiry)
                prop = _Proposal(epoch, expiry)
                self._pending[key] = prop
            members = self._quorum(key)
            if not prop.nacked and members <= prop.acks:
                if self._commit(key, prop):
                    committed += 1
                continue
            if now - prop.last_sent >= self.retry:
                prop.nacked = False      # give the nacker a fresh vote
                for dst in members - prop.acks:
                    self.replica._post(dst, ("reap", key, epoch, expiry))
                prop.last_sent = now
        if evict:
            # restrict the CURRENT X, not the loop-entry snapshot: a
            # commit above already advanced it, and assigning the stale
            # snapshot back would discard the just-committed tombstone
            cur = self.replica.X
            self.replica.X = cur.restrict(cur.all_keys() - set(evict))
            self.evicted += len(evict)
        return committed

    def _foreign(self, key: str) -> bool:
        """Neither in the key's write set nor its read set (and the key
        *has* a live replica set to carry it) — safe to shed locally."""
        owners = self.ownership.owners(key)
        return (bool(owners) and self.replica.id not in owners
                and not self.ownership.reads(self.replica.id, key))

    def _quorum(self, key: str) -> FrozenSet[str]:
        """The acks a commit needs: every *current* write-set member but
        this replica — recomputed per step, so a departed worker leaves
        the quorum instead of wedging it."""
        return frozenset(self.ownership.owners(key)) - {self.replica.id}

    def _commit(self, key: str, prop: _Proposal) -> bool:
        from ..core.store import LatticeStore

        self._pending.pop(key, None)
        epoch, expiry = self.replica.X.life_of(key)
        if (epoch, expiry) != (prop.epoch, prop.expiry):
            return False             # touched between final ack and commit
        self.replica.operation(lambda S: LatticeStore.life_delta(
            key, tombstone((prop.epoch, prop.expiry), prop.expiry)))
        self.reaped += 1
        tracer = getattr(self.replica, "tracer", None)
        if tracer is not None:
            tracer.emit("reap_commit", key=key, epoch=prop.epoch,
                        acks=len(prop.acks))
        return True

    # -- message plane (routed from Replica.on_receive) ---------------------------
    def on_ack(self, src: str, msg: Tuple) -> None:
        """Fold one ``reap-ack`` into its proposal. (The request side —
        agreeing to someone *else's* proposal — lives on the engine
        itself, ``Replica._reap_agree``: a member votes from its own
        lifecycle state and clock and needs no reaper of its own.)"""
        _, key, epoch, expiry, ok = msg
        prop = self._pending.get(key)
        if prop is None or (prop.epoch, prop.expiry) != (epoch, expiry):
            return                   # stale ack for a superseded proposal
        if ok:
            prop.acks.add(src)
        else:
            # a member holds a fresher expiry / unseen incarnation: hold
            # the proposal open but uncommittable, keeping its retransmit
            # clock — popping it here would recreate it next step with a
            # fresh clock and bypass the retry throttle entirely. Gossip
            # converges the lifecycle state, after which either the
            # (epoch, expiry) snapshot changes (proposal restarts) or a
            # throttled retransmit collects the vote.
            prop.acks.discard(src)
            prop.nacked = True

    # -- registry hooks ------------------------------------------------------------
    def _prune_peers(self, live: FrozenSet[str]) -> None:
        """Departed peers leave every proposal's ack set (the quorum
        itself re-derives from live ownership each step)."""
        for prop in self._pending.values():
            prop.acks &= set(live)

    def reset(self) -> None:
        """Crash recovery: proposals are volatile (durable expiries make
        them re-derivable); stats survive for the process lifetime."""
        self._pending.clear()

    def pending_keys(self) -> FrozenSet[str]:
        return frozenset(self._pending)
