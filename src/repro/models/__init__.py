"""Model zoo: one composable decoder implementation, ten architectures."""

from .config import (LayerSpec, MLASpec, ModelConfig, MoESpec, SSMSpec,
                     layout_groups)
from .transformer import (decode_step, forward, init_caches, init_model,
                          prefill, train_loss)

__all__ = [
    "LayerSpec", "MLASpec", "ModelConfig", "MoESpec", "SSMSpec",
    "layout_groups", "decode_step", "forward", "init_caches", "init_model",
    "prefill", "train_loss",
]
