"""GQA attention with sliding windows, softcaps, bias, and KV caches.

Three entry points share one masked-softmax core:

* ``attend_full``   — training / prefill over the whole sequence (causal,
                      optionally sliding-window) and optionally emits the
                      KV cache for subsequent decode.
* ``attend_decode`` — one new token against a cache. Caches are fixed-size
                      ring buffers carrying each slot's absolute position,
                      which uniformly handles full caches (capacity =
                      max_len) and sliding-window caches (capacity =
                      window ≪ max_len — mixtral long_500k decodes with a
                      4k-slot ring, the sub-quadratic path).

The XLA einsum path below is the reference; on TPU the same contraction is
served by ``repro.kernels.flash_attention`` (Pallas) — selected via
``impl=`` in the model stack (the dry-run lowers the XLA path; kernels are
validated against ref.py in interpret mode).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .rope import apply_rope

NEG_INF = -2.0 ** 30  # large-negative in fp32, safe under bf16 casts


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(cfg, key, dtype) -> Tuple[Dict, Dict]:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    ks = jax.random.split(key, 4)
    sc = float(1.0 / np.sqrt(d))
    p = {
        "wq": jax.random.normal(ks[0], (d, H * hd), dtype) * sc,
        "wk": jax.random.normal(ks[1], (d, KV * hd), dtype) * sc,
        "wv": jax.random.normal(ks[2], (d, KV * hd), dtype) * sc,
        "wo": jax.random.normal(ks[3], (H * hd, d), dtype) * float(1.0 / np.sqrt(H * hd)),
    }
    s = {"wq": ("embed", "heads"), "wk": ("embed", "kv"),
         "wv": ("embed", "kv"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
        s["bq"], s["bk"], s["bv"] = ("heads",), ("kv",), ("kv",)
    return p, s


def _project_qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim()
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, H, hd)
    k = k.reshape(b, s, KV, hd)
    v = v.reshape(b, s, KV, hd)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    return q, k, v


def _mha_core(cfg, q, k, v, q_pos, k_pos, window: Optional[int],
              k_valid: Optional[jax.Array] = None) -> jax.Array:
    """q [b,s,H,hd] · k,v [b,t,KV,hd] with causal(+window) position masking.

    fp32 scores/softmax; GQA via head grouping (no kv repeat materialized).
    """
    b, s, H, hd = q.shape
    t = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = cfg.query_scale if cfg.query_scale else 1.0 / np.sqrt(hd)
    qg = q.reshape(b, s, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if cfg.attn_softcap:
        c = cfg.attn_softcap
        scores = c * jnp.tanh(scores / c)
    causal = k_pos[:, None, :] <= q_pos[:, :, None]              # [b,s,t]
    if window is not None:
        causal &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    if k_valid is not None:
        causal &= k_valid[:, None, :]
    scores = jnp.where(causal[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(b, s, H, hd)


def _mha_chunked(cfg, q, k, v, q_pos, k_pos, window: Optional[int],
                 block: int) -> jax.Array:
    """Trace-time flash attention (the XLA build of kernels/flash_attention).

    Static python loops over (q-block × k-block) tiles with online softmax:
    only [bq × bk] fp32 tiles ever materialize (vs the naive [s × s]
    scores), and tiles that are entirely above the causal diagonal or
    outside the sliding-window band are skipped AT TRACE TIME — so SWA
    layers get their true O(s·w) compute instead of O(s²), and causal
    attention drops the upper-triangle half. Assumes row-major positions
    (q_pos/k_pos are arange), which attend_full guarantees.
    """
    b, s, H, hd = q.shape
    t = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    bq = min(block, s)
    bk = min(block, t)
    if s % bq or t % bk:
        return _mha_core(cfg, q, k, v, q_pos, k_pos, window)
    nq, nk = s // bq, t // bk
    scale = cfg.query_scale if cfg.query_scale else 1.0 / np.sqrt(hd)

    out_blocks = []
    for iq in range(nq):
        sl = slice(iq * bq, (iq + 1) * bq)
        qg = q[:, sl].reshape(b, bq, KV, G, hd)
        qp = q_pos[:, sl]
        m = jnp.full((b, KV, G, bq), NEG_INF, jnp.float32)
        l = jnp.zeros((b, KV, G, bq), jnp.float32)
        acc = jnp.zeros((b, KV, G, bq, hd), jnp.float32)
        for ik in range(nk):
            k_start, k_end = ik * bk, (ik + 1) * bk
            q_start, q_end = iq * bq, (iq + 1) * bq
            if k_start > q_end - 1:
                continue                      # fully above the diagonal
            if window is not None and (q_start - (k_end - 1)) >= window:
                continue                      # fully outside the SWA band
            kb = k[:, k_start:k_end]
            vb = v[:, k_start:k_end]
            kp = k_pos[:, k_start:k_end]
            sc = jnp.einsum("bqkgh,btkh->bkgqt", qg, kb,
                            preferred_element_type=jnp.float32) * scale
            if cfg.attn_softcap:
                c = cfg.attn_softcap
                sc = c * jnp.tanh(sc / c)
            mask = kp[:, None, :] <= qp[:, :, None]
            if window is not None:
                mask &= (qp[:, :, None] - kp[:, None, :]) < window
            mask = mask[:, None, None, :, :]   # [b,1,1,bq,bk]
            sc_masked = jnp.where(mask, sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc_masked, axis=-1))
            alpha = jnp.exp(m - m_new)
            pprob = jnp.where(mask, jnp.exp(sc - m_new[..., None]), 0.0)
            l = l * alpha + jnp.sum(pprob, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", pprob.astype(v.dtype), vb
            ).astype(jnp.float32)
            m = m_new
        safe_l = jnp.where(l > 0, l, 1.0)
        ob = (acc / safe_l[..., None]).astype(q.dtype)  # [b,KV,G,bq,hd]
        out_blocks.append(ob.transpose(0, 3, 1, 2, 4).reshape(b, bq, H, hd))
    return jnp.concatenate(out_blocks, axis=1)


# ---------------------------------------------------------------------------
# Full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def attend_full(p: Dict, cfg, spec, x: jax.Array, positions: jax.Array,
                make_cache: Optional[int] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    """x [b,s,d] → (y [b,s,d], cache or None).

    ``make_cache``: capacity of the decode cache to emit (≥ s for full
    attention; == window for SWA layers)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cfg.attn_impl == "chunked":
        y = _mha_chunked(cfg, q, k, v, positions, positions, spec.window,
                         cfg.attn_block)
    else:
        y = _mha_core(cfg, q, k, v, positions, positions, spec.window)
    y = jnp.einsum("bsh,he->bse", y.reshape(b, s, -1), p["wo"])
    cache = None
    if make_cache is not None:
        cache = init_kv_cache(b, make_cache, cfg.n_kv_heads,
                              cfg.resolved_head_dim(), k.dtype)
        cache = cache_append(cache, k, v, positions)
    return y, cache


# ---------------------------------------------------------------------------
# KV cache (ring buffer with per-slot absolute positions)
# ---------------------------------------------------------------------------

def init_kv_cache(b: int, capacity: int, kv_heads: int, head_dim: int,
                  dtype) -> Dict[str, jax.Array]:
    return {
        "k": jnp.zeros((b, capacity, kv_heads, head_dim), dtype),
        "v": jnp.zeros((b, capacity, kv_heads, head_dim), dtype),
        "pos": jnp.full((b, capacity), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),   # total tokens ever written
    }


def cache_append(cache: Dict, k: jax.Array, v: jax.Array,
                 positions: jax.Array) -> Dict:
    """Append s tokens (prefill bulk write or single decode step)."""
    C = cache["k"].shape[1]
    s = k.shape[1]
    slots = (cache["idx"] + jnp.arange(s, dtype=jnp.int32)) % C
    k_new = cache["k"].at[:, slots].set(k)
    v_new = cache["v"].at[:, slots].set(v)
    pos_new = cache["pos"].at[:, slots].set(positions.astype(jnp.int32))
    return {"k": k_new, "v": v_new, "pos": pos_new,
            "idx": cache["idx"] + s}


def attend_decode(p: Dict, cfg, spec, x: jax.Array, positions: jax.Array,
                  cache: Dict) -> Tuple[jax.Array, Dict]:
    """One-token step: x [b,1,d], cache holds the history."""
    b = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, positions)
    cache = cache_append(cache, k, v, positions)
    k_valid = cache["pos"] >= 0
    y = _mha_core(cfg, q, cache["k"], cache["v"], positions, cache["pos"],
                  spec.window, k_valid=k_valid)
    y = jnp.einsum("bsh,he->bse", y.reshape(b, 1, -1), p["wo"])
    return y, cache
