"""Model configuration schema + layer-layout machinery.

A ``ModelConfig`` fully describes one architecture from the assigned pool
(dense / GQA / MLA / MoE / SSM / hybrid / VLM / audio backbones). The
per-layer structure is a list of ``LayerSpec``; ``layout_groups`` factors
it into scan-able groups (smallest repeating super-block, else runs of
identical specs) so the compiled HLO stays small for 46-60 layer stacks —
essential for the 512-device dry-run compile times.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    expert_d_ff: int
    num_shared_experts: int = 0
    shared_d_ff: int = 0          # per shared expert
    router_noise: float = 0.0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class MLASpec:
    """DeepSeek-V2 multi-head latent attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class LayerSpec:
    """One decoder block: attention (or SSM) + MLP (dense or MoE)."""
    kind: str = "attn"            # "attn" | "mla" | "ssm"
    window: Optional[int] = None  # sliding-window size (None = full/global)
    mlp: str = "dense"            # "dense" | "moe"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # "dense"|"moe"|"ssm"|"hybrid"|"vlm"|"audio"
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attn-free
    n_kv_heads: int
    d_ff: int                     # dense-MLP hidden size (0 if none)
    vocab: int
    head_dim: Optional[int] = None           # default d_model // n_heads
    layout: Tuple[LayerSpec, ...] = ()
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    mla: Optional[MLASpec] = None
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0       # partial rotary (stablelm: 0.25)
    attn_softcap: Optional[float] = None      # gemma2: 50.0
    final_softcap: Optional[float] = None     # gemma2: 30.0
    query_scale: Optional[float] = None       # gemma2: 1/sqrt(query_pre_attn)
    # block details
    norm: str = "rms"             # "rms" | "ln"
    act: str = "swiglu"           # "swiglu" | "geglu" | "gelu"
    post_norms: bool = False      # gemma2 post-attn/post-ffn norms
    pos: str = "rope"             # "rope" | "sinusoidal" | "none"
    scale_embed: bool = False     # gemma2: embed * sqrt(d_model)
    tie_embeddings: bool = False
    # modality frontend (STUB): inputs arrive as precomputed embeddings
    input_mode: str = "tokens"    # "tokens" | "embeds" | "tokens+prefix"
    prefix_len: int = 0           # vlm: number of patch-embedding positions
    # attention execution path: "naive" materializes [s,s] scores (XLA
    # default); "chunked" is the trace-time flash build (tile-skipped,
    # online softmax) — the XLA twin of kernels/flash_attention
    attn_impl: str = "naive"
    attn_block: int = 2048
    # MoE execution path: "global" single dispatch (mesh-free reference);
    # "local" shard_map per-shard dispatch (EP all-to-all / TP psum)
    moe_impl: str = "global"
    # numerics
    dtype: str = "bfloat16"
    # long-context capability: True iff decode state is o(seq_len)
    subquadratic: bool = False

    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    def default_layout(self) -> Tuple[LayerSpec, ...]:
        if self.layout:
            return self.layout
        return tuple(LayerSpec() for _ in range(self.n_layers))

    # -- parameter count (for 6·N·D roofline bookkeeping) ---------------------
    def param_counts(self) -> Tuple[int, int]:
        """(total_params, active_params_per_token)."""
        d, hd = self.d_model, (self.resolved_head_dim() if self.n_heads else 0)
        # active counts the LM-head matmul once; the token-embedding gather
        # is not a matmul (0 FLOPs), so it never enters MODEL_FLOPS
        total = self.vocab * d
        active = self.vocab * d
        if not self.tie_embeddings:
            total += self.vocab * d
        for spec in self.default_layout():
            t = a = 0
            if spec.kind == "attn":
                q = d * self.n_heads * hd + (self.n_heads * hd if self.qkv_bias else 0)
                kv = 2 * (d * self.n_kv_heads * hd + (self.n_kv_heads * hd if self.qkv_bias else 0))
                o = self.n_heads * hd * d
                t = a = q + kv + o
            elif spec.kind == "mla":
                m = self.mla
                qh = m.qk_nope_head_dim + m.qk_rope_head_dim
                t = a = (d * m.q_lora_rank
                         + m.q_lora_rank * self.n_heads * qh
                         + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                         + m.kv_lora_rank * self.n_heads
                         * (m.qk_nope_head_dim + m.v_head_dim)
                         + self.n_heads * m.v_head_dim * d)
            elif spec.kind == "ssm":
                s = self.ssm
                d_in = s.expand * d
                nh = d_in // s.head_dim
                # in_proj (z,x,B,C,dt) + conv + out_proj + A,D
                t = a = (d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)
                         + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
                         + d_in * d + 2 * nh)
            if spec.mlp == "none":
                pass
            elif spec.mlp == "dense":
                gates = 2 if self.act in ("swiglu", "geglu") else 1
                t_mlp = (gates + 1) * d * self.d_ff
                t += t_mlp
                a += t_mlp
            elif spec.mlp == "moe":
                m = self.moe
                gates = 2 if self.act in ("swiglu", "geglu") else 1
                per_expert = (gates + 1) * d * m.expert_d_ff
                shared = m.num_shared_experts * (gates + 1) * d * m.shared_d_ff
                router = d * m.num_experts
                t += m.num_experts * per_expert + shared + router
                a += m.top_k * per_expert + shared + router
            total += t
            active += a
        return total, active


# ---------------------------------------------------------------------------
# Layout factoring for scan-over-layers
# ---------------------------------------------------------------------------

def layout_groups(layout: Sequence[LayerSpec]) -> List[Tuple[Tuple[LayerSpec, ...], int]]:
    """Factor the layer list into (super_block, repeats) groups.

    Preference order:
      1. smallest period p with layout[i] == layout[i mod p]  → one group,
         super-block of p layers scanned L/p times (gemma2 p=2, jamba p=8);
      2. otherwise runs of identical consecutive specs, each scanned
         (deepseek-v2: [dense]×1 + [moe]×59).

    The compiled HLO contains each distinct super-block body once.
    """
    L = len(layout)
    # p == L is excluded: "repeating once" is no repetition, and accepting
    # it would unroll heterogeneous stacks (e.g. deepseek's 1+59 layout)
    # into one giant super-block.
    for p in range(1, L):
        if L % p != 0:
            continue
        if all(layout[i] == layout[i % p] for i in range(L)):
            return [(tuple(layout[:p]), L // p)]
    # runs fallback
    groups: List[Tuple[Tuple[LayerSpec, ...], int]] = []
    i = 0
    while i < L:
        j = i
        while j < L and layout[j] == layout[i]:
            j += 1
        groups.append(((layout[i],), j - i))
        i = j
    return groups
