"""Activation-sharding hints (mesh-optional).

Models are mesh-agnostic; the launcher installs a logical→mesh mapping and
models drop ``hint(x, ("batch", None, None))`` markers at the few places
where GSPMD's default strategy is known to go wrong — without a mesh the
hints are no-ops.

Why this exists: with ZeRO-3 parameters (weight embed-dim sharded on the
FSDP axis) and batch sharded on the same axis, the SPMD partitioner may
resolve the contraction by all-gathering the *activations* over batch
(observed: 40 GB/step logits gathers at train_4k) instead of un-sharding
the small weight. Pinning activations to ("batch", …) forces the
weight-gather (ZeRO) strategy.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@contextmanager
def activation_rules(mesh: Optional[Mesh], rules: Dict[str, Any]):
    """rules: logical activation axis → mesh axis (str/tuple) or None."""
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, dict(rules)) if mesh is not None else None
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_rules():
    """(mesh, rules) if a launcher installed them, else None — lets model
    code choose manual shard_map paths when a mesh is present."""
    return getattr(_STATE, "ctx", None)


def hint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(axes) != x.ndim:
        return x
    mapped = []
    used: set = set()
    for dim, name in zip(x.shape, axes):
        m = rules.get(name) if name is not None else None
        if m is None:
            mapped.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        size = 1
        for a in ms:
            size *= mesh.shape[a]
        if dim % size != 0 or any(a in used for a in ms):
            mapped.append(None)
            continue
        used.update(ms)
        mapped.append(m)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*mapped)))


def default_rules(multi_pod: bool, serve: bool = False) -> Dict[str, Any]:
    return {
        "batch": ("pod", "data") if multi_pod else "data",
        "tokens": ("pod", "data") if multi_pod else "data",
        "vocab": "model",
        "heads": "model",
        "mlp": "model",
        "expert": "model",
        # FSDP candidate axes for manual (shard_map) weight gathers —
        # empty at inference (params replicated over batch axes when they
        # fit; see dist.shardings.make_rules(serve=True))
        "fsdp_candidates": [] if serve else (
            [("pod", "data"), ("data",)] if multi_pod else [("data",)]),
    }
