"""Shared neural layers: norms, MLPs, embeddings, softcaps, positions.

Everything is a plain function over pytrees (no framework): ``init_*``
builds (params, pspec) pairs where ``pspec`` mirrors the param tree with
*logical axis names* per dimension — the distribution layer
(repro.dist.shardings) maps logical names → mesh axes with divisibility
fallbacks. Compute dtype is the config dtype (bf16); accumulations that
matter (logits, softmax, norms) run in fp32.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hints import hint

# Logical axis vocabulary (see repro/dist/shardings.py for the rule table):
#   "vocab"   embedding-table rows            → model axis
#   "embed"   model width                     → data axis (FSDP dim)
#   "mlp"     feed-forward hidden             → model axis
#   "heads"   q-head (or flattened head·dim)  → model axis
#   "kv"      kv-head dimension               → model if divisible
#   "expert"  MoE expert dimension            → model if divisible
#   "lora"    MLA latent dims                 → replicated
#   None      replicated


def shape_of(params: Any) -> Any:
    return jax.tree_util.tree_map(lambda x: x.shape, params)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg, d: int) -> Tuple[Dict, Dict]:
    if cfg.norm == "rms":
        return ({"scale": jnp.ones((d,), jnp.float32)},
                {"scale": ("embed",)})
    return ({"scale": jnp.ones((d,), jnp.float32),
             "bias": jnp.zeros((d,), jnp.float32)},
            {"scale": ("embed",), "bias": ("embed",)})


def apply_norm(p: Dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    """Statistics in fp32; the wide elementwise path stays in the compute
    dtype. Keeping the [b, s, d]-shaped values (and hence their
    cotangents) in bf16 is what keeps the TP activation-grad psums in bf16
    — with a fully-fp32 norm, GSPMD all-reduced fp32 dx partials (observed
    2× collective bytes on the mixtral train_4k probe)."""
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * p["scale"].astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return ((x - mu.astype(x.dtype)) * inv * p["scale"].astype(x.dtype)
            + p["bias"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, d: int, d_ff: int, dtype) -> Tuple[Dict, Dict]:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = float(1.0 / np.sqrt(d))
    scale_out = float(1.0 / np.sqrt(d_ff))
    gated = cfg.act in ("swiglu", "geglu")
    p = {"wi": jax.random.normal(k1, (d, d_ff), dtype) * scale_in,
         "wo": jax.random.normal(k3, (d_ff, d), dtype) * scale_out}
    s = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if gated:
        p["wg"] = jax.random.normal(k2, (d, d_ff), dtype) * scale_in
        s["wg"] = ("embed", "mlp")
    return p, s


def apply_mlp(p: Dict, x: jax.Array, act: str) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        gate = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = gate * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embedding(cfg, key, dtype) -> Tuple[Dict, Dict]:
    k1, k2 = jax.random.split(key)
    p = {"tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), dtype) * 0.02}
    s = {"tok": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(k2, (cfg.d_model, cfg.vocab), dtype) * 0.02
        s["head"] = ("embed", "vocab")
    return p, s


def embed_tokens(p: Dict, cfg, tokens: jax.Array) -> jax.Array:
    x = p["tok"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(p: Dict, cfg, x: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    x = hint(x, ("batch",) + (None,) * (x.ndim - 1))
    logits = jnp.einsum("...d,dv->...v", x, w,
                        preferred_element_type=jnp.float32)
    logits = hint(logits, ("batch",) + (None,) * (x.ndim - 2) + ("vocab",))
    if cfg.final_softcap:
        c = cfg.final_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Positions (non-rope)
# ---------------------------------------------------------------------------

def sinusoidal_positions(positions: jax.Array, d: int,
                         dtype=jnp.float32) -> jax.Array:
    """[.., s] int positions → [.., s, d] sinusoidal embeddings."""
    half = d // 2
    freqs = np.exp(-np.log(10_000.0) * np.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy in fp32. logits [..., v], labels [...].

    Sharding-friendly on a vocab-partitioned logits tensor: the gold-logit
    extraction is an iota-compare-select fused into a reduction (partial
    sum + small all-reduce), NOT take_along_axis — a vocab gather would
    force GSPMD to all-gather the full [b, s, vocab] logits (tens of GB at
    train_4k shapes; observed before this fix as a 74 GB/step all-gather
    and a 126 GB/device temp in the dry-run)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    gold = jnp.sum(jnp.where(vpos == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
