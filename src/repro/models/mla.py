"""Multi-head Latent Attention (DeepSeek-V2).

Queries and keys/values are factored through low-rank latents; the decode
cache stores ONLY the compressed kv-latent (kv_lora=512) plus the shared
rope key (64) per token — independent of the 128 heads — and decode runs
with *weight absorption*: scores are computed directly in latent space
(q_nope absorbed through W_uk, outputs through W_uv), so a 32k-token cache
is 576 floats/token instead of 128·(192+128) = 40960. This is the paper's
"ship the small thing, reconstruct at the consumer" pattern applied to
attention state, and it is what makes deepseek-v2 decode memory-feasible in
the dry-run.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import NEG_INF
from .layers import apply_norm
from .rope import apply_rope


def init_mla(cfg, key, dtype) -> Tuple[Dict, Dict]:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    sc = lambda n: float(1.0 / np.sqrt(n))
    p = {
        "w_dq": jax.random.normal(ks[0], (d, m.q_lora_rank), dtype) * sc(d),
        "w_uq": jax.random.normal(ks[1], (m.q_lora_rank, H * qh), dtype) * sc(m.q_lora_rank),
        "w_dkv": jax.random.normal(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype) * sc(d),
        "w_uk": jax.random.normal(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype) * sc(m.kv_lora_rank),
        "w_uv": jax.random.normal(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dtype) * sc(m.kv_lora_rank),
        "wo": jax.random.normal(ks[5], (H * m.v_head_dim, d), dtype) * sc(H * m.v_head_dim),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), jnp.float32)},
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), jnp.float32)},
    }
    s = {
        "w_dq": ("embed", "lora"), "w_uq": ("lora", "heads"),
        "w_dkv": ("embed", "lora"), "w_uk": ("lora", "heads"),
        "w_uv": ("lora", "heads"), "wo": ("heads", "embed"),
        "q_norm": {"scale": (None,)}, "kv_norm": {"scale": (None,)},
    }
    return p, s


def _queries(p, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    H = cfg.n_heads
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = apply_norm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["w_dq"]),
                    "rms")
    q = jnp.einsum("bsr,rh->bsh", cq, p["w_uq"]).reshape(b, s, H, qh)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, cfg, x, positions):
    m = cfg.mla
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    ckv = apply_norm(p["kv_norm"], ckv_full[..., :m.kv_lora_rank], "rms")
    k_rope = apply_rope(ckv_full[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)[:, :, 0]   # single shared rope head
    return ckv, k_rope


def _mla_attend_naive(cfg, q_nope, q_rope, k_nope, k_rope, v, positions):
    m = cfg.mla
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bshn,bthn->bhst", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshr,btr->bhst", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    causal = positions[:, None, :] <= positions[:, :, None]
    scores = jnp.where(causal[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthv->bshv", probs, v)


def _mla_attend_chunked(cfg, q_nope, q_rope, k_nope, k_rope, v, positions,
                        block: int):
    """Trace-time flash MLA: [bq × bk] tiles + online softmax, upper-
    triangle tiles skipped statically (see attention._mha_chunked)."""
    m = cfg.mla
    b, s, H, nd = q_nope.shape
    t = k_nope.shape[1]
    vd = v.shape[-1]
    bq = min(block, s)
    bk = min(block, t)
    if s % bq or t % bk:
        return _mla_attend_naive(cfg, q_nope, q_rope, k_nope, k_rope, v,
                                 positions)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    out_blocks = []
    for iq in range(s // bq):
        sl = slice(iq * bq, (iq + 1) * bq)
        qn, qr, qp = q_nope[:, sl], q_rope[:, sl], positions[:, sl]
        mstat = jnp.full((b, H, bq), NEG_INF, jnp.float32)
        l = jnp.zeros((b, H, bq), jnp.float32)
        acc = jnp.zeros((b, H, bq, vd), jnp.float32)
        for ik in range(t // bk):
            if ik * bk > (iq + 1) * bq - 1:
                continue                      # above the diagonal
            ksl = slice(ik * bk, (ik + 1) * bk)
            sc = (jnp.einsum("bshn,bthn->bhst", qn, k_nope[:, ksl],
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshr,btr->bhst", qr, k_rope[:, ksl],
                               preferred_element_type=jnp.float32)) * scale
            mask = (positions[:, ksl][:, None, :] <= qp[:, :, None])
            mask = mask[:, None, :, :]                        # [b,1,bq,bk]
            sc_masked = jnp.where(mask, sc, NEG_INF)
            m_new = jnp.maximum(mstat, jnp.max(sc_masked, axis=-1))
            alpha = jnp.exp(mstat - m_new)
            pprob = jnp.where(mask, jnp.exp(sc - m_new[..., None]), 0.0)
            l = l * alpha + jnp.sum(pprob, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhst,bthv->bhsv", pprob.astype(v.dtype), v[:, ksl]
            ).astype(jnp.float32)
            mstat = m_new
        safe_l = jnp.where(l > 0, l, 1.0)
        ob = (acc / safe_l[..., None]).astype(q_nope.dtype)
        out_blocks.append(ob.transpose(0, 2, 1, 3))           # [b,bq,H,vd]
    return jnp.concatenate(out_blocks, axis=1)


def mla_full(p: Dict, cfg, spec, x: jax.Array, positions: jax.Array,
             make_cache: Optional[int] = None
             ) -> Tuple[jax.Array, Optional[Dict]]:
    """Train/prefill: materialized keys/values (matmul-rich, MXU-friendly)."""
    m = cfg.mla
    b, s, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _queries(p, cfg, x, positions)
    ckv, k_rope = _latents(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rh->bsh", ckv, p["w_uk"]) \
        .reshape(b, s, H, m.qk_nope_head_dim)
    v = jnp.einsum("bsr,rh->bsh", ckv, p["w_uv"]) \
        .reshape(b, s, H, m.v_head_dim)
    if cfg.attn_impl == "chunked":
        out = _mla_attend_chunked(cfg, q_nope, q_rope, k_nope, k_rope, v,
                                  positions, cfg.attn_block)
    else:
        out = _mla_attend_naive(cfg, q_nope, q_rope, k_nope, k_rope, v,
                                positions)
    out = out.reshape(b, s, -1)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    cache = None
    if make_cache is not None:
        cache = init_mla_cache(b, make_cache, m, ckv.dtype)
        cache = mla_cache_append(cache, ckv, k_rope, positions)
    return y, cache


def init_mla_cache(b: int, capacity: int, m, dtype) -> Dict[str, jax.Array]:
    return {
        "ckv": jnp.zeros((b, capacity, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((b, capacity, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((b, capacity), -1, jnp.int32),
        "idx": jnp.zeros((), jnp.int32),
    }


def mla_cache_append(cache, ckv, k_rope, positions):
    C = cache["ckv"].shape[1]
    s = ckv.shape[1]
    slots = (cache["idx"] + jnp.arange(s, dtype=jnp.int32)) % C
    return {
        "ckv": cache["ckv"].at[:, slots].set(ckv),
        "krope": cache["krope"].at[:, slots].set(k_rope),
        "pos": cache["pos"].at[:, slots].set(positions.astype(jnp.int32)),
        "idx": cache["idx"] + s,
    }


def mla_decode(p: Dict, cfg, spec, x: jax.Array, positions: jax.Array,
               cache: Dict) -> Tuple[jax.Array, Dict]:
    """Weight-absorbed decode over the latent cache (576 B-ish per token)."""
    m = cfg.mla
    b = x.shape[0]
    H = cfg.n_heads
    q_nope, q_rope = _queries(p, cfg, x, positions)          # [b,1,H,·]
    ckv, k_rope = _latents(p, cfg, x, positions)
    cache = mla_cache_append(cache, ckv, k_rope, positions)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)       # absorb W_uk
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, cache["ckv"],
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshr,btr->bhst", q_rope, cache["krope"],
                           preferred_element_type=jnp.float32)) * scale
    valid = (cache["pos"][:, None, :] >= 0) & (cache["pos"][:, None, :]
                                               <= positions[:, :, None])
    scores = jnp.where(valid[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhst,btr->bshr", probs, cache["ckv"])
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", out_lat, w_uv).reshape(b, 1, -1)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return y, cache
