"""Mixture-of-Experts: sort-based dispatch, two execution paths.

``global`` (default, mesh-free): one sorted-scatter dispatch over the whole
token space. Correct everywhere, but under SPMD the data-dependent global
gather/scatter forces GSPMD to replicate the flat token tensors (observed:
157 GB/chip/layer of fp32 all-reduce on mixtral train_4k — the §Perf log's
baseline pathology).

``local`` (mesh present): shard_map local dispatch — the production path.
Tokens never leave their shard except through explicit, minimal
collectives:

* EP regime (num_experts % model-axis == 0 — deepseek 160, jamba 16): each
  (data, model) shard dispatches a DISJOINT token slice, routes it to the
  expert-owning model shards with one tiled all-to-all, computes its own
  experts at full width, reverses the all-to-all, combines locally, and
  all-gathers the token outputs over the model axis.
* TP regime (mixtral's 8 experts on a 16-way axis): every expert's FFN is
  width-sharded over the model axis; dispatch is model-replicated and the
  combined token output is one psum.

FSDP (embed-dim) weight shards are all-gathered explicitly (ZeRO-3), and
capacity is per-shard (standard practice; a straggler/locality win — noted
in DESIGN.md). The router is replicated (it is d·E ≪ anything).

Shared experts (deepseek) run densely outside the shard_map.

The Switch-style load-balance auxiliary loss is returned by both paths.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hints import current_rules

try:  # jax >= 0.6 moved shard_map around
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

# jax >= 0.6 renamed the replication-check kwarg check_rep → check_vma;
# accept either runtime.
_SHARD_MAP_CHECK_KW = (
    "check_vma" if "check_vma" in _inspect.signature(_shard_map).parameters
    else "check_rep")

from jax.sharding import PartitionSpec as P


def init_moe(cfg, key, dtype) -> Tuple[Dict, Dict]:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    sc_in, sc_out = float(1.0 / np.sqrt(d)), float(1.0 / np.sqrt(m.expert_d_ff))
    gated = cfg.act in ("swiglu", "geglu")
    p = {
        "router": jax.random.normal(ks[0], (d, m.num_experts), jnp.float32) * sc_in,
        "wi": jax.random.normal(ks[1], (m.num_experts, d, m.expert_d_ff), dtype) * sc_in,
        "wo": jax.random.normal(ks[2], (m.num_experts, m.expert_d_ff, d), dtype) * sc_out,
    }
    s = {
        "router": (None, None),            # replicated: d·E is tiny
        "wi": ("expert", "embed", "mlp"),
        "wo": ("expert", "mlp", "embed"),
    }
    if gated:
        p["wg"] = jax.random.normal(ks[3], (m.num_experts, d, m.expert_d_ff), dtype) * sc_in
        s["wg"] = ("expert", "embed", "mlp")
    if m.num_shared_experts:
        ff_sh = m.num_shared_experts * m.shared_d_ff
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": jax.random.normal(kk[0], (d, ff_sh), dtype) * sc_in,
            "wg": jax.random.normal(kk[1], (d, ff_sh), dtype) * sc_in,
            "wo": jax.random.normal(kk[2], (ff_sh, d), dtype) * float(1.0 / np.sqrt(ff_sh)),
        }
        s["shared"] = {"wi": ("embed", "mlp"), "wg": ("embed", "mlp"),
                       "wo": ("mlp", "embed")}
    return p, s


def _act(h, g, act: str):
    if act == "swiglu":
        return jax.nn.silu(g) * h
    if act == "geglu":
        return jax.nn.gelu(g) * h
    return jax.nn.gelu(h)


# ---------------------------------------------------------------------------
# Shared core: local sorted-scatter dispatch + combine (shape-local)
# ---------------------------------------------------------------------------

def _route(router, cfg, xf):
    """Returns (gate_vals [N,K], expert_ids [N,K], aux scalar)."""
    m = cfg.moe
    N = xf.shape[0]
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    counts = jnp.zeros((m.num_experts,), jnp.float32) \
        .at[expert_ids.reshape(-1)].add(1.0)
    frac = counts / (N * m.top_k)
    aux = m.num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
    return gate_vals, expert_ids, aux


def _dispatch_table(expert_ids, E: int, capacity: int):
    """Sorted-scatter table [E, C] of flat (token·K) indices; sentinel M."""
    N, K = expert_ids.shape
    M = N * K
    flat_experts = expert_ids.reshape(M)
    sort_idx = jnp.argsort(flat_experts)                 # stable
    sorted_experts = flat_experts[sort_idx]
    counts_i = jnp.zeros((E,), jnp.int32).at[flat_experts].add(1)
    starts = jnp.cumsum(counts_i) - counts_i             # exclusive cumsum
    pos_in_expert = jnp.arange(M, dtype=jnp.int32) - starts[sorted_experts]
    slot = jnp.where(pos_in_expert < capacity, pos_in_expert, capacity)
    table = jnp.full((E, capacity), M, jnp.int32)
    table = table.at[sorted_experts, slot].set(sort_idx.astype(jnp.int32),
                                               mode="drop")
    return table, M


def _gather_tokens(xf, table, K: int):
    N, d = xf.shape
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    return x_pad[table // K]                             # [E, C, d]


def _combine_tokens(y_e, gate_vals, table, N: int, K: int):
    M = N * K
    d = y_e.shape[-1]
    gates_flat = jnp.concatenate([gate_vals.reshape(M), jnp.zeros((1,))])
    w_e = gates_flat[table].astype(y_e.dtype)
    out_flat = jnp.zeros((M + 1, d), y_e.dtype) \
        .at[table.reshape(-1)].add((y_e * w_e[..., None]).reshape(-1, d))
    return jnp.sum(out_flat[:M].reshape(N, K, d), axis=1)


def _expert_ffn(p, cfg, x_e):
    h = jnp.einsum("ecd,edf->ecf", x_e, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", x_e, p["wg"]) if "wg" in p else None
    h = _act(h, g, cfg.act)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def _shared_experts(p, cfg, xf):
    sp = p["shared"]
    hs = jnp.einsum("nd,df->nf", xf, sp["wi"])
    gs = jnp.einsum("nd,df->nf", xf, sp["wg"])
    return jnp.einsum("nf,fd->nd", _act(hs, gs, cfg.act), sp["wo"])


# ---------------------------------------------------------------------------
# Global path (mesh-free reference)
# ---------------------------------------------------------------------------

def _apply_moe_global(p: Dict, cfg, x: jax.Array,
                      capacity: Optional[int] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    m = cfg.moe
    b, s, d = x.shape
    N = b * s
    xf = x.reshape(N, d)
    gate_vals, expert_ids, aux = _route(p["router"], cfg, xf)
    if capacity is None:
        capacity = max(1, int(math.ceil(N * m.top_k / m.num_experts
                                        * m.capacity_factor)))
    table, _ = _dispatch_table(expert_ids, m.num_experts, capacity)
    x_e = _gather_tokens(xf, table, m.top_k)
    y_e = _expert_ffn(p, cfg, x_e)
    y = _combine_tokens(y_e, gate_vals, table, N, m.top_k)
    if m.num_shared_experts:
        y = y + _shared_experts(p, cfg, xf)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Local path (shard_map, mesh present)
# ---------------------------------------------------------------------------

def _fsdp_axes(rules_map, dim: int, mesh) -> Optional[Tuple[str, ...]]:
    """Mirror dist/shardings: first FSDP candidate whose size divides dim."""
    default = [("pod", "data"), ("data",)] if "pod" in mesh.shape \
        else [("data",)]
    cands = rules_map.get("fsdp_candidates", default)
    for c in cands:
        size = 1
        for a in c:
            size *= mesh.shape[a]
        if dim % size == 0:
            return c
    return None


def _apply_moe_local(p: Dict, cfg, x: jax.Array, ctx
                     ) -> Tuple[jax.Array, jax.Array]:
    mesh, rules = ctx
    m = cfg.moe
    b, s, d = x.shape
    dp = rules["tokens"]
    dp = (dp,) if isinstance(dp, str) else tuple(dp)
    G = mesh.shape["model"]
    E = m.num_experts
    ep = (E % G == 0)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    b_loc = b // n_dp
    if b_loc == 0 or (ep and (b_loc * s) % G != 0):
        return _apply_moe_global(p, cfg, x)

    fsdp = _fsdp_axes(rules, d, mesh)
    # in_specs mirroring dist/shardings greedy assignment:
    if ep:
        wi_spec = P("model", fsdp if fsdp else None, None)
        wo_spec = P("model", None, fsdp if fsdp else None)
    else:
        tp_ok = (m.expert_d_ff % G == 0)
        if not tp_ok:
            return _apply_moe_global(p, cfg, x)
        wi_spec = P(None, fsdp if fsdp else None, "model")
        wo_spec = P(None, "model", fsdp if fsdp else None)

    def local_fn(xl, router, wi, wg, wo):
        bl, sl, dl = xl.shape
        xf = xl.reshape(-1, d)                            # [N_loc, d]
        N_loc = xf.shape[0]

        # ZeRO-3: explicit FSDP gather of this layer's expert weights
        if fsdp is not None:
            wi_f = jax.lax.all_gather(wi, fsdp, axis=1, tiled=True)
            wg_f = (jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
                    if wg is not None else None)
            wo_f = jax.lax.all_gather(wo, fsdp, axis=2, tiled=True)
        else:
            wi_f, wg_f, wo_f = wi, wg, wo
        pp = {"wi": wi_f, "wo": wo_f}
        if wg_f is not None:
            pp["wg"] = wg_f

        if ep:
            # each model shard dispatches a disjoint token slice
            chunk = N_loc // G
            i = jax.lax.axis_index("model")
            xme = jax.lax.dynamic_slice_in_dim(xf, i * chunk, chunk, 0)
            gate_vals, expert_ids, aux = _route(router, cfg, xme)
            cap = max(1, int(math.ceil(chunk * m.top_k / E
                                       * m.capacity_factor)))
            table, _ = _dispatch_table(expert_ids, E, cap)
            x_e = _gather_tokens(xme, table, m.top_k)     # [E, cap, d]
            # route to expert owners: one tiled all-to-all over model
            xa = jax.lax.all_to_all(x_e, "model", split_axis=0,
                                    concat_axis=1, tiled=True)
            y_own = _expert_ffn(pp, cfg, xa)              # [E/G, cap·G, d]
            y_e = jax.lax.all_to_all(y_own, "model", split_axis=1,
                                     concat_axis=0, tiled=True)
            y_me = _combine_tokens(y_e, gate_vals, table, chunk, m.top_k)
            y = jax.lax.all_gather(y_me, "model", axis=0, tiled=True)
            aux = jax.lax.psum(aux, dp + ("model",)) / (n_dp * G)
        else:
            # TP experts: model-replicated dispatch, width-sharded FFN,
            # one token-space psum
            gate_vals, expert_ids, aux = _route(router, cfg, xf)
            cap = max(1, int(math.ceil(N_loc * m.top_k / E
                                       * m.capacity_factor)))
            table, _ = _dispatch_table(expert_ids, E, cap)
            x_e = _gather_tokens(xf, table, m.top_k)
            y_e = _expert_ffn(pp, cfg, x_e)               # partial over f
            y = _combine_tokens(y_e, gate_vals, table, N_loc, m.top_k)
            y = jax.lax.psum(y, "model")
            aux = jax.lax.psum(aux, dp) / n_dp
        return y.reshape(bl, sl, dl), aux

    in_specs = (P(dp if len(dp) > 1 else dp[0], None, None),
                P(None, None), wi_spec,
                (wi_spec if "wg" in p else None), wo_spec)
    out_specs = (P(dp if len(dp) > 1 else dp[0], None, None), P())
    y, aux = _shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs,
                        **{_SHARD_MAP_CHECK_KW: False})(
        x, p["router"], p["wi"], p.get("wg"), p["wo"])

    if m.num_shared_experts:
        xf = x.reshape(b * s, d)
        y = y + _shared_experts(p, cfg, xf).reshape(b, s, d)
    return y, aux


def apply_moe(p: Dict, cfg, x: jax.Array,
              capacity: Optional[int] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x [b, s, d] → (y [b, s, d], aux_loss scalar)."""
    ctx = current_rules()
    if getattr(cfg, "moe_impl", "global") == "local" and ctx is not None:
        return _apply_moe_local(p, cfg, x, ctx)
    return _apply_moe_global(p, cfg, x, capacity)
