"""Rotary position embeddings, with partial-rotary support (stablelm)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rope_frequencies(head_dim: int, theta: float, rotary_pct: float = 1.0):
    rot = int(head_dim * rotary_pct)
    rot -= rot % 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return rot, jnp.asarray(inv, jnp.float32)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_pct: float = 1.0) -> jax.Array:
    """x: [b, s, h, hd]; positions: [b, s] (absolute)."""
    hd = x.shape[-1]
    rot, inv = rope_frequencies(hd, theta, rotary_pct)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., None].astype(jnp.float32) * inv      # [b, s, rot/2]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([rotated, xp], axis=-1) if rot < hd else rotated
