"""Mamba-2 SSD (state-space duality) block — chunked parallel form.

TPU adaptation (see DESIGN.md): the SSD algorithm is already the right
shape for the MXU — within-chunk computation is batched matmuls over
[chunk × chunk] and [chunk × d_state] tiles (chunk=256 keeps everything in
128-multiples), and the cross-chunk recurrence is a tiny ``lax.scan`` over
per-chunk decays/states. No warp-level primitives are needed; the GPU
implementation's shared-memory staging maps to VMEM tiles chosen by XLA
(and by our BlockSpecs if the Pallas path is enabled).

Decode is the O(1) recurrent step: conv-buffer shift + state update
``h ← exp(dt·a)·h + dt·B⊗x`` — constant memory in sequence length, which
is exactly why mamba2/jamba run the ``long_500k`` cell (DESIGN.md
§Arch-applicability).

Jamba note: Jamba's Mamba-1 (S6) layers are mapped onto this SSD block
(scalar-per-head A instead of per-channel); a faithful-in-spirit TPU
adaptation, recorded in DESIGN.md §changed-assumptions.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_norm


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, conv_dim


def init_ssm(cfg, key, dtype) -> Tuple[Dict, Dict]:
    s, d_in, nh, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    sc = float(1.0 / np.sqrt(d))
    # Separate z / xBC / dt projections (not one fused [d, d_in+conv+nh]
    # matrix): the fused width (e.g. mamba2's 3352) is rarely divisible by
    # the model axis, which forced full replication; split, z (1536) and
    # xBC (1792) shard cleanly and only the tiny dt head replicates.
    p = {
        "w_z": jax.random.normal(ks[0], (d, d_in), dtype) * sc,
        "w_xbc": jax.random.normal(ks[3], (d, conv_dim), dtype) * sc,
        "w_dt": jax.random.normal(ks[4], (d, nh), dtype) * sc,
        "conv_w": jax.random.normal(ks[1], (s.d_conv, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((d_in,), jnp.float32)},
        "w_out": jax.random.normal(ks[2], (d_in, d), dtype) * float(1.0 / np.sqrt(d_in)),
    }
    spec = {
        "w_z": ("embed", "mlp"), "w_xbc": ("embed", "mlp"),
        "w_dt": ("embed", None), "conv_w": (None, "mlp"),
        "conv_b": ("mlp",), "A_log": (None,), "D": (None,),
        "dt_bias": (None,), "norm": {"scale": ("mlp",)},
        "w_out": ("mlp", "embed"),
    }
    return p, spec


def _split_proj(p, cfg, x):
    z = jnp.einsum("bsd,dk->bsk", x, p["w_z"])
    xBC = jnp.einsum("bsd,dk->bsk", x, p["w_xbc"])
    dt = jnp.einsum("bsd,dk->bsk", x, p["w_dt"])
    return z, xBC, dt


def _causal_conv_full(p, xBC):
    """[b, s, conv_dim] depthwise causal conv, kernel k."""
    k = p["conv_w"].shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * p["conv_w"][i]
              for i in range(k))
    return jax.nn.silu(out + p["conv_b"])


def _segsum(log_a):
    """[..., Q] per-step log-decays → [..., Q, Q] lower-tri cumulative sums:
    out[i,j] = Σ_{j<k≤i} log_a[k] for i ≥ j, -inf otherwise."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # Σ(j..i]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssm_full(p: Dict, cfg, x: jax.Array,
             make_cache: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """Chunked SSD over the full sequence. x [b, s_len, d]."""
    s, d_in, nh, conv_dim = _dims(cfg)
    b, slen, _ = x.shape
    g, n, hd = s.n_groups, s.d_state, s.head_dim
    hpg = nh // g

    z, xBC, dt = _split_proj(p, cfg, x)
    xBC = _causal_conv_full(p, xBC)
    xs = xBC[..., :d_in].reshape(b, slen, nh, hd)
    B = xBC[..., d_in:d_in + g * n].reshape(b, slen, g, n)
    C = xBC[..., d_in + g * n:].reshape(b, slen, g, n)

    a = -jnp.exp(p["A_log"])                                    # [nh]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [b,s,nh]
    log_decay = dt * a                                           # [b,s,nh]

    Q = min(s.chunk, slen)
    assert slen % Q == 0, (slen, Q)
    nc = slen // Q

    def rs(t, extra):  # [b, s, ...] -> [b, nc, Q, ...]
        return t.reshape((b, nc, Q) + extra)

    xs_c = rs(xs, (nh, hd))
    B_c = rs(B, (g, n))
    C_c = rs(C, (g, n))
    dt_c = rs(dt, (nh,))
    ld_c = rs(log_decay, (nh,)).transpose(0, 1, 3, 2)            # [b,nc,nh,Q]

    # within-chunk ("diagonal") term: masked quadratic attention-like matmul
    L = jnp.exp(_segsum(ld_c))                                   # [b,nc,nh,Q,Q]
    # scores[b,c,h,i,j] = (C_i · B_j) L[h,i,j] dt_j
    CB = jnp.einsum("bcign,bcjgn->bcgij", C_c, B_c,
                    preferred_element_type=jnp.float32)          # [b,nc,g,Q,Q]
    CB = jnp.repeat(CB, hpg, axis=2)                             # [b,nc,nh,Q,Q]
    W = CB * L * dt_c.transpose(0, 1, 3, 2)[..., None, :]        # dt_j
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", W.astype(xs_c.dtype), xs_c)

    # per-chunk summary state: S_c = Σ_j exp(Σ_{k>j} ld) dt_j B_j ⊗ x_j
    cum = jnp.cumsum(ld_c, axis=-1)
    tail = jnp.exp(cum[..., -1:] - cum)                          # [b,nc,nh,Q]
    wj = (tail * dt_c.transpose(0, 1, 3, 2)).astype(xs_c.dtype)  # [b,nc,nh,Q]
    Bh = jnp.repeat(B_c, hpg, axis=3)                            # [b,nc,Q,nh,n]
    S = jnp.einsum("bchj,bcjhn,bcjhp->bchpn", wj, Bh, xs_c)      # [b,nc,nh,hd,n]

    # cross-chunk recurrence (tiny scan over nc)
    chunk_decay = jnp.exp(cum[..., -1])                          # [b,nc,nh]

    def step(h, inputs):
        dec, Sc = inputs
        h_new = h * dec[..., None, None] + Sc
        return h_new, h                                          # emit state BEFORE chunk

    h0 = jnp.zeros((b, nh, hd, n), jnp.float32)
    _, h_prev = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4).astype(jnp.float32)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                     # [b,nc,nh,hd,n]

    # off-chunk contribution: y_off[i] = exp(cum[i]) C_i · h_prev
    Ch = jnp.repeat(C_c, hpg, axis=3)                            # [b,nc,Q,nh,n]
    y_off = jnp.einsum("bcihn,bchpn->bcihp", Ch.astype(jnp.float32),
                       h_prev) * jnp.exp(cum).transpose(0, 1, 3, 2)[..., None]

    y = (y_diag.astype(jnp.float32) + y_off).reshape(b, slen, nh, hd)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, slen, d_in).astype(x.dtype)

    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rms")
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])

    cache = None
    if make_cache:
        # final recurrent state + conv tail for decode continuation
        h_last, _ = jax.lax.scan(
            step, h0, (chunk_decay.transpose(1, 0, 2),
                       S.transpose(1, 0, 2, 3, 4).astype(jnp.float32)))
        _, xBC_raw, _ = _split_proj(p, cfg, x)
        k = p["conv_w"].shape[0]
        tail_in = xBC_raw[:, -(k - 1):, :]
        cache = {"ssm": h_last, "conv": tail_in,
                 "idx": jnp.asarray(slen, jnp.int32)}
    return out, cache


def init_ssm_cache(cfg, b: int, dtype) -> Dict[str, jax.Array]:
    s, d_in, nh, conv_dim = _dims(cfg)
    return {
        "ssm": jnp.zeros((b, nh, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((b, s.d_conv - 1, conv_dim), dtype),
        "idx": jnp.zeros((), jnp.int32),
    }


def ssm_decode(p: Dict, cfg, x: jax.Array,
               cache: Dict) -> Tuple[jax.Array, Dict]:
    """O(1) recurrent step. x [b, 1, d]."""
    s, d_in, nh, conv_dim = _dims(cfg)
    b = x.shape[0]
    g, n, hd = s.n_groups, s.d_state, s.head_dim
    hpg = nh // g

    z, xBC, dt = _split_proj(p, cfg, x)                 # [b,1,·]
    # conv over (cached k-1 inputs ++ current)
    window = jnp.concatenate([cache["conv"], xBC], axis=1)   # [b,k,conv_dim]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC_t = jax.nn.silu(conv_out)                            # [b,conv_dim]

    xs = xBC_t[:, :d_in].reshape(b, nh, hd)
    B = xBC_t[:, d_in:d_in + g * n].reshape(b, g, n)
    C = xBC_t[:, d_in + g * n:].reshape(b, g, n)
    Bh = jnp.repeat(B, hpg, axis=1)                          # [b,nh,n]
    Ch = jnp.repeat(C, hpg, axis=1)

    a = -jnp.exp(p["A_log"])
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [b,nh]
    decay = jnp.exp(dt_t * a)                                # [b,nh]

    h = cache["ssm"] * decay[..., None, None] + \
        (dt_t[..., None, None] * Bh[:, :, None, :].astype(jnp.float32)
         * xs[..., None].astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, 1, d_in).astype(x.dtype)

    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rms")
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    new_cache = {"ssm": h,
                 "conv": window[:, 1:, :],
                 "idx": cache["idx"] + 1}
    return out, new_cache
