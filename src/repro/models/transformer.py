"""Composable decoder stack over ``LayerSpec`` layouts.

One implementation serves all 10 assigned architectures:

* blocks: pre-norm attention/MLA/SSD + dense-or-MoE MLP (+ gemma2-style
  post-norms), assembled per the config's layer layout;
* the stack is executed as ``lax.scan`` over *stacked* layer parameters,
  grouped by ``layout_groups`` (smallest repeating super-block) so the HLO
  contains each distinct block body once — bounded compile time at 512
  devices and bounded HLO for the roofline parser;
* ``jax.checkpoint`` (remat) around each super-block in training;
* three entry points: ``train_loss`` (full seq), ``prefill`` (full seq →
  caches), ``decode_step`` (one token against caches).

Modality frontends are STUBS per the assignment: ``input_mode`` selects
token embedding, raw embeddings (musicgen frames), or token+prefix
embeddings (phi-3-vision patches).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import LayerSpec, ModelConfig, layout_groups
from .hints import hint
from .layers import (apply_mlp, apply_norm, cross_entropy, embed_tokens,
                     init_embedding, init_mlp, init_norm, lm_logits,
                     sinusoidal_positions)

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, spec: LayerSpec, key, dtype
                ) -> Tuple[Dict, Dict]:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["norm1"], s["norm1"] = init_norm(cfg, cfg.d_model)
    if spec.kind == "attn":
        p["mix"], s["mix"] = attn_mod.init_attention(cfg, ks[0], dtype)
    elif spec.kind == "mla":
        p["mix"], s["mix"] = mla_mod.init_mla(cfg, ks[0], dtype)
    elif spec.kind == "ssm":
        p["mix"], s["mix"] = ssm_mod.init_ssm(cfg, ks[0], dtype)
    else:
        raise ValueError(spec.kind)
    if spec.mlp == "dense":
        p["norm2"], s["norm2"] = init_norm(cfg, cfg.d_model)
        p["mlp"], s["mlp"] = init_mlp(cfg, ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif spec.mlp == "moe":
        p["norm2"], s["norm2"] = init_norm(cfg, cfg.d_model)
        p["mlp"], s["mlp"] = moe_mod.init_moe(cfg, ks[1], dtype)
    elif spec.mlp != "none":   # "none": pure mixer block (mamba2)
        raise ValueError(spec.mlp)
    if cfg.post_norms:
        p["post_attn"], s["post_attn"] = init_norm(cfg, cfg.d_model)
        p["post_mlp"], s["post_mlp"] = init_norm(cfg, cfg.d_model)
    return p, s


def init_model(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, logical_pspecs); layer params are stacked per group
    with a leading `layers` axis."""
    dtype = jnp.dtype(cfg.dtype)
    groups = layout_groups(cfg.default_layout())
    k_emb, k_rest = jax.random.split(key)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = init_embedding(cfg, k_emb, dtype)
    params["final_norm"], specs["final_norm"] = init_norm(cfg, cfg.d_model)

    params["groups"] = []
    specs["groups"] = []
    gkeys = jax.random.split(k_rest, len(groups))
    for (block, repeats), gk in zip(groups, gkeys):
        lkeys = jax.random.split(gk, repeats)

        def init_block(k, block=block):
            parts = []
            for li, spec in enumerate(block):
                pk = jax.random.fold_in(k, li)
                p, _ = _init_layer(cfg, spec, pk, dtype)
                parts.append(p)
            return parts

        stacked = jax.vmap(init_block)(lkeys)
        # spec tree (same for every repeat): prepend scan ("layers") axis
        sub_specs = []
        for li, spec in enumerate(block):
            _, s = _init_layer(cfg, spec, jax.random.PRNGKey(0), dtype)
            sub_specs.append(jax.tree_util.tree_map(
                lambda ax: ("layers",) + tuple(ax), s,
                is_leaf=lambda t: isinstance(t, tuple)))
        params["groups"].append(stacked)
        specs["groups"].append(sub_specs)
    return params, specs


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, spec: LayerSpec, p: Dict, x: jax.Array,
                 positions: jax.Array, mode: str,
                 cache: Optional[Dict], cache_capacity: Optional[int]
                 ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """One decoder block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    x = hint(x, ("batch", None, None))
    h = apply_norm(p["norm1"], x, cfg.norm)
    new_cache = None
    if spec.kind == "attn":
        if mode == "decode":
            y, new_cache = attn_mod.attend_decode(p["mix"], cfg, spec, h,
                                                  positions, cache)
        else:
            y, new_cache = attn_mod.attend_full(
                p["mix"], cfg, spec, h, positions,
                make_cache=cache_capacity if mode == "prefill" else None)
    elif spec.kind == "mla":
        if mode == "decode":
            y, new_cache = mla_mod.mla_decode(p["mix"], cfg, spec, h,
                                              positions, cache)
        else:
            y, new_cache = mla_mod.mla_full(
                p["mix"], cfg, spec, h, positions,
                make_cache=cache_capacity if mode == "prefill" else None)
    else:  # ssm
        if mode == "decode":
            y, new_cache = ssm_mod.ssm_decode(p["mix"], cfg, h, cache)
        else:
            y, new_cache = ssm_mod.ssm_full(p["mix"], cfg, h,
                                            make_cache=(mode == "prefill"))
    if cfg.post_norms:
        y = apply_norm(p["post_attn"], y, cfg.norm)
    x = x + y

    if spec.mlp == "none":
        return x, new_cache, aux
    h = apply_norm(p["norm2"], x, cfg.norm)
    if spec.mlp == "dense":
        y = apply_mlp(p["mlp"], h, cfg.act)
    else:
        y, aux = moe_mod.apply_moe(p["mlp"], cfg, h)
    if cfg.post_norms:
        y = apply_norm(p["post_mlp"], y, cfg.norm)
    return x + y, new_cache, aux


def _cache_capacity(cfg: ModelConfig, spec: LayerSpec, max_len: int) -> int:
    if spec.kind == "ssm":
        return 0  # SSM caches are fixed-shape; capacity unused
    if spec.window is not None:
        return min(spec.window, max_len)
    return max_len


# ---------------------------------------------------------------------------
# Stack runner (scan over stacked layer groups)
# ---------------------------------------------------------------------------

def _run_stack(cfg: ModelConfig, params: Dict, x: jax.Array,
               positions: jax.Array, mode: str,
               caches: Optional[List] = None,
               max_len: Optional[int] = None, remat: bool = True
               ) -> Tuple[jax.Array, Optional[List], jax.Array]:
    groups = layout_groups(cfg.default_layout())
    new_caches: List[Any] = []
    aux_total = jnp.zeros((), jnp.float32)

    for gi, (block, repeats) in enumerate(groups):
        stacked = params["groups"][gi]

        def body(x, layer_inputs, block=block):
            layer_params, layer_cache = layer_inputs
            aux_l = jnp.zeros((), jnp.float32)
            outs = []
            for li, spec in enumerate(block):
                c = layer_cache[li] if layer_cache is not None else None
                cap = _cache_capacity(cfg, spec, max_len) if max_len else None
                x, nc, aux = _apply_block(cfg, spec, layer_params[li], x,
                                          positions, mode, c, cap)
                outs.append(nc)
                aux_l = aux_l + aux
            if any(o is not None for o in outs):
                return x, (outs, aux_l)
            return x, (None, aux_l)

        body_fn = jax.checkpoint(body) if (remat and mode == "train") else body
        cache_in = caches[gi] if caches is not None else None
        x, (cache_out, aux_stack) = jax.lax.scan(
            body_fn, x, (stacked, cache_in))
        aux_total = aux_total + jnp.sum(aux_stack)
        new_caches.append(cache_out)

    return x, (new_caches if mode in ("prefill", "decode") else None), aux_total


# ---------------------------------------------------------------------------
# Inputs → hidden states
# ---------------------------------------------------------------------------

def _inputs_to_hidden(cfg: ModelConfig, params: Dict, batch: Dict
                      ) -> Tuple[jax.Array, jax.Array]:
    if cfg.input_mode == "embeds":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        b, s = x.shape[0], x.shape[1]
        positions = batch.get("positions",
                              jnp.broadcast_to(jnp.arange(s)[None, :], (b, s)))
    elif cfg.input_mode == "tokens+prefix" and "prefix_embeds" in batch:
        prefix = batch["prefix_embeds"].astype(jnp.dtype(cfg.dtype))
        tok = embed_tokens(params["embed"], cfg, batch["tokens"])
        x = jnp.concatenate([prefix, tok], axis=1)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    else:
        tok = batch["tokens"]
        x = embed_tokens(params["embed"], cfg, tok)
        b, s = x.shape[0], x.shape[1]
        positions = batch.get("positions",
                              jnp.broadcast_to(jnp.arange(s)[None, :], (b, s)))
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model, x.dtype)
    x = hint(x, ("batch", None, None))
    return x, positions


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Dict, batch: Dict,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence logits (training). Returns (logits, aux_loss)."""
    x, positions = _inputs_to_hidden(cfg, params, batch)
    x, _, aux = _run_stack(cfg, params, x, positions, "train", remat=remat)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], cfg, x), aux


def train_loss(cfg: ModelConfig, params: Dict, batch: Dict,
               remat: bool = True) -> jax.Array:
    logits, aux = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    if cfg.input_mode == "tokens+prefix":
        logits = logits[:, cfg.prefix_len:, :]  # loss on text positions only
    loss = cross_entropy(logits, labels, batch.get("loss_mask"))
    return loss + AUX_LOSS_WEIGHT * aux


def prefill(cfg: ModelConfig, params: Dict, batch: Dict, max_len: int
            ) -> Tuple[jax.Array, List]:
    """Run the prompt; returns (last-position logits, caches)."""
    x, positions = _inputs_to_hidden(cfg, params, batch)
    x, caches, _ = _run_stack(cfg, params, x, positions, "prefill",
                              max_len=max_len, remat=False)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], cfg, x[:, -1:, :])
    return logits, caches


def decode_step(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                pos: jax.Array, caches: List
                ) -> Tuple[jax.Array, List]:
    """One decode step: tokens [b,1] (or embeds [b,1,d]), pos [b,1]."""
    if cfg.input_mode == "embeds":
        batch = {"embeds": tokens, "positions": pos}
    else:
        batch = {"tokens": tokens, "positions": pos}
    x, positions = _inputs_to_hidden(cfg, params, batch)
    x, caches, _ = _run_stack(cfg, params, x, positions, "decode",
                              caches=caches,
                              max_len=int(caches_max_len(caches)))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return lm_logits(params["embed"], cfg, x), caches


def caches_max_len(caches: List) -> int:
    best = 1
    for group in caches:
        if group is None:
            continue
        for c in group:
            if c is not None and "k" in c:
                best = max(best, c["k"].shape[2])   # [layers,b,C,kv,hd]
            elif c is not None and "ckv" in c:
                best = max(best, c["ckv"].shape[2])
    return best


def init_caches(cfg: ModelConfig, params: Dict, b: int, max_len: int,
                dtype=None) -> List:
    """Fresh (empty) caches shaped like prefill's output — for pure-decode
    dry-runs (decode_32k / long_500k lower serve_step only)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    groups = layout_groups(cfg.default_layout())
    caches = []
    for block, repeats in groups:
        sub = []
        for spec in block:
            cap = _cache_capacity(cfg, spec, max_len)
            if spec.kind == "attn":
                c = attn_mod.init_kv_cache(b, cap, cfg.n_kv_heads,
                                           cfg.resolved_head_dim(), dtype)
            elif spec.kind == "mla":
                c = mla_mod.init_mla_cache(b, cap, cfg.mla, dtype)
            else:
                c = ssm_mod.init_ssm_cache(cfg, b, dtype)
            sub.append(jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (repeats,) + x.shape), c))
        caches.append(sub)
    return caches
