"""Real network transport: the δ-wire subsystem over asyncio sockets.

Everything below :mod:`repro.core.sim` was built for real networks —
versioned, CRC-checksummed, self-describing frames; digest-sync as a
reconnect story; drop-tolerant δ-semantics — and this package finally
ships them between OS processes:

* ``transport`` — one ``Transport`` interface, two channels: UDP
  (fire-and-forget, MTU-aware batching/splitting with drop-whole-frame
  reassembly, seeded loss/dup/reorder injection) and TCP
  (length-from-the-frame-header streaming through ``FrameStream``,
  hello-identified connections, capped-backoff reconnect).
* ``node`` — ``GossipNode``: drives a ``core.propagation.Replica`` from
  an event loop (the replica sees the node as its ``sim``), with
  periodic anti-entropy ticks, inbound frame dispatch, and bounded
  drop-oldest per-peer send queues.
* ``stats`` — ``LinkStats``: ``sim.NetStats`` plus the counters only a
  real link has, so socket byte reports line up column-for-column with
  simulator byte reports.

The simulator stays the deterministic fault harness; the contract
between the two worlds is that one write schedule replayed through both
converges to identical stores (asserted in ``tests/test_net.py``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .node import (DEFAULT_POLICY, GossipNode, cluster_converged,
                   default_replica_factory, start_cluster, start_gossip,
                   stop_cluster, wait_converged)
from .stats import LinkStats
from .transport import (TcpTransport, Transport, UdpTransport, format_addr,
                        make_transport, parse_addr)

TRANSPORTS = ("udp", "tcp")


@dataclass
class NetSpec:
    """Validated socket-cluster shape behind ``serve.py --listen/--peers``.

    ``node_id``/``peer_ids`` are the *logical* replica ids (the simulator
    id space); addresses are where the sockets live. The CLI accepts
    ``id@host:port`` to name a member, bare ``host:port`` to let the
    address be the name, and ``id@host:port@zone`` to additionally place
    the member in a failure domain (``zone`` or ``region/zone``) —
    ``zones`` then maps every member id to its zone and :attr:`topology`
    carries the cluster's :class:`~repro.topology.Topology`.

    ``session_ttl`` is the key-lifecycle TTL to run over the socket
    cluster (None = lifecycle off).
    """

    node_id: str
    listen: str
    transport: str = "udp"
    peers: Dict[str, str] = field(default_factory=dict)   # id → host:port
    zones: Dict[str, str] = field(default_factory=dict)   # id → zone
    session_ttl: Optional[float] = None

    @property
    def cluster_ids(self) -> List[str]:
        return sorted([self.node_id, *self.peers])

    @property
    def topology(self):
        """The cluster :class:`~repro.topology.Topology`, or None when no
        member carries a zone annotation (flat mesh)."""
        if not self.zones:
            return None
        from ..topology import Topology
        return Topology(self.zones)


def _split_member(spec: str) -> tuple:
    """``[id@]host:port[@zone]`` → ``(id, "host:port", zone|None)``
    (id defaults to the canonical address; a zone requires the id)."""
    parts = spec.split("@")
    if len(parts) == 1:
        name, addr, zone = None, parts[0], None
    elif len(parts) == 2:
        (name, addr), zone = parts, None
    elif len(parts) == 3:
        name, addr, zone = parts
    else:
        raise ValueError(f"member {spec!r} is not [ID@]HOST:PORT[@ZONE]")
    if zone is not None and (not name or not zone):
        raise ValueError(f"member {spec!r}: a zone annotation needs the "
                         "full ID@HOST:PORT@ZONE form")
    host, port = parse_addr(addr)            # raises ValueError on junk
    canonical = format_addr((host, port))
    return (name if name else canonical), canonical, zone


def validate_net_args(listen: Optional[str], peers: Optional[str], *,
                      transport: str = "udp", wire: bool = True,
                      udp_loss: float = 0.0,
                      session_ttl: Optional[float] = None) -> NetSpec:
    """Check a socket-mode CLI combination and shape it into a
    :class:`NetSpec` — every rejection here is a one-line error at arg
    parse time instead of a deep failure after sockets are up.
    """
    if bool(listen) != bool(peers):
        raise ValueError("socket mode needs BOTH --listen and --peers "
                         "(a gossip cluster has at least two members)")
    assert listen is not None and peers is not None
    if not wire:
        raise ValueError(
            "--no-wire is incompatible with --listen/--peers: socket "
            "gossip ships binary δ-wire frames — objects cannot cross "
            "a process boundary")
    if transport not in TRANSPORTS:
        raise ValueError(f"unknown --transport {transport!r}; "
                         f"have {', '.join(TRANSPORTS)}")
    if udp_loss and transport != "udp":
        raise ValueError("--udp-loss injects datagram loss and is "
                         "UDP-only (TCP retransmits under the socket)")
    if not 0.0 <= udp_loss < 1.0:
        raise ValueError(f"--udp-loss must be in [0, 1), got {udp_loss}")
    if session_ttl is not None and session_ttl <= 0:
        raise ValueError(f"--session-ttl must be positive seconds, "
                         f"got {session_ttl}")
    node_id, listen_addr, self_zone = _split_member(listen)
    zones: Dict[str, str] = {}
    if self_zone:
        zones[node_id] = self_zone
    peer_map: Dict[str, str] = {}
    for part in peers.split(","):
        part = part.strip()
        if not part:
            continue
        pid, addr, zone = _split_member(part)
        if addr == listen_addr or pid == node_id:
            raise ValueError(f"--peers entry {part!r} is this node's own "
                             "--listen address/id (no self-gossip)")
        if pid in peer_map:
            raise ValueError(f"duplicate peer {pid!r} in --peers")
        peer_map[pid] = addr
        if zone:
            zones[pid] = zone
    if not peer_map:
        raise ValueError("--peers names no cluster members")
    for pid, addr in peer_map.items():
        if addr.endswith(":0"):
            raise ValueError(f"peer {pid!r} has port 0 — peers need "
                             "concrete ports (only --listen may use 0)")
    if zones and len(zones) != len(peer_map) + 1:
        missing = sorted(({node_id, *peer_map} - zones.keys()))
        raise ValueError(
            f"zone annotations must cover every member or none — "
            f"missing for {', '.join(missing)} (use ID@HOST:PORT@ZONE)")
    return NetSpec(node_id=node_id, listen=listen_addr,
                   transport=transport, peers=peer_map, zones=zones,
                   session_ttl=session_ttl or None)


__all__ = [
    "DEFAULT_POLICY", "GossipNode", "LinkStats", "NetSpec",
    "TcpTransport", "TRANSPORTS", "Transport", "UdpTransport",
    "cluster_converged", "default_replica_factory", "format_addr",
    "make_transport", "parse_addr", "start_cluster", "start_gossip",
    "stop_cluster", "validate_net_args", "wait_converged",
]
