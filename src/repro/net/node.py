"""Asyncio gossip node: a real-network harness for the `Replica` engine.

:class:`GossipNode` is to a socket what :class:`~repro.core.sim.Simulator`
is to the discrete-event queue — the engine cannot tell them apart. It
presents the two-attribute surface a :class:`~repro.core.propagation.Replica`
needs from its ``sim`` (``send(src, dst, msg)`` and ``time``), attaches
the replica to itself, and drives it from an event loop:

* **periodic anti-entropy ticks** — jittered ``on_periodic`` every
  ``tick`` seconds, ``gc_deltas`` every ``gc_every`` ticks, exactly the
  cadence ``run_to_convergence`` schedules in the simulator;
* **inbound dispatch** — transport frames resolve their sender's logical
  id and feed ``replica.on_receive``; the engine's wire codec does the
  decoding, so a socket delivery and a simulator delivery are the same
  bytes hitting the same method;
* **per-peer bounded send queues** — the engine's sends enqueue
  per-destination; a sender task per peer drains batches into the
  transport. When a slow link's queue overruns, the **oldest frames are
  dropped** (counted in ``stats.queue_drops``): δ-groups re-ship until
  acked in causal mode and digest-sync re-pulls anything else, so
  shedding is an admission policy, not data loss.

Replica ids stay *logical* (``gw0``…), with a separate ``peers`` map of
id → ``host:port``. That split is what makes the object-mode ≡
socket-mode equivalence contract checkable: the same write schedule
replayed through a ``Simulator`` and through a loopback socket cluster
mints identical dots and must converge to identical stores
(``tests/test_net.py::test_sim_socket_equivalence``).

Frames only: a ``GossipNode`` refuses a replica without a wire codec —
sockets move bytes, and the byte accounting (:class:`LinkStats`, the
same counters as ``sim.NetStats``) is measured frame lengths.
"""

from __future__ import annotations

import asyncio
import random
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

from ..core.propagation import (Replica, ShippingPolicy, StoreReplica,
                                make_policy, stable_seed)
from ..topology import Topology
from ..wire import WireCodec
from .stats import LinkStats
from .transport import Transport, make_transport

DEFAULT_POLICY = "bp+rr+digest-sync:4"


class _PeerQueue:
    """Bounded drop-oldest frame queue with an async drain."""

    def __init__(self, cap: int):
        self.cap = cap
        self.frames: deque = deque()
        self._ready = asyncio.Event()

    def put(self, frame) -> int:
        """Enqueue; returns how many old frames were shed to make room."""
        drops = 0
        while len(self.frames) >= self.cap:
            self.frames.popleft()
            drops += 1
        self.frames.append(frame)
        self._ready.set()
        return drops

    async def get_batch(self) -> List[Any]:
        while not self.frames:
            self._ready.clear()
            await self._ready.wait()
        batch = list(self.frames)
        self.frames.clear()
        return batch


def default_replica_factory(policy=DEFAULT_POLICY,
                            **replica_kwargs) -> Callable[..., Replica]:
    """A factory building the standard socket-mode replica: causal keyed
    :class:`StoreReplica` gossiping binary frames under ``policy`` — a
    spec string, a ready :class:`ShippingPolicy` (hook state lives on the
    replica, so one instance serves a whole in-process cluster), or a
    zero-arg callable returning one per replica."""
    def make(node_id: str, neighbors: Sequence[str]) -> Replica:
        if isinstance(policy, str):
            pol = make_policy(policy)
        elif isinstance(policy, ShippingPolicy):
            pol = policy
        else:
            pol = policy()
        kw = dict(causal=True, policy=pol,
                  rng=random.Random(stable_seed(node_id)),
                  wire=WireCodec())
        kw.update(replica_kwargs)
        return StoreReplica(node_id, list(neighbors), **kw)
    return make


class GossipNode:
    """One cluster member: a replica, a transport, and the loop glue.

    Two-phase startup so ephemeral ports compose: ``await bind()``
    resolves the listen address (port 0 → the OS assigns one, read back
    from ``.addr``); ``set_peers({id: addr})`` names the rest of the
    cluster; ``await start()`` builds the replica and launches the tick
    and sender tasks. ``start()`` runs ``bind`` itself when the caller
    already knew its port.
    """

    def __init__(self, node_id: str, listen: str, *,
                 transport: str = "udp",
                 peers: Optional[Dict[str, str]] = None,
                 replica_factory: Optional[Callable] = None,
                 policy=DEFAULT_POLICY,
                 topology: Optional[Topology] = None,
                 tick: float = 0.1, gc_every: int = 7,
                 queue_cap: int = 256, mtu: int = 1400,
                 loss: float = 0.0, dup: float = 0.0, reorder: float = 0.0,
                 seed: int = 0,
                 tracer: Optional[Any] = None):
        self.id = node_id
        # structured trace bus: installed on the replica at build time
        # (ensure_replica) and fed queue_drop events from the send path
        self.tracer = tracer
        self.listen = listen
        # zone annotations: classify every sent/received frame's link
        # (intra / inter / wan) in the byte accounting — the socket-side
        # measurement ``bench_topology`` compares against the simulator
        self.topology = topology
        self.zone: Optional[str] = (topology.zone(node_id)
                                    if topology is not None else None)
        self.stats = LinkStats()
        self.transport: Transport = make_transport(
            transport, node_id, mtu=mtu, loss=loss, dup=dup,
            reorder=reorder, seed=seed, stats=self.stats)
        self.transport.set_receiver(self._on_frame)
        self.peers: Dict[str, str] = dict(peers or {})
        self._addr_to_id: Dict[str, str] = {}
        self._factory = (replica_factory if replica_factory is not None
                         else default_replica_factory(policy))
        self.replica: Optional[Replica] = None
        self.tick = tick
        self.gc_every = gc_every
        self.queue_cap = queue_cap
        self._queues: Dict[str, _PeerQueue] = {}
        self._tasks: List[asyncio.Task] = []
        self._rng = random.Random(seed ^ stable_seed(node_id))
        self.addr: Optional[str] = None
        self.errors: List[BaseException] = []
        self._running = False
        # observability surface (export_metrics / serve_metrics)
        self.metrics_registry: Optional[Any] = None
        self.metrics_addr: Optional[str] = None
        self.lag_probe: Optional[Any] = None
        self._metrics_server: Optional[Any] = None

    # -- what the replica sees as its "sim" -------------------------------------
    @property
    def time(self) -> float:
        return time.monotonic()

    def send(self, src: str, dst: str, msg: Any) -> None:
        """The engine's transmit path (``Node.send`` → ``sim.send``)."""
        if not isinstance(msg, (bytes, bytearray)):
            raise TypeError(
                "socket gossip ships binary δ-wire frames; attach a "
                "WireCodec to the replica (wire=WireCodec())")
        kind = getattr(msg, "kind", "frame")
        link_cls, cost = None, 1.0
        if self.topology is not None:
            link_cls = self.topology.link_class(self.id, dst)
            cost = self.topology.byte_cost(self.id, dst)
        self.stats.record(str(kind), len(msg),
                          link_class=link_cls, byte_cost=cost)
        q = self._queues.get(dst)
        if q is None:
            self.stats.dropped += 1          # unknown/departed peer
            return
        drops = q.put(msg)
        if drops:
            self.stats.queue_drops += drops
            self.stats.dropped += drops
            if self.tracer is not None:
                self.tracer.emit("queue_drop", dst=dst, dropped=drops)

    # -- lifecycle -------------------------------------------------------------
    async def bind(self) -> str:
        if self.addr is None:
            self.addr = await self.transport.start(self.listen)
        return self.addr

    def set_peers(self, peers: Dict[str, str]) -> None:
        self.peers = dict(peers)

    def ensure_replica(self) -> Replica:
        """Build the replica from the factory once peers are known —
        callable before ``start()`` so writes can precede gossip."""
        assert self.peers, "a gossip node needs at least one peer"
        if self.replica is None:
            self.replica = self._factory(self.id, sorted(self.peers))
            if self.tracer is not None:
                self.replica.tracer = self.tracer
        return self.replica

    async def start(self) -> None:
        await self.bind()
        assert self.peers, "a gossip node needs at least one peer"
        self._addr_to_id = {addr: pid for pid, addr in self.peers.items()}
        self.ensure_replica()
        if self.replica.wire is None:
            raise ValueError("socket gossip requires replica.wire — "
                             "frames are what cross the network")
        self.replica.attach(self)            # replica.sim = this node
        self._running = True
        for pid, addr in self.peers.items():
            q = self._queues[pid] = _PeerQueue(self.queue_cap)
            self._tasks.append(asyncio.ensure_future(
                self._sender(pid, addr, q)))
        self._tasks.append(asyncio.ensure_future(self._ticker()))

    def adopt_replica(self, replica: Replica) -> None:
        """Install a pre-built replica (e.g. one recovered from a durable
        snapshot for a restart test) instead of the factory's fresh one."""
        self.replica = replica

    async def _sender(self, pid: str, addr: str, q: _PeerQueue) -> None:
        try:
            while self._running:
                frames = await q.get_batch()
                await self.transport.send_frames(addr, frames)
        except asyncio.CancelledError:
            pass
        except Exception as e:               # pragma: no cover - surfaced
            self.errors.append(e)

    async def _ticker(self) -> None:
        ticks = 0
        try:
            while self._running:
                await asyncio.sleep(
                    self.tick * (1.0 + self._rng.uniform(-0.1, 0.1)))
                assert self.replica is not None
                self.replica.on_periodic()
                if self.lag_probe is not None:
                    self.lag_probe.poll()   # tick-resolution ack lag
                ticks += 1
                if ticks % self.gc_every == 0:
                    self.replica.gc_deltas()
        except asyncio.CancelledError:
            pass
        except Exception as e:
            self.errors.append(e)            # engine bug: stop ticking,

    # -- inbound ---------------------------------------------------------------
    def _on_frame(self, src_key: str, frame) -> None:
        """Transport delivery: ``src_key`` is a logical id (TCP hello) or
        a source address (UDP) mapped through the peer table."""
        src = self._addr_to_id.get(src_key, src_key)
        link_cls = (self.topology.link_class(src, self.id)
                    if self.topology is not None else None)
        self.stats.record_recv(getattr(frame, "kind", "frame"), len(frame),
                               link_class=link_cls)
        if self.replica is None:
            return
        try:
            self.replica.on_receive(src, frame)
        except Exception as e:
            self.errors.append(e)

    # -- convenience write API ---------------------------------------------------
    def update(self, key: str, typ, mutator_name: str, *args) -> Any:
        assert isinstance(self.replica, StoreReplica)
        out = self.replica.update(key, typ, mutator_name, *args)
        if self.lag_probe is not None:
            self.lag_probe.note_write()
        return out

    def operation(self, m_delta: Callable[[Any], Any]) -> Any:
        assert self.replica is not None
        out = self.replica.operation(m_delta)
        if self.lag_probe is not None:
            self.lag_probe.note_write()
        return out

    @property
    def X(self):
        assert self.replica is not None
        return self.replica.X

    async def stop(self, *, abort: bool = False) -> None:
        self._running = False
        if self._metrics_server is not None:
            await self._metrics_server.stop()
            self._metrics_server = None
        for t in self._tasks:
            t.cancel()
        for t in self._tasks:
            try:
                await t
            except (asyncio.CancelledError, Exception):
                pass
        self._tasks.clear()
        if abort and hasattr(self.transport, "abort_connections"):
            self.transport.abort_connections()
        await self.transport.close()

    def check_healthy(self) -> None:
        """Raise the first error a background task swallowed, if any."""
        if self.errors:
            raise self.errors[0]

    # -- observability -----------------------------------------------------------
    def export_metrics(self, registry: Optional[Any] = None) -> Any:
        """Wire this node into a metrics registry (default: a fresh one):
        the transport's :class:`LinkStats` (with scrape-window byte-rate
        gauges), per-peer replica health probes, write→fully-acked lag,
        and the process-wide kernel counters. Returns the registry —
        everything is collect-time, so the gossip hot path is untouched."""
        from ..obs import AckLagProbe, Registry, ReplicaProbes
        if registry is None:
            registry = Registry()
        registry.absorb_link_stats(self.stats, node=self.id,
                                   clock=lambda: self.time)
        registry.absorb_kernel_counters(node=self.id)
        replica = self.ensure_replica()
        ReplicaProbes(registry, replica, node=self.id)
        self.lag_probe = AckLagProbe(registry, replica, node=self.id,
                                     clock=lambda: self.time)
        return registry

    async def serve_metrics(self, registry: Optional[Any] = None, *,
                            host: str = "127.0.0.1", port: int = 0) -> str:
        """Start the sidecar scrape endpoint (Prometheus text at
        ``/metrics``, JSON at ``/metrics.json``) on this node's event
        loop; returns (and remembers, as ``metrics_addr``) its address.
        Stopped with the node."""
        from ..obs import MetricsServer
        if registry is None:
            registry = self.export_metrics()
        self.metrics_registry = registry
        self._metrics_server = MetricsServer(registry, host=host, port=port)
        self.metrics_addr = await self._metrics_server.start()
        return self.metrics_addr


# ---------------------------------------------------------------------------
# Cluster helpers (tests + benchmarks)
# ---------------------------------------------------------------------------

async def start_cluster(n: int, *, transport: str = "udp",
                        policy=DEFAULT_POLICY,
                        replica_factory: Optional[Callable] = None,
                        topology: Optional[Topology] = None,
                        tick: float = 0.05, queue_cap: int = 256,
                        mtu: int = 1400, loss: float = 0.0,
                        dup: float = 0.0, reorder: float = 0.0,
                        seed: int = 0, host: str = "127.0.0.1",
                        start_gossip: bool = True,
                        tracer_factory: Optional[Callable[[str], Any]]
                        = None) -> List[GossipNode]:
    """N in-process nodes on ephemeral loopback ports, fully meshed.

    Binds everyone first (so the OS assigns ports), then wires the peer
    tables, then — unless ``start_gossip=False``, for callers that want
    to apply writes before the first tick — starts the gossip tasks.
    ``topology`` annotates the members with zones: frame bytes are
    classed intra/inter/wan per link (pair with a zone-aware policy via
    ``policy``/``replica_factory`` for hierarchical gossip).
    ``tracer_factory`` (node id → :class:`~repro.obs.Tracer`) attaches a
    trace bus per member.
    """
    nodes = [GossipNode(f"gw{k}", f"{host}:0", transport=transport,
                        policy=policy, replica_factory=replica_factory,
                        topology=topology,
                        tick=tick, queue_cap=queue_cap, mtu=mtu,
                        loss=loss, dup=dup, reorder=reorder,
                        seed=seed + k,
                        tracer=(tracer_factory(f"gw{k}")
                                if tracer_factory is not None else None))
             for k in range(n)]
    for node in nodes:
        await node.bind()
    addrs = {node.id: node.addr for node in nodes}
    for node in nodes:
        node.set_peers({pid: a for pid, a in addrs.items()
                        if pid != node.id})
        node.ensure_replica()    # writes may precede the first tick
    if start_gossip:
        for node in nodes:
            await node.start()
    return nodes


async def start_gossip(nodes: Sequence[GossipNode]) -> None:
    for node in nodes:
        await node.start()


def cluster_converged(nodes: Sequence[GossipNode]) -> bool:
    states = [n.X for n in nodes]
    return all(s == states[0] for s in states[1:])


async def wait_converged(nodes: Sequence[GossipNode], *,
                         timeout: float = 30.0, poll: float = 0.1,
                         settle: Optional[Callable[[], bool]] = None
                         ) -> float:
    """Poll until every node's state agrees (or ``settle()`` says done);
    returns the seconds it took. Raises on timeout or a node error."""
    t0 = time.monotonic()
    done = settle if settle is not None else (
        lambda: cluster_converged(nodes))
    while True:
        for node in nodes:
            node.check_healthy()
        if done():
            return time.monotonic() - t0
        if time.monotonic() - t0 > timeout:
            raise AssertionError(
                f"no convergence within {timeout}s; stats="
                + "; ".join(f"{n.id}:{n.stats.summary()}" for n in nodes))
        await asyncio.sleep(poll)


async def stop_cluster(nodes: Sequence[GossipNode]) -> None:
    for node in nodes:
        await node.stop()
