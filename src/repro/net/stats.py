"""Byte accounting for the socket transports — the simulator's counters.

The network simulator and the real transports must report traffic in the
same shape, or the bench tables are apples-to-oranges: a sim row says
"digest-sync reconnect costs 1.5% of a full-state frame" and the socket
row must be directly comparable. So the socket layer does not grow its
own stats class — :class:`LinkStats` **is** :class:`repro.core.sim.NetStats`
(same ``record``/``by_kind``/``bytes_by_kind``/``payload_atoms``/
``pull_bytes``), extended with the counters only a real link has:
datagram/chunk counts, reassembly and queue-overrun drops, stream
resyncs, reconnects, and the receive-side mirror of the per-kind byte
columns (a simulator sees both ends of every link; a process sees only
its own, so catch-up cost is measured at the receiver).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from ..core.sim import NetStats


@dataclass
class LinkStats(NetStats):
    """Per-node transport counters; see module docstring.

    Inherited from ``NetStats`` (identical semantics): ``sent``,
    ``delivered``, ``dropped``, ``duplicated``, ``bytes_sent``,
    ``by_kind``, ``bytes_by_kind``, ``record(kind, size)``,
    ``payload_atoms()``, ``pull_bytes()``. ``dropped`` counts frames this
    node *chose* to drop (queue overrun admission) — loss on the wire is
    invisible to a sender and shows up only as the receiver not acking.
    """

    # receive-side mirror of the per-kind byte columns
    bytes_recv: int = 0
    recv_by_kind: Dict[str, int] = field(default_factory=dict)
    recv_bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    # receive-side mirror of the per-link-class split (populated only
    # when the node carries a Topology; send side inherits by_class /
    # bytes_by_class / link_cost from NetStats)
    recv_by_class: Dict[str, int] = field(default_factory=dict)
    recv_bytes_by_class: Dict[str, int] = field(default_factory=dict)
    # datagram channel
    datagrams_sent: int = 0
    datagrams_recv: int = 0
    chunks_sent: int = 0           # oversized-frame shards
    reassembly_drops: int = 0      # partial oversized frames evicted
    # stream channel
    resyncs: int = 0               # FrameStream skipped garbage/corruption
    reconnects: int = 0            # TCP dial retries that followed a drop
    # admission control
    queue_drops: int = 0           # frames dropped by bounded send queues

    def record_recv(self, kind: str, size: int,
                    link_class: Optional[str] = None) -> None:
        self.delivered += 1
        self.bytes_recv += size
        self.recv_by_kind[kind] = self.recv_by_kind.get(kind, 0) + 1
        self.recv_bytes_by_kind[kind] = (
            self.recv_bytes_by_kind.get(kind, 0) + size)
        if link_class is not None:
            self.recv_by_class[link_class] = (
                self.recv_by_class.get(link_class, 0) + 1)
            self.recv_bytes_by_class[link_class] = (
                self.recv_bytes_by_class.get(link_class, 0) + size)

    def recv_cross_zone_bytes(self) -> int:
        """Bytes received over links that left the sender's zone — the
        receive-side twin of :meth:`NetStats.cross_zone_bytes`."""
        return sum(v for cls, v in self.recv_bytes_by_class.items()
                   if cls != "intra")

    # the kinds that carry state toward the receiver (PAYLOAD_KINDS minus
    # digest *requests* — those are the poller's cost, scale with the
    # responder's store, and arrive in steady state whether or not this
    # node is behind, so they'd drown a catch-up measurement)
    STATE_KINDS = ("delta", "state", "handoff", "digest-resp",
                   "membership", "topk")

    def recv_payload_bytes(self) -> int:
        """Receive-side counterpart of :meth:`NetStats.payload_atoms` —
        everything a shipping policy pays for, seen from this end."""
        return sum(v for k, v in self.recv_bytes_by_kind.items()
                   if k in self.PAYLOAD_KINDS)

    def recv_state_bytes(self) -> int:
        """State-carrying bytes received — what a reconnecting node
        actually paid to catch up (see ``STATE_KINDS``)."""
        return sum(v for k, v in self.recv_bytes_by_kind.items()
                   if k in self.STATE_KINDS)

    def summary(self) -> Dict[str, int]:
        out = {
            "sent": self.sent, "bytes_sent": self.bytes_sent,
            "delivered": self.delivered, "bytes_recv": self.bytes_recv,
            "queue_drops": self.queue_drops,
            "reassembly_drops": self.reassembly_drops,
            "resyncs": self.resyncs, "reconnects": self.reconnects,
        }
        if self.bytes_by_class:          # zoned node: show the class split
            out["bytes_by_class"] = dict(self.bytes_by_class)
        if self.recv_bytes_by_class:
            out["recv_bytes_by_class"] = dict(self.recv_bytes_by_class)
        return out
