"""Socket transports: δ-wire frames over real UDP and TCP.

One :class:`Transport` interface, two channel disciplines:

* :class:`UdpTransport` — fire-and-forget datagrams. Small frames are
  *batched*: consecutive queued frames pack into one datagram up to the
  MTU budget (frames are self-delimiting, so the receiver just feeds the
  datagram through a :class:`~repro.wire.frames.FrameStream`). A frame
  larger than the MTU is *split* into shard datagrams carrying a
  ``(frame-id, index, count)`` header and reassembled at the receiver
  with **drop-whole-frame** semantics: lose any shard and the whole
  frame is discarded (δ-joins are idempotent and digest-sync is the
  repair path, so a dropped frame costs latency, never correctness).
  Loss / duplication / reordering injection hooks on the send path make
  the §2 fault model reproducible over loopback.

* :class:`TcpTransport` — connected streams. Frames need no extra
  length prefix (the frame header *is* one); each connection feeds a
  ``FrameStream``, so short reads, frames split across segments, and a
  peer dying mid-frame all resolve by construction — the per-connection
  stream state dies with the connection, and the dialer reconnects with
  capped exponential backoff. A connection opens with a tiny hello
  preamble announcing the dialer's logical node id (replica ids are
  logical names, not addresses — the same id space the simulator uses,
  which is what makes object-mode ≡ socket-mode replays possible).

Both ends of a link bind one socket: a node sends *from* its listening
UDP socket, so the datagram source address identifies the sender, and
TCP senders identify themselves in the hello. Receivers hand up
``(src_node_id, FrameBytes)``; everything above this layer is
transport-agnostic.
"""

from __future__ import annotations

import asyncio
import random
import struct
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

from ..wire.frames import FrameBytes, FrameStream
from .stats import LinkStats

Receiver = Callable[[str, FrameBytes], None]


def parse_addr(addr: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)``; raises ValueError on junk."""
    host, sep, port = addr.rpartition(":")
    if not sep or not host:
        raise ValueError(f"address {addr!r} is not HOST:PORT")
    try:
        p = int(port)
    except ValueError:
        raise ValueError(f"address {addr!r} has a non-integer port")
    if not 0 <= p <= 65535:
        raise ValueError(f"address {addr!r} port out of range")
    return host, p


def format_addr(addr: Tuple[str, int]) -> str:
    return f"{addr[0]}:{addr[1]}"


class Transport:
    """What :class:`~repro.net.node.GossipNode` drives: bind a listening
    socket, send batches of frames to peer addresses, deliver inbound
    frames (with the sender's node id) to a receiver callback."""

    def __init__(self, stats: Optional[LinkStats] = None):
        self.stats = stats if stats is not None else LinkStats()
        self._receiver: Optional[Receiver] = None
        self.addr: Optional[str] = None      # bound "host:port" after start
        self.closed = False

    def set_receiver(self, cb: Receiver) -> None:
        self._receiver = cb

    def _deliver(self, src: str, frame: FrameBytes) -> None:
        if self._receiver is not None and not self.closed:
            self._receiver(src, frame)

    async def start(self, listen: str) -> str:
        raise NotImplementedError

    async def send_frames(self, peer_addr: str, frames) -> None:
        raise NotImplementedError

    async def close(self) -> None:
        self.closed = True


# ---------------------------------------------------------------------------
# UDP
# ---------------------------------------------------------------------------

# shard header for frames larger than the MTU:
#   magic "δF", version, flags, frame-id u32, shard index u16, count u16
_SHARD_MAGIC = b"\xd5F"
_SHARD = struct.Struct("<2sBBIHH")
SHARD_VERSION = 1


class _UdpProtocol(asyncio.DatagramProtocol):
    def __init__(self, owner: "UdpTransport"):
        self.owner = owner

    def datagram_received(self, data: bytes, addr) -> None:
        self.owner._datagram_received(data, addr)

    def error_received(self, exc) -> None:  # pragma: no cover - ICMP noise
        pass


class UdpTransport(Transport):
    """Datagram channel; see module docstring.

    ``loss`` / ``dup`` / ``reorder`` are *send-path* fault injection for
    loopback tests and benches (a real deployment leaves them 0 and lets
    the network be the network): each outgoing datagram is independently
    dropped with probability ``loss``, sent twice with probability
    ``dup``, or held back one datagram with probability ``reorder`` —
    seeded, so a lossy-mesh test is reproducible.

    ``max_partial`` bounds reassembly memory per source: at most that
    many oversized frames may be in flight from one peer; starting one
    more evicts the oldest partial (drop-whole-frame).
    """

    def __init__(self, mtu: int = 1400, *, loss: float = 0.0,
                 dup: float = 0.0, reorder: float = 0.0, seed: int = 0,
                 max_partial: int = 8,
                 max_frame: int = 64 * 1024 * 1024,
                 stats: Optional[LinkStats] = None):
        super().__init__(stats)
        if mtu <= _SHARD.size:
            raise ValueError(f"mtu {mtu} smaller than the shard header")
        self.mtu = mtu
        self.loss, self.dup, self.reorder = loss, dup, reorder
        self.rng = random.Random(seed)
        self.max_partial = max_partial
        self.max_frame = max_frame
        self.injected_losses = 0
        self._transport: Optional[asyncio.DatagramTransport] = None
        self._frame_id = 0
        self._held: Optional[Tuple[bytes, Tuple[str, int]]] = None
        # per-source decode state: FrameStream + partial reassemblies
        self._streams: Dict[str, FrameStream] = {}
        self._partials: Dict[str, OrderedDict] = {}

    async def start(self, listen: str) -> str:
        loop = asyncio.get_running_loop()
        host, port = parse_addr(listen)
        self._transport, _ = await loop.create_datagram_endpoint(
            lambda: _UdpProtocol(self), local_addr=(host, port))
        self.addr = format_addr(
            self._transport.get_extra_info("sockname")[:2])
        return self.addr

    # -- send path -----------------------------------------------------------
    def _emit(self, datagram: bytes, addr: Tuple[str, int]) -> None:
        """One datagram onto the wire, through the fault hooks."""
        assert self._transport is not None
        if self.loss and self.rng.random() < self.loss:
            self.injected_losses += 1
            return
        copies = 2 if self.dup and self.rng.random() < self.dup else 1
        if self.reorder and self._held is None \
                and self.rng.random() < self.reorder:
            self._held = (datagram, addr)     # swap with the next datagram
            return
        for _ in range(copies):
            self.stats.datagrams_sent += 1
            self._transport.sendto(datagram, addr)
        if self._held is not None:
            held, haddr = self._held
            self._held = None
            self.stats.datagrams_sent += 1
            self._transport.sendto(held, haddr)

    async def send_frames(self, peer_addr: str, frames) -> None:
        addr = parse_addr(peer_addr)
        batch: list = []
        size = 0
        for frame in frames:
            if len(frame) > self.mtu:
                if batch:
                    self._emit(b"".join(batch), addr)
                    batch, size = [], 0
                self._send_sharded(bytes(frame), addr)
                continue
            if size + len(frame) > self.mtu and batch:
                self._emit(b"".join(batch), addr)
                batch, size = [], 0
            batch.append(bytes(frame))
            size += len(frame)
        if batch:
            self._emit(b"".join(batch), addr)

    def _send_sharded(self, frame: bytes, addr: Tuple[str, int]) -> None:
        body = self.mtu - _SHARD.size
        count = (len(frame) + body - 1) // body
        if count > 0xFFFF:
            raise ValueError(f"frame of {len(frame)} bytes exceeds the "
                             f"shard space at mtu={self.mtu}")
        fid = self._frame_id = (self._frame_id + 1) & 0xFFFFFFFF
        for i in range(count):
            chunk = frame[i * body:(i + 1) * body]
            self.stats.chunks_sent += 1
            self._emit(_SHARD.pack(_SHARD_MAGIC, SHARD_VERSION, 0,
                                   fid, i, count) + chunk, addr)

    # -- receive path ----------------------------------------------------------
    def _stream_for(self, src: str) -> FrameStream:
        s = self._streams.get(src)
        if s is None:
            s = self._streams[src] = FrameStream(max_frame=self.max_frame)
        return s

    def _datagram_received(self, data: bytes, addr) -> None:
        self.stats.datagrams_recv += 1
        src = format_addr(addr[:2])
        if data[:2] == _SHARD_MAGIC and len(data) >= _SHARD.size:
            data = self._reassemble(src, data)
            if data is None:
                return
        stream = self._stream_for(src)
        for frame in stream.feed(data):
            self._deliver(src, frame)
        if stream.pending:
            # datagrams are atomic: leftover bytes mean a frame was
            # truncated (a lost shard slipped through, or junk) —
            # drop-whole-frame, never smear bytes across datagrams
            stream.reset()
            self.stats.reassembly_drops += 1
        self.stats.resyncs = sum(s.resyncs for s in self._streams.values())

    def _reassemble(self, src: str, data: bytes) -> Optional[bytes]:
        magic, version, _flags, fid, index, count = _SHARD.unpack_from(
            data, 0)
        if version != SHARD_VERSION or count == 0 or index >= count:
            self.stats.reassembly_drops += 1
            return None
        partials = self._partials.setdefault(src, OrderedDict())
        entry = partials.get(fid)
        if entry is None:
            entry = partials[fid] = {}
            while len(partials) > self.max_partial:
                partials.popitem(last=False)     # evict oldest partial
                self.stats.reassembly_drops += 1
        entry[index] = data[_SHARD.size:]
        if len(entry) < count:
            return None
        del partials[fid]
        return b"".join(entry[i] for i in range(count))

    async def close(self) -> None:
        await super().close()
        if self._transport is not None:
            self._transport.close()


# ---------------------------------------------------------------------------
# TCP
# ---------------------------------------------------------------------------

_HELLO_MAGIC = b"\xd4H"
_HELLO = struct.Struct("<2sH")     # magic + id length


class TcpTransport(Transport):
    """Stream channel; see module docstring.

    ``node_id`` is announced in the hello preamble of every outbound
    connection; inbound frames are attributed to the id the dialer
    announced. Each peer gets one cached outbound connection;
    ``send_frames`` dials on demand with capped exponential backoff
    (``reconnect_min``→``reconnect_max``) and *blocks* while the peer is
    down — the caller's bounded queue is the admission valve, shedding
    oldest frames while the dialer waits.
    """

    def __init__(self, node_id: str, *,
                 reconnect_min: float = 0.05, reconnect_max: float = 1.0,
                 max_frame: int = 64 * 1024 * 1024,
                 stats: Optional[LinkStats] = None):
        super().__init__(stats)
        self.node_id = node_id
        self.reconnect_min = reconnect_min
        self.reconnect_max = reconnect_max
        self.max_frame = max_frame
        self._server: Optional[asyncio.AbstractServer] = None
        self._writers: Dict[str, asyncio.StreamWriter] = {}
        self._reader_tasks: set = set()

    async def start(self, listen: str) -> str:
        host, port = parse_addr(listen)
        self._server = await asyncio.start_server(self._on_conn, host, port)
        self.addr = format_addr(
            self._server.sockets[0].getsockname()[:2])
        return self.addr

    # -- inbound ---------------------------------------------------------------
    async def _on_conn(self, reader: asyncio.StreamReader,
                       writer: asyncio.StreamWriter) -> None:
        task = asyncio.current_task()
        if task is not None:
            self._reader_tasks.add(task)
            task.add_done_callback(self._reader_tasks.discard)
        stream = FrameStream(max_frame=self.max_frame)
        src: Optional[str] = None
        try:
            head = await reader.readexactly(_HELLO.size)
            magic, idlen = _HELLO.unpack(head)
            if magic != _HELLO_MAGIC:
                return                       # not one of ours
            src = (await reader.readexactly(idlen)).decode("utf-8")
            while not self.closed:
                data = await reader.read(65536)
                if not data:
                    break                    # EOF: peer closed / crashed
                for frame in stream.feed(data):
                    self._deliver(src, frame)
        except (asyncio.IncompleteReadError, ConnectionError,
                asyncio.CancelledError):
            pass                             # mid-frame death: stream state
        finally:                             # dies with the connection
            self.stats.resyncs += stream.resyncs
            writer.close()

    # -- outbound --------------------------------------------------------------
    async def _dial(self, peer_addr: str) -> asyncio.StreamWriter:
        host, port = parse_addr(peer_addr)
        backoff = self.reconnect_min
        first = True
        while not self.closed:
            try:
                _reader, writer = await asyncio.open_connection(host, port)
                ident = self.node_id.encode("utf-8")
                writer.write(_HELLO.pack(_HELLO_MAGIC, len(ident)) + ident)
                await writer.drain()
                return writer
            except OSError:
                if not first:
                    self.stats.reconnects += 1
                first = False
                await asyncio.sleep(backoff)
                backoff = min(backoff * 2, self.reconnect_max)
        raise ConnectionError("transport closed while dialing")

    async def _writer_for(self, peer_addr: str) -> asyncio.StreamWriter:
        w = self._writers.get(peer_addr)
        if w is not None and not w.is_closing():
            return w
        if w is not None:
            self.stats.reconnects += 1
        w = await self._dial(peer_addr)
        self._writers[peer_addr] = w
        return w

    async def send_frames(self, peer_addr: str, frames) -> None:
        w = await self._writer_for(peer_addr)
        try:
            w.write(b"".join(bytes(f) for f in frames))
            await w.drain()                  # TCP backpressure, for real
        except (ConnectionError, OSError):
            w.close()                        # frames lost with the link —
            self._writers.pop(peer_addr, None)   # digest-sync repairs

    async def inject_raw(self, peer_addr: str, data: bytes) -> None:
        """Test hook: push raw bytes (e.g. half a frame) down the
        connection without framing — how the mid-frame-crash tests put
        a torn frame on a real socket deterministically."""
        w = await self._writer_for(peer_addr)
        w.write(data)
        await w.drain()

    def abort_connections(self) -> None:
        """Abruptly kill every outbound connection (crash simulation)."""
        for w in self._writers.values():
            t = w.transport
            if t is not None:
                t.abort()
        self._writers.clear()

    async def close(self) -> None:
        await super().close()
        if self._server is not None:
            self._server.close()
            try:
                await self._server.wait_closed()
            except asyncio.CancelledError:  # pragma: no cover
                pass
        self.abort_connections()
        for task in list(self._reader_tasks):
            task.cancel()


def make_transport(kind: str, node_id: str, *, mtu: int = 1400,
                   loss: float = 0.0, dup: float = 0.0,
                   reorder: float = 0.0, seed: int = 0,
                   stats: Optional[LinkStats] = None) -> Transport:
    """Transport factory behind ``serve.py --transport``."""
    if kind == "udp":
        return UdpTransport(mtu=mtu, loss=loss, dup=dup, reorder=reorder,
                            seed=seed, stats=stats)
    if kind == "tcp":
        if loss or dup or reorder:
            raise ValueError("loss/dup/reorder injection is UDP-only "
                             "(TCP retransmits under the socket)")
        return TcpTransport(node_id, stats=stats)
    raise ValueError(f"unknown transport {kind!r}; have udp, tcp")
