"""Unified observability: trace bus, metrics registry, probes, scrape.

One subsystem, four surfaces (DESIGN.md §12):

* :mod:`repro.obs.trace`    — :class:`Tracer`, the structured event bus
  every engine layer emits into (deterministic-clock mode makes sim and
  socket traces comparable).
* :mod:`repro.obs.registry` — :class:`Registry` (counters / gauges /
  histograms with label sets) plus absorbers for the counters the repo
  already keeps (``NetStats``/``LinkStats``/``KernelCounters``) and the
  replicated δ-CRDT metrics lattice (ex ``sync/metrics.py``).
* :mod:`repro.obs.probes`   — derived convergence-lag and engine-health
  gauges (:class:`ReplicaProbes`, :class:`AckLagProbe`, marker lag).
* :mod:`repro.obs.scrape`   — :class:`MetricsServer` (Prometheus text +
  JSON sidecar endpoint) and the matching :func:`scrape` clients.
* :mod:`repro.obs.analyze`  — trace analytics: redundancy ratio,
  convergence rounds/lag per key, anomaly flags, semantic equivalence.
"""

from .analyze import (anomalies, convergence, load_trace, redundancy,
                      report, semantic_trace)
from .probes import AckLagProbe, ReplicaProbes, marker_lag_histogram
from .registry import (Counter, Gauge, Histogram, Metrics, MetricRecord,
                       MetricsState, Registry, global_registry,
                       reset_global_registry)
from .scrape import MetricsServer, parse_prometheus, scrape, scrape_json
from .trace import EVENT_KINDS, Tracer, merge_events, trace_kernel_launches

__all__ = [
    "AckLagProbe", "Counter", "EVENT_KINDS", "Gauge", "Histogram",
    "Metrics", "MetricRecord", "MetricsServer", "MetricsState",
    "Registry", "ReplicaProbes", "Tracer", "anomalies", "convergence",
    "global_registry", "load_trace", "marker_lag_histogram",
    "merge_events", "parse_prometheus", "redundancy", "report",
    "reset_global_registry", "scrape", "scrape_json", "semantic_trace",
    "trace_kernel_launches",
]
