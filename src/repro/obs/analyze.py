"""Trace analyzer: redundancy, convergence, and anomaly detection.

2006.09823 frames strong eventual consistency as a *trace* property —
every delivered update is eventually joined everywhere — and 1803.02750
quantifies the cost side: how many of the shipped bytes were already
known to the receiver. Both are directly computable from a merged
:class:`~repro.obs.trace.Tracer` stream:

* :func:`redundancy` — bytes shipped (``delta_ship`` + ``digest_resp``
  + ``handoff``) vs. bytes whose arrival actually changed receiver
  state (``delta_join`` with a non-empty changed-key set). The ratio is
  ≥ 1.0 by construction; ship-all on a mesh sits far above BP+RR.
* :func:`convergence` — per key: writes, the writers, the nodes the key
  reached, the seconds from last write to the last state-changing join,
  and the number of writer ship-rounds that elapsed in that window
  (the paper's rounds-to-convergence, measured not simulated).
* :func:`anomalies` — trace-level SEC violations:
  ``ship_without_join`` (a written key was shipped but never changed
  state anywhere else — the delivery hole a converged cluster must not
  have), ``ship_before_have`` (a node shipped a key it neither wrote nor
  joined first — accounting corruption), ``ack_without_ship`` (an ack
  arrived from a peer that was never shipped a tagged payload, or for a
  tag above anything shipped — credit corruption upstream of RR's
  known-state bound).
* :func:`semantic_trace` — the timing-free per-key view two runs of the
  same schedule must agree on (who wrote how often, who converged to
  holding it); ``test_sim_socket_equivalence`` asserts a Simulator run
  and a loopback UDP run produce equal semantic traces. Ship edges and
  digest participation are deliberately excluded: *which* peer first
  delivered a key is a race both in the sim and on sockets.
* :func:`report` — the bench-facing rollup (redundancy ratio,
  convergence summary, anomaly counts) recorded into BENCH_tier1.json.

Caveats the functions enforce: events whose key lists were truncated
(``keys_truncated``) disable key-level anomaly checks rather than
emitting false positives, and a ring buffer that evicted early events
can fabricate ``ship_before_have`` — analyze full traces (size the
tracer capacity to the run, or use a JSONL sink).
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Set

from .trace import merge_events

SHIP_KINDS = ("delta_ship", "digest_resp", "handoff")


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Read one tracer's JSONL sink back into an event list."""
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _events(trace: Any) -> List[Dict[str, Any]]:
    """Accept a tracer, an event list, or a list of either (merged)."""
    if hasattr(trace, "events"):
        return trace.events()
    if isinstance(trace, (list, tuple)) and trace and not isinstance(
            trace[0], dict):
        return merge_events(*trace)
    return list(trace)


# ---------------------------------------------------------------------------
# Redundancy: shipped bytes vs bytes that changed state
# ---------------------------------------------------------------------------

def redundancy(trace: Any) -> Dict[str, Any]:
    """How much of the shipped traffic was already known to receivers.

    ``ratio`` = state-carrying bytes shipped / bytes of arrivals that
    changed receiver state (NaN when nothing joined). ``redundant_joins``
    counts arrivals that changed nothing at all — the payloads RR/BP
    exist to eliminate.
    """
    shipped = joined = 0
    ships = joins = redundant = 0
    for ev in _events(trace):
        k = ev["kind"]
        if k in SHIP_KINDS:
            shipped += ev.get("bytes", 0)
            ships += 1
        elif k == "delta_join":
            joins += 1
            if ev.get("joined", 0) > 0:
                joined += ev.get("bytes", 0)
            else:
                redundant += 1
    return {
        "shipped_bytes": shipped,
        "joined_bytes": joined,
        "ratio": (shipped / joined) if joined else float("nan"),
        "ships": ships,
        "joins": joins,
        "redundant_joins": redundant,
    }


# ---------------------------------------------------------------------------
# Convergence: per-key write→everywhere lag and rounds
# ---------------------------------------------------------------------------

def convergence(trace: Any) -> Dict[str, Dict[str, Any]]:
    """Per-key convergence record (see module docstring). ``lag_s`` and
    ``rounds`` measure from the key's *last* write to its last
    state-changing join — on a converged run, the moment every replica
    held the final value."""
    events = _events(trace)
    out: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        if ev["kind"] != "write":
            continue
        for k in ev.get("keys") or ():
            rec = out.setdefault(k, {"writes": 0, "writers": set(),
                                     "nodes": set(), "last_write_t": None,
                                     "lag_s": 0.0, "rounds": 0})
            rec["writes"] += 1
            rec["writers"].add(ev["node"])
            rec["nodes"].add(ev["node"])
            t = ev.get("t", 0.0)
            if rec["last_write_t"] is None or t > rec["last_write_t"]:
                rec["last_write_t"] = t
    for ev in events:
        if ev["kind"] != "delta_join" or not ev.get("joined", 0):
            continue
        for k in ev.get("keys") or ():
            rec = out.get(k)
            if rec is None:
                continue
            rec["nodes"].add(ev["node"])
            if rec["last_write_t"] is not None:
                lag = ev.get("t", 0.0) - rec["last_write_t"]
                if lag > rec["lag_s"]:
                    rec["lag_s"] = lag
    # rounds: distinct (writer, round) ship rounds carrying the key in
    # each key's convergence window [last write, last changing join]
    for ev in events:
        if ev["kind"] != "delta_ship":
            continue
        for k in ev.get("keys") or ():
            rec = out.get(k)
            if rec is None or ev["node"] not in rec["writers"]:
                continue
            t0 = rec["last_write_t"]
            if t0 is not None and t0 <= ev.get("t", 0.0) <= t0 + rec["lag_s"]:
                rounds = rec.setdefault("_round_set", set())
                rounds.add((ev["node"], ev.get("round", 0)))
    for rec in out.values():
        rec["rounds"] = len(rec.pop("_round_set", ()))
        rec["writers"] = sorted(rec["writers"])
        rec["nodes"] = sorted(rec["nodes"])
    return out


# ---------------------------------------------------------------------------
# Anomalies
# ---------------------------------------------------------------------------

def anomalies(trace: Any) -> List[Dict[str, Any]]:
    """Trace-level consistency violations (empty list ⇔ clean trace)."""
    events = _events(trace)
    truncated = any(ev.get("keys_truncated") for ev in events)
    out: List[Dict[str, Any]] = []

    # ack bookkeeping is key-independent: always checkable
    max_ship_tag: Dict[tuple, int] = {}
    for ev in events:
        if ev["kind"] == "delta_ship" and "tag" in ev:
            edge = (ev["node"], ev["dst"])
            max_ship_tag[edge] = max(max_ship_tag.get(edge, -1), ev["tag"])
        elif ev["kind"] == "ack":
            edge = (ev["node"], ev["src"])
            top = max_ship_tag.get(edge)
            if top is None:
                out.append({"kind": "ack_without_ship", "node": ev["node"],
                            "src": ev["src"], "tag": ev.get("tag")})
            elif ev.get("tag", 0) > top:
                out.append({"kind": "ack_above_ship", "node": ev["node"],
                            "src": ev["src"], "tag": ev.get("tag"),
                            "max_shipped": top})
    if truncated:
        out.append({"kind": "keys_truncated",
                    "note": "key-level checks skipped"})
        return out

    nodes: Set[str] = {ev["node"] for ev in events}
    have: Dict[str, Set[str]] = {}          # node -> keys written/joined
    shipped_keys: Set[str] = set()
    written_keys: Set[str] = set()
    joined_keys: Set[str] = set()
    for ev in events:
        node = ev["node"]
        if ev["kind"] == "write":
            ks = ev.get("keys") or ()
            written_keys.update(ks)
            have.setdefault(node, set()).update(ks)
        elif ev["kind"] == "delta_join":
            ks = (ev.get("keys") or ()) if ev.get("joined", 0) else ()
            joined_keys.update(ks)
            have.setdefault(node, set()).update(ks)
        elif ev["kind"] in ("delta_ship", "handoff"):
            ks = ev.get("keys") or ()
            shipped_keys.update(ks)
            if not ev.get("full"):
                held = have.get(node, set())
                for k in ks:
                    if k not in held:
                        out.append({"kind": "ship_before_have",
                                    "node": node, "dst": ev.get("dst"),
                                    "key": k, "seq": ev.get("seq")})
    if len(nodes) > 1:
        for k in sorted((written_keys & shipped_keys) - joined_keys):
            out.append({"kind": "ship_without_join", "key": k})
    return out


# ---------------------------------------------------------------------------
# Semantic equivalence
# ---------------------------------------------------------------------------

def semantic_trace(trace: Any) -> Dict[str, Dict[str, Any]]:
    """The timing-free view two runs of one schedule must agree on:
    per key, how many writes each writer issued and the sorted set of
    nodes that ended up holding it (writers + state-changing joiners)."""
    out: Dict[str, Dict[str, Any]] = {}
    for ev in _events(trace):
        if ev["kind"] == "write":
            for k in ev.get("keys") or ():
                rec = out.setdefault(k, {"writes": {}, "joined": set()})
                w = rec["writes"]
                w[ev["node"]] = w.get(ev["node"], 0) + 1
                rec["joined"].add(ev["node"])
        elif ev["kind"] == "delta_join" and ev.get("joined", 0):
            for k in ev.get("keys") or ():
                rec = out.setdefault(k, {"writes": {}, "joined": set()})
                rec["joined"].add(ev["node"])
    return {k: {"writes": rec["writes"],
                "joined": sorted(rec["joined"])}
            for k, rec in out.items()}


# ---------------------------------------------------------------------------
# Rollup
# ---------------------------------------------------------------------------

def report(trace: Any, *, expect_converged: Optional[Iterable[str]] = None
           ) -> Dict[str, Any]:
    """The bench-facing rollup: redundancy, convergence summary, anomaly
    counts. ``expect_converged`` (an iterable of node ids) additionally
    asserts every written key reached every one of those nodes."""
    events = _events(trace)
    red = redundancy(events)
    conv = convergence(events)
    anom = anomalies(events)
    lags = [rec["lag_s"] for rec in conv.values() if rec["writes"]]
    rounds = [rec["rounds"] for rec in conv.values() if rec["writes"]]
    anomaly_counts: Dict[str, int] = {}
    for a in anom:
        anomaly_counts[a["kind"]] = anomaly_counts.get(a["kind"], 0) + 1
    rep = {
        "redundancy": red,
        "keys": len(conv),
        "mean_lag_s": (sum(lags) / len(lags)) if lags else 0.0,
        "max_lag_s": max(lags) if lags else 0.0,
        "mean_rounds": (sum(rounds) / len(rounds)) if rounds else 0.0,
        "anomalies": anomaly_counts,
        "anomaly_list": anom,
    }
    if expect_converged is not None:
        want = set(expect_converged)
        missing = {k: sorted(want - set(rec["nodes"]))
                   for k, rec in conv.items()
                   if want - set(rec["nodes"])}
        rep["unconverged_keys"] = missing
    return rep
