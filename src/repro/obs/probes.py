"""Derived probes: convergence-lag and engine-health gauges.

The absorbers in :mod:`repro.obs.registry` mirror counters that already
exist; the probes compute the quantities 1803.02750 actually argues
about — how far behind each peer is, how long a write takes to be safe,
whether the reaper's quorums are making progress:

* :class:`ReplicaProbes` — per-peer delta-buffer and ack-horizon health
  read straight off a live :class:`~repro.core.propagation.Replica`:
  buffer depth, the GC horizon and its *age* (entries the slowest peer
  has not acknowledged — the quantity that pins buffer memory), per-peer
  unacked-entry counts, the in-flight/ack credit balance
  (``_inflight`` records awaiting acknowledgment), and reap-quorum
  progress (pending proposals, outstanding votes, committed/evicted
  totals) when a reaper is attached.
* :class:`AckLagProbe` — write→fully-acked latency: :meth:`note_write`
  stamps each local δ-mutation's counter tag; a poll (every scrape, or
  every tick via :meth:`poll`) resolves tags once every *push peer's*
  cumulative ack has passed them and feeds the latency histogram. This
  is the locally-measurable replication-lag signal: a fully-acked write
  is durable at every push peer, so it upper-bounds visibility lag on
  the push set without writing any probe keys into the store (a socket
  cluster's key set is workload state — bench_net asserts on it).
* :func:`marker_lag_histogram` — the cross-process marker technique's
  home: ``bench_net``'s UDP load generator writes marker keys and polls
  the *read side* for visibility; the measured write→visible-everywhere
  latencies feed this histogram, giving the scrape surface true
  end-to-end per-key replication lag where a read set is observable.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Callable, Deque, Optional, Tuple

from .registry import Histogram, Registry

LAG_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
               5.0, 10.0, 30.0)


def marker_lag_histogram(registry: Registry, *, node: str = "") -> Any:
    """The per-key replication-lag histogram (marker technique): callers
    observe write→visible-on-read-set seconds into the returned child."""
    return registry.histogram(
        "repro_marker_lag_seconds",
        "per-key write→visible-on-read-set replication lag "
        "(marker technique)",
        ("node",), buckets=LAG_BUCKETS).labels(node)


class ReplicaProbes:
    """Collect-time gauges over one replica's engine state.

    Registers a single collector; every scrape reads the live maps
    (``entries``, ``A``, ``_basic_sent``, ``_inflight``, the reaper's
    ``_pending``) — the engine's hot path is untouched.
    """

    def __init__(self, registry: Registry, replica: Any, *,
                 node: Optional[str] = None):
        self.replica = replica
        node = node if node is not None else replica.id
        depth = registry.gauge("repro_replica_delta_buffer_depth",
                               "buffered delta entries", ("node",))
        counter = registry.gauge("repro_replica_counter",
                                 "the causal counter c", ("node",))
        rounds = registry.counter("repro_replica_rounds_total",
                                  "anti-entropy rounds run", ("node",))
        horizon = registry.gauge("repro_replica_gc_horizon",
                                 "entry index every push peer has "
                                 "passed (acks / basic watermarks)",
                                 ("node",))
        horizon_age = registry.gauge(
            "repro_replica_gc_horizon_age",
            "entries above the GC horizon — what the slowest push peer "
            "pins in memory", ("node",))
        unacked = registry.gauge("repro_replica_unacked_entries",
                                 "entries this peer has not acked "
                                 "(c - A[peer]; basic mode: c - "
                                 "broadcast watermark)", ("node", "peer"))
        inflight = registry.gauge(
            "repro_replica_inflight",
            "remembered in-flight payloads awaiting this peer's ack "
            "(the ack credit balance)", ("node", "peer"))
        tomb = registry.gauge("repro_replica_tombstoned_keys",
                              "keys held only as tombstones", ("node",))
        reap_pending = registry.gauge("repro_reap_pending",
                                      "open reap proposals", ("node",))
        reap_votes = registry.gauge(
            "repro_reap_votes_outstanding",
            "quorum votes still missing across open proposals",
            ("node",))
        reap_committed = registry.counter("repro_reap_committed_total",
                                          "tombstones committed",
                                          ("node",))
        reap_evicted = registry.counter("repro_reap_evicted_total",
                                        "foreign expired copies shed",
                                        ("node",))

        def collect() -> None:
            r = self.replica
            depth.labels(node).set(len(r.entries))
            counter.labels(node).set(r.c)
            rounds.labels(node).set_total(r.rounds)
            peers = r.policy.ack_peers(r, list(r.neighbors))
            marks = r.A if r.causal else r._basic_sent
            for j in peers:
                unacked.labels(node, j).set(r.c - marks.get(j, 0))
            h = min((marks.get(j, 0) for j in peers), default=r.c)
            horizon.labels(node).set(h)
            horizon_age.labels(node).set(r.c - h)
            per_peer: dict = {}
            for (dst, _tag) in r._inflight:
                per_peer[dst] = per_peer.get(dst, 0) + 1
            for j in peers:
                inflight.labels(node, j).set(per_peer.get(j, 0))
            try:
                tomb.labels(node).set(len(r.store.tombstoned_keys()))
            except AttributeError:
                pass
            reaper = r.reaper
            if reaper is not None:
                pend = reaper._pending
                reap_pending.labels(node).set(len(pend))
                missing = 0
                for key, prop in list(pend.items()):
                    missing += max(
                        0, len(reaper._quorum(key)) - len(prop.acks))
                reap_votes.labels(node).set(missing)
                reap_committed.labels(node).set_total(reaper.reaped)
                reap_evicted.labels(node).set_total(reaper.evicted)

        registry.add_collector(collect)


class AckLagProbe:
    """Write→fully-acked-by-push-peers latency for one causal replica.

    ``note_write()`` after each local δ-mutation stamps ``(replica.c,
    now)``; :meth:`poll` resolves every stamp whose tag all current push
    peers have acked (``min A ≥ tag``) into the lag histogram. The probe
    registers itself as a collector, so an idle scrape also resolves —
    but calling ``poll`` from the tick loop gives tick-resolution
    latencies instead of scrape-resolution ones.
    """

    MAX_PENDING = 4096      # stamps; beyond this the oldest are shed

    def __init__(self, registry: Registry, replica: Any, *,
                 node: Optional[str] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.replica = replica
        self.clock = clock if clock is not None else replica.now
        node = node if node is not None else replica.id
        self._pending: Deque[Tuple[int, float]] = deque()
        self.shed = 0
        self.lag: Histogram = registry.histogram(
            "repro_ack_lag_seconds",
            "write→fully-acked-by-push-peers latency",
            ("node",), buckets=LAG_BUCKETS)
        self._lag_child = self.lag.labels(node)
        self._pending_gauge = registry.gauge(
            "repro_ack_pending_writes",
            "local writes not yet acked by every push peer", ("node",))
        self._pending_child = self._pending_gauge.labels(node)
        registry.add_collector(self.poll)

    def note_write(self) -> None:
        """Stamp the just-recorded write (call right after ``update`` /
        ``operation``: the write holds tag ``c - 1``, so it is covered
        once acks reach ``c``)."""
        self._pending.append((self.replica.c, self.clock()))
        while len(self._pending) > self.MAX_PENDING:
            self._pending.popleft()
            self.shed += 1

    def poll(self, now: Optional[float] = None) -> int:
        """Resolve fully-acked stamps; returns how many resolved."""
        r = self.replica
        peers = r.policy.ack_peers(r, list(r.neighbors))
        if not self._pending or not peers:
            self._pending_child.set(len(self._pending))
            return 0
        acked = min(r.A.get(j, 0) for j in peers)
        now = self.clock() if now is None else now
        n = 0
        while self._pending and self._pending[0][0] <= acked:
            _, t0 = self._pending.popleft()
            self._lag_child.observe(now - t0)
            n += 1
        self._pending_child.set(len(self._pending))
        return n
