"""Metrics registry: one home for every counter the nine layers grew.

The runtime already *counts* everything that matters — ``sim.NetStats``
counts sent frames, ``net.stats.LinkStats`` adds the receive side,
``kernels.ops.KernelCounters`` counts launches and staging bytes — but
each in its own shape, none scrapeable. The registry does not replace
those objects (their attribute APIs are load-bearing at hundreds of call
sites); it **absorbs** them: an absorber registers a collector that
reads the live stats object at scrape time and publishes its fields as
labelled metric families. Call sites keep incrementing plain attributes;
the registry sees the current value whenever someone looks.

Three family types, Prometheus-shaped:

* :class:`Counter` — monotone totals. ``inc()`` for native counts, or
  ``set_total()`` for absorbed sources that already accumulate.
* :class:`Gauge` — point-in-time values; ``set()``/``inc()``/``dec()``,
  or ``set_function(fn)`` for values computed at collect time.
* :class:`Histogram` — fixed buckets + sum/count; ``observe()`` and an
  ``approx_quantile`` for the bench tables.

Families carry label *names*; children (one per label-value tuple) carry
the numbers. Dynamic label sets — per-peer, per-kind, per-link-class —
come from **collectors**: callables registered via
:meth:`Registry.add_collector` that run at the top of every
``snapshot()`` / ``render_prometheus()`` and write whatever children the
live objects currently imply.

The registry also hosts the δ-CRDT metrics lattice
(:class:`MetricRecord` / :class:`MetricsState` / :class:`Metrics`, moved
here from ``sync/metrics.py`` — that module is now a re-export shim):
local process counters and replicated duplicate-safe aggregates are two
views of the same observability layer, and :meth:`Registry.absorb_crdt_metrics`
bridges them (each replicated metric's cluster-wide aggregates surface
as gauges).
"""

from __future__ import annotations

import bisect
import json
import math
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.crdts import DeltaCRDT
from ..core.dots import ReplicaId

# ---------------------------------------------------------------------------
# Metric families
# ---------------------------------------------------------------------------

_RESERVED = {"le"}      # histogram bucket label


def _check_name(name: str) -> str:
    if not name or not all(c.isalnum() or c in "_:" for c in name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


def _escape(v: Any) -> str:
    return (str(v).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


class _Family:
    """Shared labelled-children machinery for the three metric types."""

    typ = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = ()):
        bad = _RESERVED & set(labelnames)
        if bad:
            raise ValueError(f"reserved label name(s) {sorted(bad)}")
        self.name = _check_name(name)
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: Dict[Tuple[str, ...], Any] = {}

    def labels(self, *values: Any, **kv: Any):
        if kv:
            if values:
                raise ValueError("pass label values positionally OR by name")
            values = tuple(kv[n] for n in self.labelnames)
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name} expects labels {self.labelnames}, "
                f"got {values!r}")
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = self._make_child()
        return child

    def _make_child(self):  # pragma: no cover - overridden
        raise NotImplementedError

    def _label_str(self, key: Tuple[str, ...],
                   extra: Tuple[Tuple[str, str], ...] = ()) -> str:
        pairs = [(n, v) for n, v in zip(self.labelnames, key)] + list(extra)
        if not pairs:
            return ""
        inner = ",".join(f'{n}="{_escape(v)}"' for n, v in pairs)
        return "{" + inner + "}"

    def clear(self) -> None:
        self._children.clear()

    # a label-less family proxies child methods through a default child
    def _default(self):
        return self.labels()


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self.value += amount

    def set_total(self, total: float) -> None:
        """Install an externally-accumulated monotone total (absorbers:
        the source object — NetStats etc. — is the accumulator; the
        child just mirrors it at collect time)."""
        self.value = float(total)


class Counter(_Family):
    typ = "counter"

    def _make_child(self):
        return _CounterChild()

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def set_total(self, total: float) -> None:
        self._default().set_total(total)

    @property
    def value(self) -> float:
        return self._default().value

    def render(self, out: List[str]) -> None:
        for key, child in sorted(self._children.items()):
            out.append(f"{self.name}{self._label_str(key)} "
                       f"{_fmt(child.value)}")

    def sample(self) -> Any:
        if not self.labelnames:
            return self._default().value
        return {",".join(k): c.value for k, c in sorted(self._children.items())}


class _GaugeChild:
    __slots__ = ("_value", "fn")

    def __init__(self):
        self._value = 0.0
        self.fn: Optional[Callable[[], float]] = None

    def set(self, value: float) -> None:
        self.fn = None
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self._value -= amount

    def set_function(self, fn: Callable[[], float]) -> None:
        self.fn = fn

    @property
    def value(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value


class Gauge(_Family):
    typ = "gauge"

    def _make_child(self):
        return _GaugeChild()

    def set(self, value: float) -> None:
        self._default().set(value)

    def inc(self, amount: float = 1.0) -> None:
        self._default().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default().dec(amount)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._default().set_function(fn)

    @property
    def value(self) -> float:
        return self._default().value

    def render(self, out: List[str]) -> None:
        for key, child in sorted(self._children.items()):
            out.append(f"{self.name}{self._label_str(key)} "
                       f"{_fmt(child.value)}")

    def sample(self) -> Any:
        if not self.labelnames:
            return self._default().value
        return {",".join(k): c.value for k, c in sorted(self._children.items())}


DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                   1.0, 2.5, 5.0, 10.0)


class _HistogramChild:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: Tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * len(buckets)     # per-bucket (non-cumulative)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.sum += value
        self.count += 1
        i = bisect.bisect_left(self.buckets, value)
        if i < len(self.buckets):
            self.counts[i] += 1

    def approx_quantile(self, q: float) -> float:
        """Bucket-boundary quantile estimate (upper bound of the bucket
        the q-th observation falls in; +Inf tail returns the largest
        finite bound). NaN with no observations."""
        if self.count == 0:
            return float("nan")
        rank = q * self.count
        seen = 0
        for ub, c in zip(self.buckets, self.counts):
            seen += c
            if seen >= rank:
                return ub
        return self.buckets[-1] if self.buckets else float("nan")


class Histogram(_Family):
    typ = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("histogram needs at least one bucket")
        self.buckets = bs

    def _make_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, value: float) -> None:
        self._default().observe(value)

    def approx_quantile(self, q: float) -> float:
        return self._default().approx_quantile(q)

    @property
    def count(self) -> int:
        return self._default().count

    @property
    def sum(self) -> float:
        return self._default().sum

    def render(self, out: List[str]) -> None:
        for key, child in sorted(self._children.items()):
            cum = 0
            for ub, c in zip(child.buckets, child.counts):
                cum += c
                out.append(f"{self.name}_bucket"
                           f"{self._label_str(key, (('le', _fmt(ub)),))} "
                           f"{cum}")
            out.append(f"{self.name}_bucket"
                       f"{self._label_str(key, (('le', '+Inf'),))} "
                       f"{child.count}")
            out.append(f"{self.name}_sum{self._label_str(key)} "
                       f"{_fmt(child.sum)}")
            out.append(f"{self.name}_count{self._label_str(key)} "
                       f"{child.count}")

    def sample(self) -> Any:
        def one(c: _HistogramChild) -> Dict[str, Any]:
            return {"count": c.count, "sum": c.sum,
                    "p50": c.approx_quantile(0.5),
                    "p99": c.approx_quantile(0.99)}
        if not self.labelnames:
            return one(self._default())
        return {",".join(k): one(c)
                for k, c in sorted(self._children.items())}


def _fmt(v: float) -> str:
    if v != v:
        return "NaN"
    if v in (float("inf"), float("-inf")):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class Registry:
    """A named set of metric families plus collect-time collectors.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent by
    name — re-declaring with different labelnames raises, so two
    subsystems cannot silently fork one metric). Collectors run at the
    top of every :meth:`snapshot` / :meth:`render_prometheus`; they are
    how dynamic label sets (per-peer gauges, per-kind byte columns) stay
    current without the hot path writing to the registry at all. A lock
    guards family creation and collection — scrapes come from an asyncio
    sidecar while bench threads observe histograms.
    """

    def __init__(self):
        self._families: Dict[str, _Family] = {}
        self._collectors: List[Callable[[], None]] = []
        self._lock = threading.Lock()

    # -- declaration ------------------------------------------------------------
    def _get_or_make(self, cls, name: str, help: str,
                     labelnames: Sequence[str], **kw) -> Any:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if not isinstance(fam, cls) or (
                        fam.labelnames != tuple(labelnames)):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.typ}{fam.labelnames}")
                return fam
            fam = cls(name, help, labelnames, **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_make(Histogram, name, help, labelnames,
                                 buckets=buckets)

    def add_collector(self, fn: Callable[[], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    def collect(self) -> None:
        for fn in list(self._collectors):
            fn()

    # -- output -----------------------------------------------------------------
    def render_prometheus(self) -> str:
        """The Prometheus text exposition of every family."""
        self.collect()
        out: List[str] = []
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                if fam.help:
                    out.append(f"# HELP {name} {fam.help}")
                out.append(f"# TYPE {name} {fam.typ}")
                fam.render(out)
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able ``{metric: value}`` view: scalars for label-less
        families, ``{label-values: value}`` maps otherwise, and
        count/sum/p50/p99 summaries for histograms."""
        self.collect()
        with self._lock:
            return {name: self._families[name].sample()
                    for name in sorted(self._families)}

    def render_json(self) -> str:
        def clean(v: Any) -> Any:
            if isinstance(v, float) and not math.isfinite(v):
                return str(v)
            if isinstance(v, dict):
                return {k: clean(x) for k, x in v.items()}
            return v
        return json.dumps({k: clean(v) for k, v in self.snapshot().items()},
                          sort_keys=True)

    # -- absorbers: existing stats objects → labelled families --------------------
    def absorb_net_stats(self, stats: Any, *, node: str = "") -> None:
        """Publish a live :class:`~repro.core.sim.NetStats` (every field
        it has today — frame/byte totals, per-kind and per-link-class
        splits, the cost-model accumulator) as ``repro_net_*`` families
        labelled by ``node``. The stats object stays the accumulator;
        nothing at its call sites changes."""
        c = {
            "repro_net_frames_sent_total": ("sent", "frames sent"),
            "repro_net_frames_delivered_total": ("delivered",
                                                 "frames delivered"),
            "repro_net_frames_dropped_total": ("dropped", "frames dropped"),
            "repro_net_frames_duplicated_total": ("duplicated",
                                                  "frames duplicated"),
            "repro_net_bytes_sent_total": ("bytes_sent", "bytes sent"),
        }
        fams = {name: self.counter(name, help, ("node",))
                for name, (_, help) in c.items()}
        kind_n = self.counter("repro_net_frames_by_kind_total",
                              "frames sent per payload kind",
                              ("node", "kind"))
        kind_b = self.counter("repro_net_bytes_by_kind_total",
                              "bytes sent per payload kind",
                              ("node", "kind"))
        cls_b = self.counter("repro_net_bytes_by_class_total",
                             "bytes sent per link class",
                             ("node", "link_class"))
        cost = self.counter("repro_net_link_cost_total",
                            "bytes × link byte-cost (WAN egress billing)",
                            ("node",))

        def collect() -> None:
            for name, (attr, _) in c.items():
                fams[name].labels(node).set_total(getattr(stats, attr))
            for k, v in stats.by_kind.items():
                kind_n.labels(node, k).set_total(v)
            for k, v in stats.bytes_by_kind.items():
                kind_b.labels(node, k).set_total(v)
            for k, v in stats.bytes_by_class.items():
                cls_b.labels(node, k).set_total(v)
            cost.labels(node).set_total(stats.link_cost)

        self.add_collector(collect)

    def absorb_link_stats(self, stats: Any, *, node: str = "",
                          clock: Optional[Callable[[], float]] = None
                          ) -> None:
        """:meth:`absorb_net_stats` plus the socket-only columns of
        :class:`~repro.net.stats.LinkStats` (receive mirror, datagram and
        stream channel counters, queue drops) and the derived per-link
        byte-*rate* gauges: with a ``clock``, ``repro_net_bytes_sent_per_second``
        (and per link class) over the window since the previous scrape —
        the liveness signal the obs-smoke CI job asserts is finite."""
        self.absorb_net_stats(stats, node=node)
        c = {
            "repro_net_bytes_recv_total": ("bytes_recv", "bytes received"),
            "repro_net_datagrams_sent_total": ("datagrams_sent",
                                               "UDP datagrams sent"),
            "repro_net_datagrams_recv_total": ("datagrams_recv",
                                               "UDP datagrams received"),
            "repro_net_chunks_sent_total": ("chunks_sent",
                                            "oversized-frame shards sent"),
            "repro_net_reassembly_drops_total": (
                "reassembly_drops", "partial oversized frames evicted"),
            "repro_net_resyncs_total": ("resyncs",
                                        "stream resyncs after corruption"),
            "repro_net_reconnects_total": ("reconnects",
                                           "TCP dial retries after a drop"),
            "repro_net_queue_drops_total": (
                "queue_drops", "frames shed by bounded send queues"),
        }
        fams = {name: self.counter(name, help, ("node",))
                for name, (_, help) in c.items()}
        rkind_b = self.counter("repro_net_recv_bytes_by_kind_total",
                               "bytes received per payload kind",
                               ("node", "kind"))
        rcls_b = self.counter("repro_net_recv_bytes_by_class_total",
                              "bytes received per link class",
                              ("node", "link_class"))
        rate = self.gauge("repro_net_bytes_sent_per_second",
                          "send byte rate over the last scrape window",
                          ("node",))
        rate_cls = self.gauge("repro_net_bytes_by_class_per_second",
                              "per-link-class send byte rate over the "
                              "last scrape window",
                              ("node", "link_class"))
        window = {"t": None, "bytes": 0, "by_class": {}}

        def collect() -> None:
            for name, (attr, _) in c.items():
                fams[name].labels(node).set_total(getattr(stats, attr))
            for k, v in stats.recv_bytes_by_kind.items():
                rkind_b.labels(node, k).set_total(v)
            for k, v in stats.recv_bytes_by_class.items():
                rcls_b.labels(node, k).set_total(v)
            if clock is None:
                return
            now = clock()
            if window["t"] is not None:
                dt = now - window["t"]
                if dt > 0:
                    rate.labels(node).set(
                        (stats.bytes_sent - window["bytes"]) / dt)
                    for k, v in stats.bytes_by_class.items():
                        prev = window["by_class"].get(k, 0)
                        rate_cls.labels(node, k).set((v - prev) / dt)
            else:
                # first scrape: rates are defined (0.0), just windowless
                rate.labels(node).set(0.0)
                for k in stats.bytes_by_class:
                    rate_cls.labels(node, k).set(0.0)
            window["t"] = now
            window["bytes"] = stats.bytes_sent
            window["by_class"] = dict(stats.bytes_by_class)

        self.add_collector(collect)

    def absorb_kernel_counters(self, kc: Optional[Any] = None, *,
                               node: str = "") -> None:
        """Publish :class:`~repro.kernels.ops.KernelCounters` (default:
        the process-wide instance) as ``repro_kernel_*`` counters."""
        if kc is None:
            from ..kernels import ops
            kc = ops.counters
        launches = self.counter("repro_kernel_launches_total",
                                "kernel wrapper dispatches", ("node",))
        h2d = self.counter("repro_kernel_h2d_bytes_total",
                           "bytes staged host→device", ("node",))
        d2h = self.counter("repro_kernel_d2h_bytes_total",
                           "bytes fetched device→host", ("node",))

        def collect() -> None:
            launches.labels(node).set_total(kc.launches)
            h2d.labels(node).set_total(kc.h2d_bytes)
            d2h.labels(node).set_total(kc.d2h_bytes)

        self.add_collector(collect)

    def absorb_crdt_metrics(self, metrics: "Metrics", *,
                            node: str = "") -> None:
        """Publish a replicated :class:`Metrics` recorder's cluster-wide
        aggregates (exact once every reporter's latest record has
        gossiped in) as gauges labelled by metric name."""
        count = self.gauge("repro_crdt_metric_count",
                           "replicated sample count per metric",
                           ("node", "metric"))
        total = self.gauge("repro_crdt_metric_sum",
                           "replicated sample sum per metric",
                           ("node", "metric"))

        def collect() -> None:
            for m, _ in metrics.state.entries:
                count.labels(node, m).set(metrics.state.count(m))
                total.labels(node, m).set(metrics.state.total(m))

        self.add_collector(collect)


_GLOBAL = Registry()


def global_registry() -> Registry:
    """The process-wide registry — what ``benchmarks/run.py --json``
    snapshots per suite and in-process probes default to."""
    return _GLOBAL


def reset_global_registry() -> Registry:
    """Replace the process-wide registry with a fresh one (tests and
    per-suite bench isolation; the old instance keeps working for anyone
    still holding it)."""
    global _GLOBAL
    _GLOBAL = Registry()
    return _GLOBAL


# ---------------------------------------------------------------------------
# Replicated δ-CRDT metrics (moved verbatim in semantics from sync/metrics.py;
# that module now re-exports these names)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MetricRecord:
    """Per-replica monotone ``(n, sum, min, max)`` sample record —
    versioned by its own sample count so joins keep the freshest record
    per reporter (idempotent, commutative; §4.2's counter argument)."""

    n: int = 0
    total: float = 0.0
    min_v: float = float("inf")
    max_v: float = float("-inf")

    def observe(self, value: float, weight: int = 1) -> "MetricRecord":
        return MetricRecord(self.n + weight, self.total + value,
                            min(self.min_v, value), max(self.max_v, value))

    def join(self, other: "MetricRecord") -> "MetricRecord":
        # per-replica records are monotone in n: larger n subsumes
        return self if self.n >= other.n else other


@dataclass(frozen=True)
class MetricsState(DeltaCRDT):
    """metric name → replica → MetricRecord."""

    entries: Tuple[Tuple[str, Tuple[Tuple[ReplicaId, MetricRecord], ...]], ...] = ()

    @staticmethod
    def bottom() -> "MetricsState":
        return MetricsState()

    def _as_dict(self) -> Dict[str, Dict[ReplicaId, MetricRecord]]:
        return {m: dict(rs) for m, rs in self.entries}

    @staticmethod
    def _freeze(d: Dict[str, Dict[ReplicaId, MetricRecord]]) -> "MetricsState":
        return MetricsState(tuple(sorted(
            (m, tuple(sorted(rs.items()))) for m, rs in d.items())))

    def observe_delta(self, i: ReplicaId, metric: str, value: float,
                      weight: int = 1) -> "MetricsState":
        cur = self._as_dict().get(metric, {}).get(i, MetricRecord())
        return MetricsState._freeze({metric: {i: cur.observe(value, weight)}})

    def observe_full(self, i: ReplicaId, metric: str, value: float,
                     weight: int = 1) -> "MetricsState":
        return self.join(self.observe_delta(i, metric, value, weight))

    def join(self, other: "MetricsState") -> "MetricsState":
        a = self._as_dict()
        for m, rs in other._as_dict().items():
            mine = a.setdefault(m, {})
            for r, rec in rs.items():
                mine[r] = mine[r].join(rec) if r in mine else rec
        return MetricsState._freeze(a)

    # -- aggregates -----------------------------------------------------------
    def count(self, metric: str) -> int:
        return sum(rec.n for rec in self._as_dict().get(metric, {}).values())

    def total(self, metric: str) -> float:
        return sum(rec.total for rec in self._as_dict().get(metric, {}).values())

    def mean(self, metric: str) -> float:
        n = self.count(metric)
        return self.total(metric) / n if n else float("nan")

    def minimum(self, metric: str) -> float:
        vals = [rec.min_v for rec in self._as_dict().get(metric, {}).values()]
        return min(vals) if vals else float("inf")

    def maximum(self, metric: str) -> float:
        vals = [rec.max_v for rec in self._as_dict().get(metric, {}).values()]
        return max(vals) if vals else float("-inf")


class Metrics:
    """Convenience recorder for one replica."""

    def __init__(self, replica: ReplicaId):
        self.replica = replica
        self.state = MetricsState.bottom()

    def observe(self, metric: str, value: float, weight: int = 1) -> MetricsState:
        delta = self.state.observe_delta(self.replica, metric, value, weight)
        self.state = self.state.join(delta)
        return delta

    def merge(self, remote: MetricsState) -> None:
        self.state = self.state.join(remote)
