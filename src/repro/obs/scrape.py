"""Live scrape surface: a sidecar HTTP endpoint over one registry.

A gossip process is only debuggable mid-run if its counters are
reachable *while it is stuck* — after the fact, a wedged quorum and a
partitioned link look identical. :class:`MetricsServer` is a deliberately
tiny asyncio HTTP/1.0 responder (stdlib only, runs on the same event
loop as the gossip tasks, binds an ephemeral loopback sidecar port by
default) serving two read-only views of a :class:`~repro.obs.registry.Registry`:

* ``GET /metrics``       — Prometheus text exposition (``text/plain``)
* ``GET /metrics.json``  — the JSON snapshot (``application/json``)

``GossipNode.serve_metrics()`` wires a node's :class:`LinkStats`,
replica probes, and kernel counters into a registry and serves it;
``serve.py --metrics`` does the same per process and advertises the
sidecar address in its status-file heartbeat, so the 3-process
``bench_net`` cluster is scrapeable by pid, port, or status file.

:func:`scrape` / :func:`scrape_json` are the matching clients (stdlib
``http.client``) used by tests and the ``obs-smoke`` CI job.
"""

from __future__ import annotations

import asyncio
import http.client
import json
from typing import Any, Dict, Optional, Tuple

from .registry import Registry

_CONTENT_TYPES = {
    "/metrics": "text/plain; version=0.0.4; charset=utf-8",
    "/metrics.json": "application/json; charset=utf-8",
}


class MetricsServer:
    """Serve one registry's scrape views on a loopback sidecar port."""

    def __init__(self, registry: Registry, *, host: str = "127.0.0.1",
                 port: int = 0):
        self.registry = registry
        self.host = host
        self.port = port
        self.addr: Optional[str] = None
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> str:
        """Bind and serve; returns the resolved ``host:port``."""
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        host, port = self._server.sockets[0].getsockname()[:2]
        self.addr = f"{host}:{port}"
        return self.addr

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    def _respond(self, path: str) -> Tuple[int, str, str]:
        if path in ("/metrics", "/"):
            return 200, _CONTENT_TYPES["/metrics"], \
                self.registry.render_prometheus()
        if path in ("/metrics.json", "/json"):
            return 200, _CONTENT_TYPES["/metrics.json"], \
                self.registry.render_json()
        return 404, "text/plain; charset=utf-8", "not found\n"

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            line = await asyncio.wait_for(reader.readline(), timeout=5.0)
            parts = line.decode("latin-1", "replace").split()
            path = parts[1].split("?", 1)[0] if len(parts) >= 2 else "/"
            # drain request headers (clients send them; we need none)
            while True:
                h = await asyncio.wait_for(reader.readline(), timeout=5.0)
                if h in (b"\r\n", b"\n", b""):
                    break
            status, ctype, body = self._respond(path)
            reason = {200: "OK", 404: "Not Found"}[status]
            payload = body.encode("utf-8")
            writer.write(
                f"HTTP/1.0 {status} {reason}\r\n"
                f"Content-Type: {ctype}\r\n"
                f"Content-Length: {len(payload)}\r\n"
                f"Connection: close\r\n\r\n".encode("latin-1"))
            writer.write(payload)
            await writer.drain()
        except (asyncio.TimeoutError, ConnectionError):
            pass
        finally:
            writer.close()


def scrape(addr: str, path: str = "/metrics", *,
           timeout: float = 5.0) -> str:
    """Fetch one scrape view from ``host:port`` (raises on non-200)."""
    host, port = addr.rsplit(":", 1)
    conn = http.client.HTTPConnection(host, int(port), timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        body = resp.read().decode("utf-8")
        if resp.status != 200:
            raise RuntimeError(f"scrape {addr}{path}: HTTP {resp.status}")
        return body
    finally:
        conn.close()


def scrape_json(addr: str, *, timeout: float = 5.0) -> Dict[str, Any]:
    return json.loads(scrape(addr, "/metrics.json", timeout=timeout))


def parse_prometheus(text: str) -> Dict[str, Dict[str, float]]:
    """Parse a Prometheus text exposition into
    ``{metric_name: {label_string: value}}`` (label_string "" for
    label-less samples) — enough for assertions; not a full parser."""
    out: Dict[str, Dict[str, float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_labels, _, value = line.rpartition(" ")
        if "{" in name_labels:
            name, _, rest = name_labels.partition("{")
            labels = rest.rstrip("}")
        else:
            name, labels = name_labels, ""
        try:
            v = float(value)
        except ValueError:
            v = float("nan")
        out.setdefault(name, {})[labels] = v
    return out
