"""Structured trace bus: typed, timestamped events from every layer.

The paper's claims are *trajectory* claims — a δ-mutator's state reaches
every replica through some sequence of ships, joins, acks, and digest
exchanges — but until now that trajectory was only visible as aggregate
counters. The :class:`Tracer` records it as a stream of typed events:

====================  ========================================================
kind                  emitted when
====================  ========================================================
``write``             a local δ-mutation entered the delta buffer
                      (``Replica.operation``; fields: ``keys``, ``tag``)
``delta_ship``        a delta/state payload left for ``dst``
                      (fields: ``dst``, ``bytes``, ``full``, ``keys``,
                      causal ``tag``)
``delta_join``        a received payload was folded in (fields: ``src``,
                      ``via`` ∈ delta/handoff/digest-resp, ``bytes``,
                      ``keys`` = the keys that actually *changed state*;
                      empty ⇒ the payload was redundant)
``ack``               a cumulative ack arrived back at the sender
                      (fields: ``src``, ``tag``, ``stale``)
``digest_req``        a pull-round digest request shipped (``dst``, ``bytes``)
``digest_resp``       a digest response shipped (``dst``, ``bytes``)
``handoff``           a rebalance handoff shipped (``dst``, ``bytes``,
                      ``keys``)
``reap_propose``      the reaper proposed a tombstone to one member
``reap_ack``          a reap vote arrived (``src``, ``key``, ``ok``)
``reap_commit``       a fully-acked tombstone committed (``key``, ``epoch``)
``gc_horizon_advance``  delta-buffer entries left the buffer
                      (``horizon``, ``dropped``, ``depth``)
``queue_drop``        a bounded per-peer send queue shed old frames
                      (``dst``, ``dropped``)
``kernel_launch``     a kernel wrapper dispatched (``op``, ``h2d_bytes``;
                      via :func:`trace_kernel_launches`)
====================  ========================================================

Every event also carries ``t`` (the tracer's clock), ``seq`` (a per-tracer
monotone index — total order of this node's events even under clock
ties), ``node``, and — for engine events — ``round`` (the replica's
anti-entropy round counter, the *logical* clock that makes a simulator
trace and a socket trace of the same schedule comparable).

**Deterministic-clock mode.** The tracer never calls ``time`` itself:
``clock`` is injected. Attach ``clock=lambda: sim.time`` and a simulated
run's trace is bit-reproducible; a socket run uses ``time.monotonic``.
Cross-run comparison never relies on absolute times — the analyzer's
semantic view (``repro.obs.analyze.semantic_trace``) orders by per-node
``seq``/``round``, which both clocks agree on.

**Cost model.** A disabled tracer is one ``is None`` test per site. An
enabled tracer at the default ``sample=1.0`` builds one small dict per
event into a bounded ring buffer (``deque(maxlen=capacity)``) —
``bench_obs`` asserts the UDP load generator's throughput stays within
10% of the untraced run. ``sample < 1.0`` keeps a random fraction
(seeded — reproducible), trading analyzer completeness for overhead:
anomaly detection (``analyze.anomalies``) needs the full stream, so run
it at 1.0.

The JSONL sink mirrors every kept event to a file as it is emitted, one
JSON object per line — the interchange format ``analyze.load_trace``
reads back.
"""

from __future__ import annotations

import json
import random
from collections import deque
from typing import Any, Callable, Dict, List, Optional

EVENT_KINDS = frozenset({
    "write", "delta_ship", "delta_join", "ack",
    "digest_req", "digest_resp", "handoff",
    "reap_propose", "reap_ack", "reap_commit",
    "gc_horizon_advance", "queue_drop", "kernel_launch",
})


class Tracer:
    """Bounded, sampled, optionally file-backed event recorder.

    One tracer per traced node (its ``node`` tag names the emitter);
    assign it to ``Replica.tracer`` / pass it to ``GossipNode`` and the
    instrumented layers feed it. ``clock`` is injected for determinism
    (see module docstring); ``sink`` is a path or open text file that
    receives each event as a JSON line.
    """

    __slots__ = ("node", "clock", "sample", "_rng", "_buf", "_sink",
                 "_owns_sink", "_seq", "dropped")

    def __init__(self, node: str = "", *,
                 clock: Optional[Callable[[], float]] = None,
                 capacity: int = 65536,
                 sink: Any = None,
                 sample: float = 1.0,
                 seed: int = 0):
        if not 0.0 < sample <= 1.0:
            raise ValueError(f"sample must be in (0, 1], got {sample!r}")
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity!r}")
        self.node = node
        if clock is None:
            import time
            clock = time.monotonic
        self.clock = clock
        self.sample = sample
        self._rng = random.Random(seed)
        self._buf: deque = deque(maxlen=capacity)
        self._owns_sink = isinstance(sink, (str, bytes))
        self._sink = open(sink, "w") if self._owns_sink else sink
        self._seq = 0
        self.dropped = 0     # events sampled out (not ring-buffer evictions)

    # -- emit -----------------------------------------------------------------
    def emit(self, kind: str, **fields: Any) -> None:
        """Record one event. Unknown kinds raise — the taxonomy is the
        contract the analyzer parses, so a typo'd kind must fail loudly
        at the emit site, not silently skew a report."""
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind {kind!r}")
        if self.sample < 1.0 and self._rng.random() >= self.sample:
            self.dropped += 1
            return
        ev: Dict[str, Any] = {"t": self.clock(), "seq": self._seq,
                              "node": self.node, "kind": kind}
        ev.update(fields)
        self._seq += 1
        self._buf.append(ev)
        if self._sink is not None:
            self._sink.write(json.dumps(ev, separators=(",", ":")))
            self._sink.write("\n")

    # -- read back -------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """The retained events, oldest first (the ring buffer keeps the
        newest ``capacity``)."""
        return list(self._buf)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self._buf:
            out[ev["kind"]] = out.get(ev["kind"], 0) + 1
        return out

    def clear(self) -> None:
        self._buf.clear()

    def flush(self) -> None:
        if self._sink is not None:
            self._sink.flush()

    def close(self) -> None:
        if self._sink is not None:
            self._sink.flush()
            if self._owns_sink:
                self._sink.close()
            self._sink = None

    def __enter__(self) -> "Tracer":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def merge_events(*sources: Any) -> List[Dict[str, Any]]:
    """Combine per-node traces (tracers or event lists) into one stream
    ordered by ``(t, node, seq)`` — what the analyzer consumes for a
    whole-cluster view. Per-node ``seq`` order is preserved even when
    clocks tie (a simulator applies a whole schedule at t=0)."""
    events: List[Dict[str, Any]] = []
    for s in sources:
        events.extend(s.events() if hasattr(s, "events") else s)
    return sorted(events, key=lambda e: (e.get("t", 0.0),
                                         e.get("node", ""),
                                         e.get("seq", 0)))


def trace_kernel_launches(tracer: Tracer) -> Callable[[], None]:
    """Install ``tracer`` as the process-wide kernel-launch hook: every
    ``kernels.ops`` wrapper dispatch emits a ``kernel_launch`` event
    (op name + host→device bytes staged). Returns an uninstall callable
    — the hook is global (the counters it mirrors are process-wide), so
    callers must remove it when their scope ends."""
    from ..kernels import ops

    def hook(op: str, h2d_bytes: int) -> None:
        tracer.emit("kernel_launch", op=op, h2d_bytes=h2d_bytes)

    ops.set_launch_hook(hook)
    return lambda: ops.set_launch_hook(None)
