"""Optimizer substrate: AdamW with fp32 master weights, global-norm
clipping, warmup+cosine schedule. Pure pytree functions — sharding comes
from the distribution layer's PartitionSpecs (optimizer state mirrors the
parameter sharding, ZeRO-style)."""

from .adamw import (AdamWConfig, adamw_update, init_opt_state,
                    lr_at_step, opt_state_pspecs)

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "lr_at_step",
           "opt_state_pspecs"]
