"""AdamW (decoupled weight decay) with mixed-precision discipline.

* params may be bf16; the optimizer keeps an fp32 master copy and fp32
  moments (12 bytes/param — the figure the roofline memory rows assume);
* gradients are cast to fp32 before moment updates;
* global-norm clipping in fp32;
* linear warmup → cosine decay schedule evaluated inside jit (step is a
  traced scalar, so one compiled train_step serves all steps).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at_step(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * \
        0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    f32 = lambda x: x.astype(jnp.float32)
    return {
        "m": jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                    params),
        "v": jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                    params),
        "master": jax.tree_util.tree_map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_pspecs(param_pspecs: Any) -> Dict[str, Any]:
    """Optimizer state shards exactly like the parameters (ZeRO)."""
    from jax.sharding import PartitionSpec as P
    return {"m": param_pspecs, "v": param_pspecs, "master": param_pspecs,
            "step": P()}


def _global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: AdamWConfig) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at_step(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p_dtype_leaf, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                                    + cfg.weight_decay * master)
        return new_master.astype(p_dtype_leaf.dtype), m, v, new_master

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    outs = [upd(p, g, m, v, ma) for p, g, m, v, ma
            in zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]),
        "v": jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs]),
        "master": jax.tree_util.tree_unflatten(treedef, [o[3] for o in outs]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
