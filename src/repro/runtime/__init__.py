"""Step functions: training (loss→grad→AdamW) and serving (prefill/decode)."""

from .steps import TrainConfig, make_decode_fn, make_prefill_fn, make_train_step

__all__ = ["TrainConfig", "make_decode_fn", "make_prefill_fn",
           "make_train_step"]
