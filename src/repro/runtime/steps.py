"""Train / serve step factories.

``make_train_step``: loss (remat'd scan-over-layers) → grads → global-norm
clip → AdamW with fp32 master. Optional microbatch gradient accumulation
(``lax.scan`` over microbatches — activation memory ÷ n_micro at the cost
of serializing the per-microbatch collectives; a §Perf knob).

The factories close over the ModelConfig only; params/opt-state/batch come
in as arguments, so one jitted step serves the whole run and the dry-run
can lower it with ShapeDtypeStructs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import decode_step as model_decode
from ..models import prefill as model_prefill
from ..models import train_loss
from ..optim import AdamWConfig, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    remat: bool = True


def make_train_step(cfg, tcfg: TrainConfig = TrainConfig()
                    ) -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return train_loss(cfg, params, batch, remat=tcfg.remat)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            n = tcfg.microbatches

            def split(x):
                b = x.shape[0]
                assert b % n == 0
                return x.reshape((n, b // n) + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc_fn(carry, mb):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32) / n, g_acc, g)
                return (loss_acc + loss / n, g_acc), None

            (loss, grads), _ = jax.lax.scan(acc_fn, (0.0, zero), micro)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             tcfg.optimizer)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_fn(cfg, max_len: int) -> Callable:
    """(params, batch) → (next-token logits, caches)."""

    def prefill_fn(params, batch):
        return model_prefill(cfg, params, batch, max_len=max_len)

    return prefill_fn


def make_decode_fn(cfg) -> Callable:
    """(params, tokens, pos, caches) → (logits, caches). This is
    ``serve_step`` for the decode_* / long_* dry-run cells."""

    def decode_fn(params, tokens, pos, caches):
        return model_decode(cfg, params, tokens, pos, caches)

    return decode_fn
