"""Cross-pod δ-CRDT synchronization runtime.

Tier-1 of the framework's two-tier distribution story (DESIGN.md §2):
inside a pod, synchronous SPMD collectives; across pods — where links are
slow, lossy, partition-prone and membership is elastic — replication is
δ-CRDT anti-entropy:

* ``localsgd``      — DiLoCo-style cross-pod training: pods run K local
                      steps, contribute uniquely-dotted pseudo-gradient
                      deltas to a ``DotSumStore`` lattice, gossiped with
                      Algorithm 2; the §7.2-compressed ``IntervalSum``
                      variant keeps O(1) memory.
* ``compression``   — top-k magnitude sparsification with error feedback
                      (the delta payloads for dense models).
* ``membership``    — elastic worker membership: AWORSet of workers +
                      monotone heartbeats; straggler detection/eviction;
                      ``ClusterReplica`` gossips the view through the
                      unified propagation runtime (pluggable policies);
                      ``KeyOwnership``/``ShardByKey`` rendezvous-hash the
                      keyed-store keyspace over the live worker set so
                      each replica buffers/ships only its shard.
* ``metrics``       — duplicate-safe distributed metrics (per-replica
                      monotone entries; PN counters).
"""

from .compression import (TopKCompressor, sparse_nbytes, topk_frame,
                          topk_unframe)
from .localsgd import DeltaSyncPod, OuterParams
from .membership import (ClusterReplica, ClusterState, KeyOwnership,
                         Membership, RebalanceHandoff, ShardByKey,
                         owners_for_key, rendezvous_score)
from .metrics import Metrics, MetricsState

__all__ = [
    "TopKCompressor", "sparse_nbytes", "topk_frame", "topk_unframe",
    "DeltaSyncPod", "OuterParams",
    "ClusterReplica", "ClusterState", "KeyOwnership", "Membership",
    "RebalanceHandoff", "ShardByKey", "owners_for_key",
    "rendezvous_score", "Metrics",
    "MetricsState",
]
