"""Gradient/delta compression for cross-pod shipping.

Dense models touch every parameter every step, so chunk-version deltas
degenerate to full state per round (DESIGN.md §4). The practical payload
reducer is magnitude top-k sparsification with **error feedback**: the
un-shipped residual is accumulated locally and added to the next round's
delta, so the compression error is a delay, not a loss — exactly the
delta-friendly shape: each shipped sparse update is a uniquely-dotted
contribution to the ``DotSumStore`` lattice, still idempotent under
re-delivery.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _topk_sparsify(x: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Indices and values of the k largest-|·| entries of flattened x."""
    flat = x.reshape(-1)
    k = max(1, min(int(k), flat.shape[0]))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return idx, flat[idx]


_topk_sparsify_jit = jax.jit(_topk_sparsify, static_argnums=1)


class TopKCompressor:
    """Per-leaf top-k with error feedback.

    ``compress`` returns a sparse pytree-of-(idx, vals, shape) and keeps the
    residual; ``decompress`` densifies. Rate is the kept fraction.
    """

    def __init__(self, rate: float = 0.01):
        assert 0.0 < rate <= 1.0
        self.rate = rate
        self.residual: Optional[Any] = None

    def compress(self, update: Any) -> Any:
        if self.residual is None:
            self.residual = jax.tree_util.tree_map(jnp.zeros_like, update)
        carried = jax.tree_util.tree_map(lambda u, r: u + r,
                                         update, self.residual)

        def one(x):
            n = int(np.prod(x.shape))
            k = max(1, int(round(self.rate * n)))
            idx, vals = _topk_sparsify_jit(x, k)
            return {"idx": idx, "vals": vals, "shape": x.shape}

        sparse = jax.tree_util.tree_map(one, carried)

        def leftover(x, s):
            flat = x.reshape(-1)
            return flat.at[s["idx"]].set(0.0).reshape(x.shape)

        self.residual = jax.tree_util.tree_map(
            leftover, carried, sparse,
            is_leaf=lambda t: isinstance(t, jnp.ndarray))
        return sparse

    @staticmethod
    def decompress(sparse: Any) -> Any:
        def one(s):
            flat = jnp.zeros(int(np.prod(s["shape"])),
                             dtype=s["vals"].dtype)
            return flat.at[s["idx"]].set(s["vals"]).reshape(s["shape"])

        return jax.tree_util.tree_map(
            one, sparse, is_leaf=lambda t: isinstance(t, dict) and "idx" in t)


def topk_frame(sparse: Any) -> bytes:
    """Encode a :meth:`TopKCompressor.compress` result as one ``topk``
    wire frame (raw index/value columns + a tiny pickled treedef — see
    ``repro.wire.codec.encode_topk``). ``len(frame)`` is the measured
    wire size the benchmarks report — the estimate
    :func:`sparse_nbytes` kept missing framing, dtype, and shape
    overhead."""
    from ..wire import encode_frame, encode_topk

    return encode_frame("topk", encode_topk(sparse))


def topk_unframe(frame) -> Any:
    """Decode a ``topk`` frame back to the sparse pytree
    (:meth:`TopKCompressor.decompress`-ready)."""
    from ..wire import FrameError, decode_frame, decode_topk

    kind, payload = decode_frame(frame)
    if kind != "topk":
        raise FrameError(f"expected a topk frame, got {kind!r}")
    return decode_topk(payload)


def sparse_nbytes(sparse: Any) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            sparse, is_leaf=lambda t: isinstance(t, dict) and "idx" in t):
        total += int(leaf["idx"].size) * 4 + int(leaf["vals"].size) * \
            leaf["vals"].dtype.itemsize
    return total


def dense_nbytes(tree: Any) -> int:
    return sum(int(x.size) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))
