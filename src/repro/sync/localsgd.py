"""Cross-pod delta-synchronized training (local SGD / DiLoCo shape).

Each pod trains K local steps per *round*, then contributes the round's
pseudo-gradient (scaled parameter displacement) as a **uniquely-dotted
delta** to the additive ``DotSumStore`` lattice. Rounds gossip between pods
with the paper's Algorithm 2 (delta-intervals + acks) over an unreliable
network; every pod's *outer parameters* are the deterministic function

    outer = init + Σ_{dots (pod, round)} update / P

of the converged lattice, so (Prop. 1) all pods agree once all dots are
delivered — regardless of loss, duplication, or reordering, and without any
exactly-once machinery. Optionally payloads are top-k+error-feedback
compressed (``TopKCompressor``); the dot then carries the sparse update.

``DeltaSyncPod`` runs on the unified propagation runtime
(``repro.core.propagation.Replica`` in causal mode): the CRDT state IS the
dot store, and the ``policy=`` knob selects what each gossip round ships —
``ShipAll`` (default), ``AvoidBackPropagation`` / ``RemoveRedundant`` (or
their ``Compose``) to cut redundant bytes on dense topologies. The
§7.2-compressed execution (``IntervalSum`` — O(1) memory instead of the
full dot cloud) is property-tested equivalent in
tests/test_tensor_lattice.py and used by the example driver for large
models.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..core.propagation import Replica, ShippingPolicy
from ..core.tensor_lattice import DotSumStore, IntervalSum
from .compression import TopKCompressor


@dataclass
class OuterParams:
    """init + scale · Σ dots — materializer for the outer parameters."""

    init: Any
    scale: float

    def materialize(self, store: DotSumStore,
                    decompress: Optional[Callable[[Any], Any]] = None) -> Any:
        total = store.total()
        if total is None:
            return self.init
        if decompress is not None:
            total = decompress(total)
        return jax.tree_util.tree_map(
            lambda p, t: p + self.scale * t.astype(p.dtype), self.init, total)

    def materialize_sum(self, running_sum: Any) -> Any:
        if running_sum is None:
            return self.init
        return jax.tree_util.tree_map(
            lambda p, t: p + self.scale * t.astype(p.dtype),
            self.init, running_sum)


class DeltaSyncPod(Replica):
    """A pod replica: local training + δ-CRDT gossip of round updates.

    ``local_update_fn(params, round_idx, pod_id) -> new_params`` is the
    K-local-steps inner loop (supplied by the example driver / tests).
    ``policy`` is any :class:`~repro.core.propagation.ShippingPolicy`
    (default ship-all, Algorithm 2 semantics preserved).
    """

    def __init__(self, pod_id: str, neighbors, init_params: Any,
                 local_update_fn: Callable[[Any, int, str], Any],
                 num_pods: int,
                 compressor: Optional[TopKCompressor] = None,
                 rng: Optional[random.Random] = None,
                 ghost_check: bool = False,
                 policy: Optional[ShippingPolicy] = None):
        super().__init__(pod_id, DotSumStore.bottom(), neighbors,
                         causal=True, policy=policy, rng=rng,
                         ghost_check=ghost_check, fanout=1)
        self.outer = OuterParams(init=init_params, scale=1.0 / num_pods)
        self.local_update_fn = local_update_fn
        self.compressor = compressor
        self.round_idx = 0

    # -- current view -----------------------------------------------------------
    def params(self) -> Any:
        decompress = (TopKCompressor.decompress
                      if self.compressor is not None else None)
        if self.compressor is not None:
            # dots carry sparse updates: decompress each then sum
            total = None
            for _, upd in self.X.dots:
                dense = TopKCompressor.decompress(upd)
                total = dense if total is None else jax.tree_util.tree_map(
                    lambda a, b: a + b, total, dense)
            return self.outer.materialize_sum(total)
        return self.outer.materialize(self.X)

    # -- one training round ------------------------------------------------------
    def do_round(self) -> None:
        base = self.params()
        new_params = self.local_update_fn(base, self.round_idx, self.id)
        delta = jax.tree_util.tree_map(lambda n, b: n - b, new_params, base)
        payload = (self.compressor.compress(delta)
                   if self.compressor is not None else delta)
        self.operation(lambda X: X.contribute_delta(self.id, payload))
        self.round_idx += 1


class CompressedAggregator:
    """Large-model execution of the same semantics: keep only the
    (version-vector, running-sum) per §7.2 instead of the dot cloud.

    Exactness relies on the causal delta-merging condition, enforced by
    ``IntervalSum.apply_interval`` (gap ⇒ reject, duplicate ⇒ no-op); it is
    exercised against the reference ``DotSumStore`` in tests.
    """

    def __init__(self, init_params: Any, num_pods: int):
        self.outer = OuterParams(init=init_params, scale=1.0 / num_pods)
        self.agg = IntervalSum()

    def apply(self, producer: str, start_seq: int, updates) -> bool:
        return self.agg.apply_interval(producer, start_seq, updates)

    def params(self) -> Any:
        return self.outer.materialize_sum(self.agg.sum)
