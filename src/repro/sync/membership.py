"""Elastic cluster membership + straggler mitigation, as δ-CRDTs.

The live-worker set is an **add-wins OR-Set** (paper §7): a pod that
rejoins after a partition wins over a stale eviction — exactly the add-wins
conflict policy wanted for elasticity. Heartbeats are per-worker monotone
LWW entries. Both pieces form a product lattice, so the whole cluster view
gossips through the same anti-entropy machinery as everything else, over
lossy links, with no coordinator.

Straggler policy: a worker whose heartbeat lags ``timeout`` behind the
observer's clock is a straggler; after ``evict_after`` it is removed from
the membership set (an observed-remove — concurrent rejoin wins). The
local-SGD layer simply stops waiting for contributions from workers outside
the live set (bounded-staleness barrier), which is the δ-CRDT version of
backup-worker straggler mitigation: progress never blocks on a slow pod,
and a late pod's dots still merge idempotently when they eventually arrive.
"""

from __future__ import annotations

import hashlib
import random
from dataclasses import dataclass, field
from typing import (Callable, Dict, FrozenSet, Iterable, Optional, Sequence,
                    Tuple, Union)

from ..core.crdts import AWORSet, DeltaCRDT, LWWSet
from ..core.dots import ReplicaId
from ..core.propagation import Replica, ShippingPolicy
from ..core.store import LatticeStore
from ..topology import Topology


@dataclass(frozen=True)
class HeartbeatMap(DeltaCRDT):
    """worker → monotone max timestamp (grow-only pointwise-max map)."""

    entries: Tuple[Tuple[ReplicaId, float], ...] = ()

    @staticmethod
    def bottom() -> "HeartbeatMap":
        return HeartbeatMap()

    def beat_delta(self, worker: ReplicaId, ts: float) -> "HeartbeatMap":
        return HeartbeatMap(((worker, ts),))

    def beat_full(self, worker: ReplicaId, ts: float) -> "HeartbeatMap":
        return self.join(self.beat_delta(worker, ts))

    def last_seen(self, worker: ReplicaId) -> float:
        return dict(self.entries).get(worker, float("-inf"))

    def join(self, other: "HeartbeatMap") -> "HeartbeatMap":
        m = dict(self.entries)
        for w, ts in other.entries:
            m[w] = max(m.get(w, float("-inf")), ts)
        return HeartbeatMap(tuple(sorted(m.items())))


@dataclass(frozen=True)
class ClusterState(DeltaCRDT):
    """Product lattice: membership OR-Set × heartbeat map."""

    members: AWORSet = AWORSet()
    heartbeats: HeartbeatMap = HeartbeatMap()

    @staticmethod
    def bottom() -> "ClusterState":
        return ClusterState()

    def join(self, other: "ClusterState") -> "ClusterState":
        return ClusterState(self.members.join(other.members),
                            self.heartbeats.join(other.heartbeats))

    # -- delta-mutators --------------------------------------------------------
    def join_delta(self, i: ReplicaId, worker: ReplicaId,
                   ts: float) -> "ClusterState":
        return ClusterState(self.members.add_delta(i, worker),
                            self.heartbeats.beat_delta(worker, ts))

    def leave_delta(self, i: ReplicaId, worker: ReplicaId) -> "ClusterState":
        return ClusterState(self.members.rmv_delta(i, worker),
                            HeartbeatMap.bottom())

    def beat_delta(self, worker: ReplicaId, ts: float) -> "ClusterState":
        return ClusterState(AWORSet.bottom(),
                            self.heartbeats.beat_delta(worker, ts))

    # -- queries -------------------------------------------------------------
    def workers(self) -> FrozenSet[ReplicaId]:
        return self.members.elements()

    def alive(self, now: float, timeout: float) -> FrozenSet[ReplicaId]:
        return frozenset(w for w in self.workers()
                         if now - self.heartbeats.last_seen(w) <= timeout)

    def stragglers(self, now: float, timeout: float) -> FrozenSet[ReplicaId]:
        return self.workers() - self.alive(now, timeout)


class Membership:
    """Local membership agent for one pod: wraps delta-mutations so the
    surrounding anti-entropy node (Basic/Causal) can gossip them."""

    def __init__(self, self_id: ReplicaId, timeout: float = 30.0,
                 evict_after: float = 90.0):
        self.self_id = self_id
        self.timeout = timeout
        self.evict_after = evict_after

    def announce(self, state: ClusterState, now: float) -> ClusterState:
        return state.join_delta(self.self_id, self.self_id, now)

    def heartbeat(self, state: ClusterState, now: float) -> ClusterState:
        return state.beat_delta(self.self_id, now)

    def stale(self, state: ClusterState, now: float) -> FrozenSet[ReplicaId]:
        """Workers (other than self) silent for ≥ evict_after."""
        return frozenset(
            w for w in state.workers()
            if w != self.self_id
            and now - state.heartbeats.last_seen(w) >= self.evict_after)

    def evictions(self, state: ClusterState, now: float) -> ClusterState:
        """Delta that removes every worker silent for ≥ evict_after."""
        delta = ClusterState.bottom()
        for w in self.stale(state, now):
            delta = delta.join(state.leave_delta(self.self_id, w))
        return delta

    def quorum(self, state: ClusterState, now: float,
               fraction: float = 0.5) -> FrozenSet[ReplicaId]:
        """The bounded-staleness barrier set: contributions awaited only
        from currently-alive workers (straggler mitigation)."""
        alive = state.alive(now, self.timeout)
        need = max(1, int(len(state.workers()) * fraction))
        return alive if len(alive) >= need else frozenset()


# ---------------------------------------------------------------------------
# Hash-sharded key ownership (rendezvous hashing over the live worker set)
# ---------------------------------------------------------------------------

def rendezvous_score(worker: ReplicaId, key: str) -> int:
    """Deterministic, process-independent score of ``worker`` for ``key``
    (highest-random-weight / rendezvous hashing). blake2b, not ``hash()``:
    the builtin is salted per process, which would shard every replica
    differently."""
    h = hashlib.blake2b(f"{worker}\x00{key}".encode("utf-8"), digest_size=8)
    return int.from_bytes(h.digest(), "big")


def owners_for_key(key: str, workers: Iterable[ReplicaId],
                   replication: int = 1) -> Tuple[ReplicaId, ...]:
    """The ``replication`` highest-scoring live workers for ``key``.

    Rendezvous hashing gives minimal disruption under elasticity: a
    worker joining or leaving only moves the keys it scores highest on
    (expected 1/n of the keyspace) — every other key keeps its owners.
    """
    ranked = sorted(workers, key=lambda w: (-rendezvous_score(w, key), w))
    return tuple(ranked[:max(1, replication)])


class KeyOwnership:
    """Key → replica-set assignment over a (possibly live) worker set.

    ``workers`` is either a static iterable of worker ids or a callable
    returning the current set — pass e.g.
    ``lambda: cluster_replica.X.workers()`` so ownership re-shuffles as
    the gossiped membership OR-Set changes (join/leave), with rendezvous
    hashing keeping the re-shuffle minimal. ``replication`` is the number
    of replicas per key (the key's **write** replica set = its owners).

    The *read* set is wider: ``read_replication`` statically extends
    every key's readers to the next rendezvous-ranked workers, and
    :meth:`subscribe` dynamically adds a specific worker to a specific
    hot key's readers. Readers receive the key's gossip through
    digest-sync pull (``ShardByKey.restrict_pull`` routes by
    ``reads``), but stay out of the write set — they are not pushed to,
    never buffer/forward the key, and never gate its reap quorum.

    ``topology`` (a :class:`repro.topology.Topology`) turns on
    **zone-spreading**: whenever the cluster spans ≥ 2 zones and
    ``replication ≥ 2``, a key's write set is forced across ≥ 2 failure
    domains — the rendezvous prefix keeps its first ``replication - 1``
    slots, and if the whole prefix landed in one zone the *last* slot is
    swapped for the highest-ranked worker of any other zone. Read
    extension becomes zone-coverage-greedy: the extra
    ``read_replication`` slots first place a replica in each not-yet-
    covered zone (in rank order), then fill by rank — so every zone
    prefers a zone-local read replica. With one zone (or no topology)
    the ranking is *exactly* the flat rendezvous order, and reshuffle
    under join/leave stays minimal: a key's write set changes only when
    the changed worker sits in the rendezvous prefix or in the write set
    itself (the swap target is itself rank-maximal among its zone)."""

    _CACHE_MAX = 1 << 16    # bound the per-key memo (serving keyspaces
                            # are unbounded; rendezvous recompute is cheap)

    def __init__(self, workers: Union[Iterable[ReplicaId],
                                      Callable[[], Iterable[ReplicaId]]],
                 replication: int = 1,
                 read_replication: Optional[int] = None,
                 topology: Optional[Topology] = None):
        if replication < 1:
            raise ValueError(f"replication must be ≥ 1, got {replication}")
        if read_replication is not None and read_replication < replication:
            raise ValueError(
                f"read_replication must be ≥ replication "
                f"({replication}), got {read_replication}")
        self._workers = workers
        self.replication = replication
        self.read_replication = (replication if read_replication is None
                                 else read_replication)
        self.topology = topology
        # owners() sits on the gossip hot path (ShardByKey consults it per
        # key per destination per round): memoize the read-width ranking
        # per key (owners = its prefix), invalidated whenever the live
        # worker set changes
        self._cache_workers: Tuple[ReplicaId, ...] = ()
        self._cache: Dict[str, Tuple[ReplicaId, ...]] = {}
        # dynamic hot-key subscriptions: key → workers that asked to read
        self._subs: Dict[str, set] = {}

    def workers(self) -> Tuple[ReplicaId, ...]:
        ws = self._workers() if callable(self._workers) else self._workers
        return tuple(sorted(ws))

    def _rank_among(self, key: str,
                    ws: Tuple[ReplicaId, ...]) -> Tuple[ReplicaId, ...]:
        """The read-width, zone-aware ranking of ``ws`` for ``key`` —
        the write set is its ``replication`` prefix. Pure function of
        (key, worker snapshot), so :class:`RebalanceHandoff` can replay
        it against the *previous* worker set."""
        width = self.read_replication
        ranked = sorted(ws, key=lambda w: (-rendezvous_score(w, key), w))
        topo = self.topology
        if topo is None:
            return tuple(ranked[:width])
        zone = {w: topo.zone(w) for w in ranked}
        if len(set(zone.values())) < 2:
            return tuple(ranked[:width])   # one zone ⇒ exactly flat
        r = self.replication
        write = ranked[:r]
        if r >= 2 and len({zone[w] for w in write}) < 2:
            # single-zone prefix: the last slot yields to the highest-
            # ranked worker of any other zone (≥ 2 failure domains)
            swap = next(w for w in ranked[r:] if zone[w] != zone[write[0]])
            write = write[:-1] + [swap]
        out = list(write)
        covered = {zone[w] for w in out}
        rest = [w for w in ranked if w not in out]
        for w in rest:                     # zone-coverage-greedy readers
            if len(out) >= width:
                break
            if zone[w] not in covered:
                out.append(w)
                covered.add(zone[w])
        for w in rest:                     # then fill by rank
            if len(out) >= width:
                break
            if w not in out:
                out.append(w)
        return tuple(out)

    def _ranked(self, key: str) -> Tuple[ReplicaId, ...]:
        ws = self.workers()
        if ws != self._cache_workers:
            self._cache_workers = ws       # membership changed: re-shuffle
            self._cache = {}
        hit = self._cache.get(key)
        if hit is None:
            hit = self._rank_among(key, ws) if ws else ()
            if len(self._cache) >= self._CACHE_MAX:
                self._cache.clear()
            self._cache[key] = hit
        return hit

    def owners(self, key: str) -> Tuple[ReplicaId, ...]:
        return self._ranked(key)[:self.replication]

    def owners_among(self, key: str, workers: Iterable[ReplicaId]
                     ) -> Tuple[ReplicaId, ...]:
        """The write set ``key`` *would* have over an arbitrary worker
        snapshot — same zone-spread rule, no cache. Rebalance uses this
        to recover a key's owners under the previous membership."""
        ws = tuple(sorted(workers))
        if not ws:
            return ()
        return self._rank_among(key, ws)[:self.replication]

    def owner(self, key: str) -> Optional[ReplicaId]:
        """The primary (top-scoring) owner, or None with no workers."""
        owners = self.owners(key)
        return owners[0] if owners else None

    def replicates(self, worker: ReplicaId, key: str) -> bool:
        return worker in self.owners(key)

    # -- the wider read set ------------------------------------------------------
    def subscribe(self, worker: ReplicaId, key: str) -> None:
        """Add ``worker`` to ``key``'s readers (a hot key it wants to
        serve locally). Pull responses start routing the key to it on
        the next digest exchange; nothing else changes — no write-set
        membership, no reap-quorum seat."""
        self._subs.setdefault(key, set()).add(worker)

    def unsubscribe(self, worker: ReplicaId, key: str) -> None:
        subs = self._subs.get(key)
        if subs is not None:
            subs.discard(worker)
            if not subs:
                del self._subs[key]

    def readers(self, key: str) -> Tuple[ReplicaId, ...]:
        """The key's read set: the write owners, the statically wider
        ``read_replication`` rank prefix, and any live subscribers."""
        ranked = self._ranked(key)
        subs = self._subs.get(key)
        if not subs:
            return ranked
        live = set(self.workers())
        extra = sorted(w for w in subs if w in live and w not in ranked)
        return ranked + tuple(extra)

    def reads(self, worker: ReplicaId, key: str) -> bool:
        return worker in self.readers(key)

    # -- zone relays (hierarchical gossip aggregation) -----------------------------
    def relays(self) -> Dict[str, ReplicaId]:
        """zone → its elected relay over the current worker set (empty
        without a topology)."""
        if self.topology is None:
            return {}
        ws = self.workers()
        return {z: r for z in self.topology.zone_names(ws)
                if (r := self.topology.relay(z, ws)) is not None}

    def _relay_reads(self, worker: ReplicaId, key: str) -> bool:
        """Is ``worker`` its zone's elected relay AND does anyone in
        that zone read ``key``? The aggregation rule of hierarchical
        gossip: a relay carries its whole zone's read interest across
        the zone boundary."""
        topo = self.topology
        if topo is None:
            return False
        ws = self.workers()
        z = topo.zone(worker)
        if topo.relay(z, ws) != worker:
            return False
        return any(self.reads(m, key) for m in topo.members(z, ws))

    def buffers(self, worker: ReplicaId, key: str) -> bool:
        """May ``worker`` buffer/forward ``key``'s received deltas?
        Owners always; additionally its zone's relay buffers any key a
        zone-mate reads, so rows pulled across the zone boundary survive
        long enough to be pushed on to the zone (Def. 6 makes the extra
        forwarding join-equivalent — it can only deliver sooner)."""
        return self.replicates(worker, key) or self._relay_reads(worker, key)

    def routes_pull(self, worker: ReplicaId, key: str) -> bool:
        """Does a digest response to ``worker`` carry ``key``? Readers
        always; additionally a zone relay pulls on behalf of every
        zone-mate's read set — the responder cannot see *why* a relay
        asks, so the aggregated interest lives here, in the ownership
        map both sides share."""
        return self.reads(worker, key) or self._relay_reads(worker, key)


class ShardByKey(ShippingPolicy):
    """Ship each key's deltas only to the replicas that own/replicate it.

    ``include`` drops buffered store entries carrying nothing the
    destination replicates; ``finalize`` restricts every outgoing store
    payload (delta-interval joins AND full-state fallbacks) to the
    destination's shard, so bytes shipped per round scale with the keys a
    peer replicates, not with store size. Non-store payloads pass through
    (the policy composes with single-object replicas as a no-op).

    Sharding intentionally relaxes *global* convergence to *per-key*
    convergence across each key's replica set — a destination never
    receives keys outside its shard, so whole-store equality across all
    replicas no longer holds (and ``ghost_check``, which asserts exactly
    that equivalence, must stay off). Acks remain truthful for the shard
    the receiver is responsible for, which is all it serves.

    Push traffic routes by the **write** set (``replicates``); pull
    responses route by the wider **read** set (``reads``) — that split
    is what makes read replicas work: a subscriber's digest request
    comes back with its hot keys' rows, while nobody ever pushes to it
    or waits on it. Key enumeration uses ``all_keys()``, so lifecycle
    tombstones (which hold no value) shard, ship, and hand off exactly
    like values — a reaped key must reach its whole replica set or
    stragglers could resurrect it.
    """

    def __init__(self, ownership: KeyOwnership):
        self.ownership = ownership
        self.name = f"shard:{ownership.replication}"

    def _dst_keys(self, dst: ReplicaId, store: LatticeStore):
        # Push routes by the destination's *buffer* set: identical to
        # ``replicates`` on a flat topology, but under a zoned one it
        # additionally lets a zone relay accept (and re-push) the keys
        # its zone-mates read, which is how a delta born in a zone with
        # no other owner of its key reaches the key's remote replicas
        # without any cross-zone fanout.
        may = getattr(self.ownership, "buffers", None)
        route = may if may is not None else self.ownership.replicates
        return [k for k in store.all_keys() if route(dst, k)]

    def include(self, replica, dst, index, entry) -> bool:
        if not isinstance(entry.delta, LatticeStore):
            return True
        return bool(self._dst_keys(dst, entry.delta))

    def finalize(self, replica, dst, payload):
        if not isinstance(payload, LatticeStore):
            return payload
        return payload.restrict(self._dst_keys(dst, payload))

    def credit(self, replica, dst, delta):
        """Unlike BP/RR, this policy withholds state the receiver does
        NOT hold (keys outside its shard), so acked buffered entries may
        only be credited to the receiver's known-state bound after the
        same restriction — otherwise keys that later move into the shard
        look 'already delivered' and RR trims them out of the full-state
        fallback forever."""
        if not isinstance(delta, LatticeStore):
            return delta
        return delta.restrict(self._dst_keys(dst, delta))

    def restrict_pull(self, replica, dst, store):
        """Digest responses route by the READ set: a requester receives
        the keys it replicates *or subscribes to* (a pure routing
        restriction, which is all the pull hook permits) — this is the
        entire transport story of read replicas. Under a zoned topology
        the route widens to ``routes_pull``: a zone relay's request also
        pulls every key its zone-mates read (cross-zone aggregation)."""
        if not isinstance(store, LatticeStore):
            return store
        routes = getattr(self.ownership, "routes_pull",
                         self.ownership.reads)
        return store.restrict(k for k in store.all_keys()
                              if routes(dst, k))


class RebalanceHandoff:
    """Rebalance-aware handoff: push moved keys instead of waiting.

    When the live worker set changes, rendezvous hashing moves ~1/n of
    the keyspace to new owners. Organic anti-entropy eventually delivers
    those keys (``ShardByKey`` starts routing them on the next rounds),
    but a fresh owner serves ⊥ until its first delta-interval lands —
    and under ``bp+rr`` a long-converged key generates no new deltas at
    all until someone writes it, so the wait is unbounded. This agent
    closes the gap: it watches the ownership's worker set, and on a
    change each **old** owner immediately pushes every moved key's
    full-state delta to each **new** owner it gained (a ``handoff``
    frame under the wire codec). The push is a plain join — idempotent,
    unacked, safe under loss/duplication — so organic anti-entropy
    remains the convergence safety net and the merging condition is
    untouched (handoffs bypass the interval machinery entirely on the
    send side; the receiver buffers them like any received delta so it
    can forward).

    Call :meth:`check` after membership events (or periodically — it is
    a no-op while the worker set is stable). Keys are batched per
    destination into ONE store payload per push.
    """

    def __init__(self, replica: Replica, ownership: KeyOwnership):
        self.replica = replica
        self.ownership = ownership
        self._workers: Tuple[ReplicaId, ...] = ownership.workers()

    def check(self) -> int:
        """Detect a worker-set change and push moved keys; returns the
        number of handoff messages sent."""
        cur = self.ownership.workers()
        if cur == self._workers:
            return 0
        prev, self._workers = self._workers, cur
        store = self.replica.X
        if not isinstance(store, LatticeStore):
            return 0
        # receiver-state bounds were derived under the old shard map;
        # dropping them is always sound (an under-approximation may only
        # shrink — RR briefly trims less). _inflight is kept: it records
        # the exact payloads that were shipped, which is precisely what
        # acks in flight across the change should credit.
        self.replica._known.clear()
        by_dst: Dict[ReplicaId, list] = {}
        for key in store.all_keys():    # tombstones hand off like values
            # replay the ownership's own (possibly zone-aware) rule over
            # the previous snapshot — flat owners_for_key would disagree
            # with a zone-spread write set and mis-assign the pusher role
            old = (self.ownership.owners_among(key, prev) if prev else ())
            if self.replica.id not in old:
                continue              # only a key's old owners push it
            for dst in self.ownership.owners(key):
                if dst not in old and dst != self.replica.id:
                    by_dst.setdefault(dst, []).append(key)
        for dst, keys in by_dst.items():
            self.replica.push_handoff(dst, store.restrict(keys))
        return len(by_dst)


class ClusterReplica(Replica):
    """One pod's cluster-view replica on the unified propagation runtime:
    the :class:`Membership` agent's delta-mutations gossip through the same
    ``Replica`` engine (Algorithm 2 + pluggable shipping policy) as every
    other lattice. On a full mesh, ``AvoidBackPropagation`` +
    ``RemoveRedundant`` keep heartbeat chatter from echoing back to its
    producer or re-shipping state the receiver already acked."""

    def __init__(self, node_id: ReplicaId, neighbors: Sequence[ReplicaId],
                 *, policy: Optional[ShippingPolicy] = None,
                 rng: Optional[random.Random] = None,
                 timeout: float = 30.0, evict_after: float = 90.0,
                 wire: Optional[object] = None):
        super().__init__(node_id, ClusterState.bottom(), neighbors,
                         causal=True, policy=policy, rng=rng, wire=wire)
        self.agent = Membership(node_id, timeout=timeout,
                                evict_after=evict_after)

    # -- delta-mutations through the engine -----------------------------------
    def announce(self, now: float) -> None:
        self.operation(lambda X: self.agent.announce(X, now))

    def heartbeat(self, now: float) -> None:
        self.operation(lambda X: self.agent.heartbeat(X, now))

    def evict_stragglers(self, now: float) -> FrozenSet[ReplicaId]:
        """Record an eviction delta for every worker silent ≥ evict_after;
        returns the set evicted by this call."""
        doomed = self.agent.stale(self.X, now)
        if doomed:
            self.operation(lambda X: self.agent.evictions(X, now))
        return doomed

    # -- queries over the replicated view --------------------------------------
    def alive_workers(self, now: float) -> FrozenSet[ReplicaId]:
        return self.X.alive(now, self.agent.timeout)

    def quorum(self, now: float, fraction: float = 0.5) -> FrozenSet[ReplicaId]:
        return self.agent.quorum(self.X, now, fraction)
