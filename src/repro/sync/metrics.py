"""Compatibility shim: the replicated δ-CRDT metrics moved to
:mod:`repro.obs.registry` (the single metrics home — local process
counters and replicated duplicate-safe aggregates are two views of one
observability layer). Import from ``repro.obs`` in new code."""

from __future__ import annotations

from ..obs.registry import MetricRecord, Metrics, MetricsState

__all__ = ["MetricRecord", "Metrics", "MetricsState"]
