"""Duplicate-safe distributed training metrics as δ-CRDTs.

Telemetry links are the textbook case for the paper's counter example
(§4.2): a lost or re-sent report must never lose or double-count samples.
Each metric is a per-replica map of monotone ``(n, sum, min, max)``
records — the per-replica record is versioned by its own sample count, so
the join keeps the freshest record per reporter (idempotent, commutative).
Global aggregates are exact once every replica's latest record arrives.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from ..core.crdts import DeltaCRDT
from ..core.dots import ReplicaId


@dataclass(frozen=True)
class MetricRecord:
    n: int = 0
    total: float = 0.0
    min_v: float = float("inf")
    max_v: float = float("-inf")

    def observe(self, value: float, weight: int = 1) -> "MetricRecord":
        return MetricRecord(self.n + weight, self.total + value,
                            min(self.min_v, value), max(self.max_v, value))

    def join(self, other: "MetricRecord") -> "MetricRecord":
        # per-replica records are monotone in n: larger n subsumes
        return self if self.n >= other.n else other


@dataclass(frozen=True)
class MetricsState(DeltaCRDT):
    """metric name → replica → MetricRecord."""

    entries: Tuple[Tuple[str, Tuple[Tuple[ReplicaId, MetricRecord], ...]], ...] = ()

    @staticmethod
    def bottom() -> "MetricsState":
        return MetricsState()

    def _as_dict(self) -> Dict[str, Dict[ReplicaId, MetricRecord]]:
        return {m: dict(rs) for m, rs in self.entries}

    @staticmethod
    def _freeze(d: Dict[str, Dict[ReplicaId, MetricRecord]]) -> "MetricsState":
        return MetricsState(tuple(sorted(
            (m, tuple(sorted(rs.items()))) for m, rs in d.items())))

    def observe_delta(self, i: ReplicaId, metric: str, value: float,
                      weight: int = 1) -> "MetricsState":
        cur = self._as_dict().get(metric, {}).get(i, MetricRecord())
        return MetricsState._freeze({metric: {i: cur.observe(value, weight)}})

    def observe_full(self, i: ReplicaId, metric: str, value: float,
                     weight: int = 1) -> "MetricsState":
        return self.join(self.observe_delta(i, metric, value, weight))

    def join(self, other: "MetricsState") -> "MetricsState":
        a = self._as_dict()
        for m, rs in other._as_dict().items():
            mine = a.setdefault(m, {})
            for r, rec in rs.items():
                mine[r] = mine[r].join(rec) if r in mine else rec
        return MetricsState._freeze(a)

    # -- aggregates -----------------------------------------------------------
    def count(self, metric: str) -> int:
        return sum(rec.n for rec in self._as_dict().get(metric, {}).values())

    def total(self, metric: str) -> float:
        return sum(rec.total for rec in self._as_dict().get(metric, {}).values())

    def mean(self, metric: str) -> float:
        n = self.count(metric)
        return self.total(metric) / n if n else float("nan")

    def minimum(self, metric: str) -> float:
        vals = [rec.min_v for rec in self._as_dict().get(metric, {}).values()]
        return min(vals) if vals else float("inf")

    def maximum(self, metric: str) -> float:
        vals = [rec.max_v for rec in self._as_dict().get(metric, {}).values()]
        return max(vals) if vals else float("-inf")


class Metrics:
    """Convenience recorder for one replica."""

    def __init__(self, replica: ReplicaId):
        self.replica = replica
        self.state = MetricsState.bottom()

    def observe(self, metric: str, value: float, weight: int = 1) -> MetricsState:
        delta = self.state.observe_delta(self.replica, metric, value, weight)
        self.state = self.state.join(delta)
        return delta

    def merge(self, remote: MetricsState) -> None:
        self.state = self.state.join(remote)
