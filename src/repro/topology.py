"""Topology: zones, link classes, and per-class link profiles.

The δ-CRDT anti-entropy machinery is topology-agnostic — deltas join
correctly over any channel (Def. 6: any join-equivalent routing of
deltas preserves convergence) — but a production deployment is not a
flat, uniform-cost full mesh. Workers live in **zones** (failure
domains: an availability zone, a datacenter), zones group into
**regions**, and the links between workers fall into three classes with
wildly different latency, loss, and byte cost:

* ``intra`` — same zone: fast, cheap, effectively lossless;
* ``inter`` — different zones of one region: slower, still cheap;
* ``wan``   — across regions: slow, lossy, and the bytes are the bill.

This module is the ONE place those facts live. Every layer that used to
assume the flat mesh refactors against :class:`Topology`:

* ``core.sim``          — per-link-class delay/loss/dup and per-class
                          byte accounting (``Simulator(topology=...)``);
* ``sync.membership``   — zone-spreading rendezvous ownership (a key's
                          write set crosses ≥2 failure domains; read
                          replicas prefer zone-local coverage);
* ``core.hiergossip``   — the ``HierarchicalGossip`` shipping policy:
                          push gossip stays intra-zone, elected per-zone
                          relays batch cross-zone repair as digest-sync;
* ``net``               — ``id@host:port@zone`` peer annotations and
                          per-link-class ``LinkStats`` byte columns on
                          real sockets;
* ``benchmarks.bench_topology`` — WAN bytes and convergence of
                          hierarchical vs flat gossip under zone
                          partitions, in sim and socket mode.

Zone names are strings, optionally ``"region/zone"``: two distinct
zones sharing a region prefix are ``inter``; distinct zones with no
shared region (including bare un-prefixed names) are ``wan``. Everything
here is deterministic and dependency-free — the simulator's seeded RNG
is the only source of randomness in a topology-aware run.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import (Callable, Dict, Iterable, Mapping, Optional, Tuple,
                    Union)

# the three link classes, cheapest first
INTRA = "intra"
INTER = "inter"
WAN = "wan"
LINK_CLASSES = (INTRA, INTER, WAN)

DEFAULT_ZONE = "z0"          # unannotated workers share one zone


def zone_region(zone: str) -> str:
    """The region a zone belongs to: the ``"region/"`` prefix when the
    name has one, else the zone name itself (a bare zone is its own
    region, so distinct bare zones are WAN apart)."""
    region, sep, _ = zone.rpartition("/")
    return region if sep else zone


def link_class(zone_a: str, zone_b: str) -> str:
    """Class of the link between two zones: ``intra`` within a zone,
    ``inter`` across zones of one region, ``wan`` across regions."""
    if zone_a == zone_b:
        return INTRA
    if zone_region(zone_a) == zone_region(zone_b):
        return INTER
    return WAN


@dataclass(frozen=True)
class LinkProfile:
    """Per-class link behaviour the simulator applies to a message and
    the cost model weighs its bytes with. ``loss``/``dup`` are per-
    transmission probabilities; delays are uniform jitter bounds (the
    reordering of the §2 model falls out of random delays); ``byte_cost``
    is the relative price of a byte on this class of link (what
    ``NetStats.link_cost`` accumulates — WAN egress is billed, a
    top-of-rack hop is not)."""

    min_delay: float = 0.05
    max_delay: float = 1.0
    loss: float = 0.0
    dup: float = 0.0
    byte_cost: float = 1.0


#: Default per-class profiles: an intra-zone hop is ~RTT-free and free;
#: inter-zone adds latency; WAN adds latency, loss, and a 10x byte bill.
DEFAULT_PROFILES: Dict[str, LinkProfile] = {
    INTRA: LinkProfile(min_delay=0.01, max_delay=0.05, loss=0.0,
                       byte_cost=1.0),
    INTER: LinkProfile(min_delay=0.05, max_delay=0.25, loss=0.01,
                       byte_cost=4.0),
    WAN: LinkProfile(min_delay=0.2, max_delay=1.0, loss=0.02,
                     byte_cost=10.0),
}


def hrw_score(member: str, key: str) -> int:
    """Deterministic, process-independent highest-random-weight score of
    ``member`` for ``key`` (blake2b, not ``hash()`` — the builtin is
    salted per process). The same hash rendezvous ownership uses, so
    relay election and key placement share one minimal-disruption
    argument."""
    h = hashlib.blake2b(f"{member}\x00{key}".encode("utf-8"),
                        digest_size=8)
    return int.from_bytes(h.digest(), "big")


def relay_for(zone: str, members: Iterable[str],
              zone_of: Callable[[str], str]) -> Optional[str]:
    """The zone's elected relay/aggregator: the HRW-highest member of
    ``zone`` within ``members``. Pure function of (zone, live member
    set), so every replica that agrees on the membership view agrees on
    the relay — and when the relay dies, its departure from the live set
    IS the failover election."""
    local = [m for m in members if zone_of(m) == zone]
    if not local:
        return None
    return max(local, key=lambda m: (hrw_score(m, f"relay:{zone}"), m))


class Topology:
    """Zone annotations + per-class link profiles for a worker set.

    ``zones`` maps worker id → zone name; workers absent from the map
    sit in ``default_zone``. ``profiles`` maps link class → a
    :class:`LinkProfile` override — classes without an entry fall back
    to whatever the consuming layer's flat-mesh defaults are (the
    simulator's ``NetConfig``), so ``Topology({})`` composed anywhere is
    byte-for-byte the old flat behaviour. Pass ``profiles=DEFAULT_PROFILES``
    (or your own) to opt into per-class link conditions.
    """

    def __init__(self, zones: Mapping[str, str],
                 profiles: Optional[Mapping[str, LinkProfile]] = None,
                 default_zone: str = DEFAULT_ZONE):
        self.zones: Dict[str, str] = dict(zones)
        self.default_zone = default_zone
        self.profiles: Dict[str, LinkProfile] = dict(profiles or {})
        for cls in self.profiles:
            if cls not in LINK_CLASSES:
                raise ValueError(f"unknown link class {cls!r}; "
                                 f"have {LINK_CLASSES}")

    # -- zones -----------------------------------------------------------------
    def zone(self, node_id: str) -> str:
        return self.zones.get(node_id, self.default_zone)

    def zone_names(self, workers: Optional[Iterable[str]] = None
                   ) -> Tuple[str, ...]:
        """Distinct zones, sorted — of ``workers`` when given, else of
        every annotated worker."""
        ids = self.zones.keys() if workers is None else workers
        return tuple(sorted({self.zone(w) for w in ids}))

    def members(self, zone: str, workers: Iterable[str]) -> Tuple[str, ...]:
        return tuple(sorted(w for w in workers if self.zone(w) == zone))

    def by_zone(self, workers: Iterable[str]) -> Dict[str, Tuple[str, ...]]:
        out: Dict[str, list] = {}
        for w in sorted(workers):
            out.setdefault(self.zone(w), []).append(w)
        return {z: tuple(ws) for z, ws in out.items()}

    # -- links -----------------------------------------------------------------
    def link_class(self, a: str, b: str) -> str:
        """Class of the a↔b link from the two endpoints' zones."""
        return link_class(self.zone(a), self.zone(b))

    def profile(self, a: str, b: str) -> Optional[LinkProfile]:
        """The link's profile override, or None (fall back to flat
        defaults)."""
        return self.profiles.get(self.link_class(a, b))

    def byte_cost(self, a: str, b: str) -> float:
        prof = self.profile(a, b)
        return prof.byte_cost if prof is not None else 1.0

    # -- relays ----------------------------------------------------------------
    def relay(self, zone: str, members: Iterable[str]) -> Optional[str]:
        """The zone's elected relay among ``members`` (see
        :func:`relay_for`)."""
        return relay_for(zone, members, self.zone)

    def is_relay(self, node_id: str, members: Iterable[str]) -> bool:
        return self.relay(self.zone(node_id), members) == node_id

    # -- construction helpers ----------------------------------------------------
    @classmethod
    def flat(cls, workers: Iterable[str],
             zone: str = DEFAULT_ZONE) -> "Topology":
        """Everyone in one zone — the old world, spelled explicitly."""
        return cls({w: zone for w in workers})

    @classmethod
    def zoned(cls, workers: Iterable[str], n_zones: int,
              profiles: Optional[Mapping[str, LinkProfile]] = None,
              zone_fmt: str = "z{}") -> "Topology":
        """Round-robin ``workers`` over ``n_zones`` zones — the standard
        N-zone test/bench cluster shape, deterministic in worker order."""
        if n_zones < 1:
            raise ValueError(f"need at least one zone, got {n_zones}")
        zones = {w: zone_fmt.format(i % n_zones)
                 for i, w in enumerate(sorted(workers))}
        return cls(zones, profiles=profiles)

    def __repr__(self) -> str:
        zs = self.zone_names()
        return f"Topology(zones={len(zs)}:{list(zs)}, workers={len(self.zones)})"


def parse_zone_map(spec: Union[str, Mapping[str, str], None]
                   ) -> Dict[str, str]:
    """``"gw0=eu/a,gw1=eu/b"`` (CLI form) or a mapping → ``{id: zone}``."""
    if spec is None:
        return {}
    if isinstance(spec, Mapping):
        return dict(spec)
    out: Dict[str, str] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        wid, sep, zone = part.partition("=")
        if not sep or not wid or not zone:
            raise ValueError(f"zone spec {part!r} is not ID=ZONE")
        out[wid] = zone
    return out


__all__ = [
    "DEFAULT_PROFILES", "DEFAULT_ZONE", "INTER", "INTRA", "LINK_CLASSES",
    "LinkProfile", "Topology", "WAN", "hrw_score", "link_class",
    "parse_zone_map", "relay_for", "zone_region",
]
