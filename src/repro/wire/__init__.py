"""Binary δ-wire subsystem: what actually crosses the network.

The paper's bandwidth argument — ``size(mᵟ(X)) ≪ size(X)`` — only pays
off if the transport realizes it in bytes. This package is that
transport layer:

* ``frames``  — versioned, CRC-checksummed typed envelopes for every
                payload kind the propagation engine ships (delta
                intervals, full states, acks, digest summaries,
                membership gossip, rebalance handoffs, top-k updates),
                plus :class:`WireCodec`, the engine-pluggable message
                codec (``Replica(wire=WireCodec())``).
* ``codec``   — the stacked store codec: one payload per store delta,
                live chunk rows of all keys grouped by (chunk-width,
                dtype) signature into stacked columns with a columnar
                (key, tensor, chunk-index, version) index; decoding
                yields zero-copy sparse row views that join into
                resident state in O(shipped chunks).

Byte accounting becomes measurement: an encoded frame *is* the wire
message, so every benchmark byte report is ``len(frame)``.
"""

from .codec import (decode_digest, decode_store, decode_topk,
                    decode_value, encode_digest, encode_store,
                    encode_topk, encode_value, store_body_is_empty)
from .frames import (FRAME_KINDS, FrameBytes, FrameError, FrameStream,
                     HEADER_SIZE, MAGIC, VERSION, WireCodec, decode_frame,
                     encode_frame, peek_kind)

__all__ = [
    "decode_digest", "decode_store", "decode_topk", "decode_value",
    "encode_digest", "encode_store", "encode_topk", "encode_value",
    "store_body_is_empty",
    "FRAME_KINDS", "FrameBytes", "FrameError", "FrameStream",
    "HEADER_SIZE", "MAGIC", "VERSION", "WireCodec", "decode_frame",
    "encode_frame", "peek_kind",
]
