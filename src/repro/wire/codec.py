"""Stacked binary codec for :class:`~repro.core.store.LatticeStore` deltas.

One store delta — any subset of keys, each holding any lattice value —
packs into one contiguous byte payload:

* Every ``TensorState`` chunk tensor contributes only its **live** rows
  (version > 0; a delta's untouched chunks are ⊥ and never ship). Rows
  from *all* keys and tensors are grouped by ``(chunk-width, value-dtype,
  version-dtype)`` signature and laid out as one stacked values column +
  one versions column + one chunk-index column per group — the same
  signature grouping ``kernels.ops.batched_delta_join`` launches over, so
  a receiver's columnar ingest sees data already in launch order.
* A columnar index maps rows back to tensors: a key table, a tensor
  descriptor table ``(key, name, n_chunks)``, and per group a
  ``(descriptor, row-count)`` run-length list (rows of one tensor are
  contiguous and sorted by chunk position).
* Non-tensor lattice values (counters, OR-Sets, registers, membership
  views, dot stores, …) ride as tagged opaque bodies per key.

Decoding is **zero-copy for the columns**: each tensor comes back as a
:class:`~repro.core.tensor_lattice.SparseChunks` whose ``idx``/``vals``/
``vers`` arrays are views into the frame buffer. Joining the decoded
store into resident state gathers, LWW-merges, and scatters only the
listed rows — ingest is O(shipped chunks), with no full-size zero-padded
densification round-trip (the cost :func:`tensor_lattice.unpack_delta`
used to pay).

Format versioning rides in the frame header (:mod:`repro.wire.frames`);
this module only ever sees validated payloads.
"""

from __future__ import annotations

import pickle
import struct
from typing import Any, Dict, List, Tuple

import numpy as np

from ..core.store import LatticeStore
from ..core.tensor_lattice import SparseChunks, TensorState, _sp_live

_U8 = struct.Struct("<B")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_II = struct.Struct("<II")

_KIND_TENSOR = 0
_KIND_OPAQUE = 1

# payload tags for encode_value/decode_value
_TAG_STORE = 0
_TAG_TENSORSTATE = 1
_TAG_OPAQUE = 2

_SINGLE = "\x00single"    # wrapper key for bare-TensorState payloads


def _pad8(buf: bytearray) -> None:
    buf.extend(b"\x00" * ((-len(buf)) % 8))


def _put_str(buf: bytearray, s: str, width=_U16) -> None:
    raw = s.encode("utf-8")
    buf += width.pack(len(raw))
    buf += raw


class _Cursor:
    """Sequential reader over a memoryview with aligned array views."""

    __slots__ = ("buf", "off")

    def __init__(self, buf):
        self.buf = memoryview(buf)
        self.off = 0

    def unpack(self, st: struct.Struct):
        vals = st.unpack_from(self.buf, self.off)
        self.off += st.size
        return vals if len(vals) > 1 else vals[0]

    def get_str(self, width=_U16) -> str:
        n = self.unpack(width)
        s = bytes(self.buf[self.off:self.off + n]).decode("utf-8")
        self.off += n
        return s

    def get_blob(self) -> memoryview:
        n = self.unpack(_U32)
        blob = self.buf[self.off:self.off + n]
        self.off += n
        return blob

    def align8(self) -> None:
        self.off += (-self.off) % 8

    def array(self, dtype, count: int, shape=None) -> np.ndarray:
        self.align8()
        dt = np.dtype(dtype)
        arr = np.frombuffer(self.buf, dtype=dt, count=count, offset=self.off)
        self.off += count * dt.itemsize
        return arr.reshape(shape) if shape is not None else arr


def _live_rows(ct) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(chunk positions, values rows, versions) of a tensor's live chunks,
    sorted by position — directly from sparse row sets, by mask for dense."""
    if ct.is_sparse:
        idx, vals, vers = _sp_live(ct)
        return np.asarray(idx, dtype=np.int32), vals, vers
    vers = np.asarray(ct.versions)
    mask = vers > 0
    idx = np.nonzero(mask)[0].astype(np.int32)
    return idx, np.asarray(ct.values)[idx], vers[idx]


def encode_store(store: LatticeStore) -> bytes:
    """Pack a whole store delta into one stacked, columnar byte payload."""
    out = bytearray()
    entries = store.entries

    # -- key table ------------------------------------------------------------
    out += _U32.pack(len(entries))
    tensor_descs: List[Tuple[int, str, Any]] = []   # (key_i, name, ct)
    opaque: List[Tuple[int, Any]] = []
    for key_i, (key, val) in enumerate(entries):
        _put_str(out, key)
        if isinstance(val, TensorState):
            out += bytes([_KIND_TENSOR])
            out += _U64.pack(int(val.lamport))
            for name, ct in val.chunks:
                tensor_descs.append((key_i, name, ct))
        else:
            out += bytes([_KIND_OPAQUE])
            opaque.append((key_i, val))

    # -- opaque bodies ----------------------------------------------------------
    out += _U32.pack(len(opaque))
    for key_i, val in opaque:
        blob = pickle.dumps(val, protocol=4)
        out += _U32.pack(key_i)
        out += _U32.pack(len(blob))
        out += blob

    # -- tensor descriptors -------------------------------------------------------
    out += _U32.pack(len(tensor_descs))
    for key_i, name, ct in tensor_descs:
        out += _U32.pack(key_i)
        _put_str(out, name)
        out += _U32.pack(int(ct.shape[0]))

    # -- signature groups: stacked columns ----------------------------------------
    groups: Dict[Tuple[int, str, str], List[int]] = {}
    rows_by_desc: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    for desc_i, (_, _, ct) in enumerate(tensor_descs):
        idx, vals, vers = _live_rows(ct)
        rows_by_desc.append((idx, vals, vers))
        sig = (int(ct.shape[1]), np.dtype(vals.dtype).str,
               np.dtype(vers.dtype).str)
        groups.setdefault(sig, []).append(desc_i)

    out += _U16.pack(len(groups))
    for (chunk_w, dstr, vstr), members in sorted(groups.items()):
        _put_str(out, dstr, width=_U16)
        _put_str(out, vstr, width=_U16)
        out += _U32.pack(chunk_w)
        out += _U32.pack(len(members))
        total = 0
        for desc_i in members:
            rows = int(rows_by_desc[desc_i][0].shape[0])
            out += _U32.pack(desc_i)
            out += _U32.pack(rows)
            total += rows
        out += _U32.pack(total)
        _pad8(out)
        for desc_i in members:                       # chunk-index column
            out += np.ascontiguousarray(
                rows_by_desc[desc_i][0], dtype=np.int32).tobytes()
        _pad8(out)
        for desc_i in members:                       # versions column
            out += np.ascontiguousarray(rows_by_desc[desc_i][2]).tobytes()
        _pad8(out)
        for desc_i in members:                       # stacked values column
            out += np.ascontiguousarray(rows_by_desc[desc_i][1]).tobytes()
        _pad8(out)
    return bytes(out)


def decode_store(buf) -> LatticeStore:
    """Open a stacked payload back into a :class:`LatticeStore`.

    Tensor values come back as :class:`SparseChunks` whose columns are
    zero-copy views into ``buf`` — hand the result straight to
    ``resident.join(decoded)`` and the store's join dispatches every
    tensor through the O(shipped-rows) gather/merge/scatter path.
    """
    cur = _Cursor(buf)
    n_keys = cur.unpack(_U32)
    keys: List[str] = []
    kinds: List[int] = []
    lamports: List[int] = []
    for _ in range(n_keys):
        keys.append(cur.get_str())
        kind = cur.unpack(_U8)
        kinds.append(kind)
        lamports.append(cur.unpack(_U64) if kind == _KIND_TENSOR else 0)

    values: Dict[int, Any] = {}
    tensor_chunks: Dict[int, Dict[str, Any]] = {
        i: {} for i, k in enumerate(kinds) if k == _KIND_TENSOR}

    n_opaque = cur.unpack(_U32)
    for _ in range(n_opaque):
        key_i = cur.unpack(_U32)
        values[key_i] = pickle.loads(cur.get_blob())

    n_descs = cur.unpack(_U32)
    descs: List[Tuple[int, str, int]] = []
    for _ in range(n_descs):
        key_i = cur.unpack(_U32)
        name = cur.get_str()
        n_chunks = cur.unpack(_U32)
        descs.append((key_i, name, n_chunks))

    n_groups = cur.unpack(_U16)
    for _ in range(n_groups):
        dstr = cur.get_str(width=_U16)
        vstr = cur.get_str(width=_U16)
        chunk_w = cur.unpack(_U32)
        n_members = cur.unpack(_U32)
        members = [cur.unpack(_II) for _ in range(n_members)]
        total = cur.unpack(_U32)
        idx_col = cur.array(np.int32, total)
        vers_col = cur.array(np.dtype(vstr), total)
        vals_col = cur.array(np.dtype(dstr), total * chunk_w,
                             shape=(total, chunk_w))
        row = 0
        for desc_i, rows in members:
            key_i, name, n_chunks = descs[desc_i]
            tensor_chunks[key_i][name] = SparseChunks(
                n_chunks, idx_col[row:row + rows],
                vals_col[row:row + rows], vers_col[row:row + rows])
            row += rows

    for key_i, chunks in tensor_chunks.items():
        values[key_i] = TensorState.of(chunks, lamport=lamports[key_i])
    return LatticeStore.of({keys[i]: v for i, v in values.items()})


# ---------------------------------------------------------------------------
# Generic payload bodies (what frames carry)
# ---------------------------------------------------------------------------

def encode_value(value: Any) -> bytes:
    """Tagged payload body for any lattice value the engine ships: stores
    and bare TensorStates take the stacked columnar path; every other
    lattice (membership views, dot stores, counters…) rides opaque."""
    if isinstance(value, LatticeStore):
        return bytes([_TAG_STORE]) + encode_store(value)
    if isinstance(value, TensorState):
        wrapped = LatticeStore.key_delta(_SINGLE, value)
        return bytes([_TAG_TENSORSTATE]) + encode_store(wrapped)
    return bytes([_TAG_OPAQUE]) + pickle.dumps(value, protocol=4)


def decode_value(buf) -> Any:
    view = memoryview(buf)
    tag = view[0]
    if tag == _TAG_STORE:
        return decode_store(view[1:])
    if tag == _TAG_TENSORSTATE:
        store = decode_store(view[1:])
        return store.get(_SINGLE, TensorState)
    if tag == _TAG_OPAQUE:
        return pickle.loads(view[1:])
    raise ValueError(f"unknown payload tag {tag}")


# ---------------------------------------------------------------------------
# Top-k sparsified updates (sync.compression payloads)
# ---------------------------------------------------------------------------

def encode_topk(sparse: Any) -> bytes:
    """Body encoding for a ``TopKCompressor.compress`` result: per leaf,
    raw little-endian index/value columns (the dominant bytes); the
    pytree structure rides as a tiny pickled preamble."""
    import jax

    is_leaf = lambda t: isinstance(t, dict) and "idx" in t
    leaves, treedef = jax.tree_util.tree_flatten(sparse, is_leaf=is_leaf)
    tdef = pickle.dumps(treedef, protocol=4)
    out = bytearray()
    out += _U32.pack(len(tdef))
    out += tdef
    out += _U32.pack(len(leaves))
    for leaf in leaves:
        idx = np.ascontiguousarray(leaf["idx"], dtype=np.int32)
        vals = np.ascontiguousarray(leaf["vals"])
        shape = tuple(int(s) for s in leaf["shape"])
        out += _U8.pack(len(shape))
        for dim in shape:
            out += _U32.pack(dim)
        _put_str(out, np.dtype(vals.dtype).str, width=_U16)
        out += _U32.pack(int(idx.size))
        _pad8(out)
        out += idx.tobytes()
        _pad8(out)
        out += vals.tobytes()
        _pad8(out)
    return bytes(out)


def decode_topk(buf) -> Any:
    import jax

    cur = _Cursor(buf)
    treedef = pickle.loads(cur.get_blob())
    n_leaves = cur.unpack(_U32)
    leaves = []
    for _ in range(n_leaves):
        rank = cur.unpack(_U8)
        shape = tuple(cur.unpack(_U32) for _ in range(rank))
        dtype = np.dtype(cur.get_str(width=_U16))
        k = cur.unpack(_U32)
        idx = cur.array(np.int32, k)
        vals = cur.array(dtype, k)
        leaves.append({"idx": idx, "vals": vals, "shape": shape})
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Digest summaries (typed envelope for version-vector-style exchanges)
# ---------------------------------------------------------------------------

def encode_digest(store: LatticeStore) -> bytes:
    """Per-(key, tensor) chunk-version summary — the 'what do you hold'
    half of a digest-driven anti-entropy exchange; a peer diffs it
    against local versions to compute exactly the rows to ship."""
    items: List[Tuple[str, str, np.ndarray]] = []
    for key, val in store.entries:
        if not isinstance(val, TensorState):
            continue
        for name, ct in val.chunks:
            if ct.is_sparse:
                vers = np.zeros(ct.n_chunks,
                                dtype=np.asarray(ct.vers).dtype)
                vers[ct.idx] = ct.vers
            else:
                vers = np.asarray(ct.versions)
            items.append((key, name, vers))
    out = bytearray()
    out += _U32.pack(len(items))
    for key, name, vers in items:
        _put_str(out, key)
        _put_str(out, name)
        _put_str(out, np.dtype(vers.dtype).str, width=_U16)
        out += _U32.pack(len(vers))
        _pad8(out)
        out += np.ascontiguousarray(vers).tobytes()
    return bytes(out)


def decode_digest(buf) -> Dict[Tuple[str, str], np.ndarray]:
    cur = _Cursor(buf)
    n = cur.unpack(_U32)
    out: Dict[Tuple[str, str], np.ndarray] = {}
    for _ in range(n):
        key = cur.get_str()
        name = cur.get_str()
        vstr = cur.get_str(width=_U16)
        count = cur.unpack(_U32)
        out[(key, name)] = cur.array(np.dtype(vstr), count)
    return out
