"""Stacked binary codec for :class:`~repro.core.store.LatticeStore` deltas.

One store delta — any subset of keys, each holding any lattice value —
packs into one contiguous byte payload:

* Every ``TensorState`` chunk tensor contributes only its **live** rows
  (version > 0; a delta's untouched chunks are ⊥ and never ship). Rows
  from *all* keys and tensors are grouped by ``(chunk-width, value-dtype,
  version-dtype)`` signature and laid out as one stacked values column +
  one versions column + one chunk-index column per group — the same
  signature grouping ``kernels.ops.batched_delta_join`` launches over, so
  a receiver's columnar ingest sees data already in launch order.
* A columnar index maps rows back to tensors: a key table, a tensor
  descriptor table ``(key, name, n_chunks)``, and per group a
  ``(descriptor, row-count)`` run-length list (rows of one tensor are
  contiguous and sorted by chunk position).
* Causal dot-store lattices (AWORSet, RWORSet, MVRegister, flags,
  flat ORMaps) ride as **dot-column bodies**: a rid table, the causal
  context's dense vv column + sorted cloud column, and the store's
  packed int64 dot column (plus key table/group offsets for maps) —
  decoded zero-copy into the :mod:`repro.core.dotcols` array
  representation, zlib-composable per body like the signature groups.
  Remaining non-tensor lattice values (counters, membership views,
  nested maps, …) ride as tagged opaque pickle bodies per key.
* Per-key lifecycle state (``repro.lifecycle``: epoch + LWW expiry,
  tombstones included) rides in a trailing life table — reaped keys
  cost one ``(key, epoch, expiry)`` row, and the digest filter
  (``known_life``) is epoch-aware so pull responses propagate reaps and
  never resurrect them.
* Each signature group's stacked columns may be zlib-deflated behind a
  per-group flag byte (``encode_store(compress=True)`` /
  ``WireCodec(compress=True)``) — self-describing, off by default
  because compressed columns cannot be zero-copy ingested.

Decoding is **zero-copy for the columns**: each tensor comes back as a
:class:`~repro.core.tensor_lattice.SparseChunks` whose ``idx``/``vals``/
``vers`` arrays are views into the frame buffer. Joining the decoded
store into resident state gathers, LWW-merges, and scatters only the
listed rows — ingest is O(shipped chunks), with no full-size zero-padded
densification round-trip (the cost :func:`tensor_lattice.unpack_delta`
used to pay).

Format versioning rides in the frame header (:mod:`repro.wire.frames`);
this module only ever sees validated payloads.
"""

from __future__ import annotations

import pickle
import struct
import zlib
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from ..core import dotcols
from ..core.crdts import CAUSAL_WIRE_TYPES
from ..core.digest import StoreDigest, life_diff, opaque_hash, versions_at
from ..core.dotcols import (CausalDigest, CausalContextCols, DotFunCols,
                            DotMapCols, DotSetCols)
from ..core.store import LatticeStore
from ..core.tensor_lattice import SparseChunks, TensorState, live_rows
from ..lifecycle.lattice import LIFE_BOTTOM, Life

_U8 = struct.Struct("<B")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_II = struct.Struct("<II")
_LIFE = struct.Struct("<Id")     # (epoch u32, expiry f64) per life entry

_KIND_TENSOR = 0
_KIND_OPAQUE = 1
_KIND_DOTSTORE = 2               # causal CRDT on dot-column encoding

# payload tags for encode_value/decode_value
_TAG_STORE = 0
_TAG_TENSORSTATE = 1
_TAG_OPAQUE = 2

_SINGLE = "\x00single"    # wrapper key for bare-TensorState payloads


def _pad8(buf: bytearray) -> None:
    buf.extend(b"\x00" * ((-len(buf)) % 8))


def _put_str(buf: bytearray, s: str, width=_U16) -> None:
    raw = s.encode("utf-8")
    buf += width.pack(len(raw))
    buf += raw


class _Cursor:
    """Sequential reader over a memoryview with aligned array views."""

    __slots__ = ("buf", "off")

    def __init__(self, buf):
        self.buf = memoryview(buf)
        self.off = 0

    def unpack(self, st: struct.Struct):
        vals = st.unpack_from(self.buf, self.off)
        self.off += st.size
        return vals if len(vals) > 1 else vals[0]

    def get_str(self, width=_U16) -> str:
        n = self.unpack(width)
        s = bytes(self.buf[self.off:self.off + n]).decode("utf-8")
        self.off += n
        return s

    def get_blob(self) -> memoryview:
        n = self.unpack(_U32)
        blob = self.buf[self.off:self.off + n]
        self.off += n
        return blob

    def align8(self) -> None:
        self.off += (-self.off) % 8

    def array(self, dtype, count: int, shape=None) -> np.ndarray:
        self.align8()
        dt = np.dtype(dtype)
        arr = np.frombuffer(self.buf, dtype=dt, count=count, offset=self.off)
        self.off += count * dt.itemsize
        return arr.reshape(shape) if shape is not None else arr


def _causal_wire_value(val):
    """(type-id, columnar value) when ``val`` takes the dot-column
    encoding; None otherwise (non-causal lattices, or store shapes the
    columnar form does not model — those stay on the opaque path)."""
    for tid, cls in enumerate(CAUSAL_WIRE_TYPES):
        if type(val) is cls:
            cv = dotcols.value_to_cols(val)
            return None if cv is None else (tid, cv)
    return None


def encode_store(store: LatticeStore,
                 known_versions: Optional[Mapping[Tuple[str, str],
                                                  np.ndarray]] = None,
                 known_opaque: Optional[Mapping[str, bytes]] = None,
                 known_life: Optional[Mapping[str, Life]] = None,
                 known_causal: Optional[Mapping[str, CausalDigest]] = None,
                 compress: bool = False) -> bytes:
    """Pack a whole store delta into one stacked, columnar byte payload.

    ``known_versions`` / ``known_opaque`` / ``known_life`` /
    ``known_causal`` are the sections of a peer's
    :class:`~repro.core.digest.StoreDigest` and turn the encoder into
    the responder of a digest exchange: chunk rows whose version the
    digest already covers are dropped **while the columns are being
    built** (no filtered intermediate store is materialized), opaque
    keys with a matching content hash are dropped whole, causal keys
    are narrowed to the exact missing-dot response
    (:func:`~repro.core.dotcols.causal_diff_cols` — per-dot, so a
    million-dot map re-ships a few % instead of the whole body), and a
    key none of whose rows/dots survive is elided from the key table
    entirely. Lifecycle-aware (``repro.lifecycle``): life entries ship
    iff strictly above the peer's, a key the peer has tombstoned *past*
    contributes nothing at all, and version/hash filters only compare
    within the same incarnation. With the filters unset the output is
    byte-identical to the unfiltered format.

    ``compress`` zlib-compresses each signature group's stacked columns
    (the dominant bytes of a tensor payload) — flagged per group in the
    payload, so decoders need no out-of-band signal. Off by default:
    compressed columns cannot be zero-copy ingested.
    """
    out = bytearray()
    life_map = dict(store.life)

    def peer_epoch(key: str) -> int:
        return known_life.get(key, LIFE_BOTTOM)[0] if known_life else 0

    # -- filter pass: surviving rows per tensor, surviving keys -----------------
    entries: List[Tuple[str, int, Any]] = []    # (key, kind, value)
    rows_of: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    for key, val in store.entries:
        epoch = life_map.get(key, LIFE_BOTTOM)[0]
        if known_life is not None and peer_epoch(key) > epoch:
            continue                # peer's tombstone absorbs this key
        same_epoch = peer_epoch(key) == epoch
        if isinstance(val, TensorState):
            key_rows = []
            for name, ct in val.chunks:
                idx, vals, vers = live_rows(ct)
                known = (known_versions.get((key, name))
                         if known_versions is not None and same_epoch
                         else None)
                if known is not None and idx.size:
                    keep = vers > versions_at(known, idx, vers.dtype)
                    idx, vals, vers = idx[keep], vals[keep], vers[keep]
                key_rows.append((idx, vals, vers))
            if (known_versions is not None
                    and not any(r[0].size for r in key_rows)):
                continue            # peer covers every row: elide the key
            entries.append((key, _KIND_TENSOR, val))
            rows_of.extend(key_rows)
        else:
            cw = _causal_wire_value(val)
            if cw is not None:
                tid, cv = cw
                g = (known_causal.get(key)
                     if known_causal is not None and same_epoch else None)
                if g is not None:
                    # per-dot filter AT ENCODE TIME: ship only the dots
                    # the requester's context provably lacks, plus the
                    # exact removal context (dotcols.causal_diff_cols)
                    cv = dotcols.causal_diff_cols(cv, g)
                    if cv is None:
                        continue    # requester lacks nothing: elide key
                entries.append((key, _KIND_DOTSTORE, (tid, cv)))
                continue
            if (known_opaque is not None and same_epoch
                    and known_opaque.get(key) == opaque_hash(val)):
                continue            # peer holds this exact value
            entries.append((key, _KIND_OPAQUE, val))

    # life entries the peer provably lacks, epoch-stamping every
    # surviving key — shared with the object-mode responder so the
    # no-resurrection invariant cannot drift between modes
    life_out = life_diff(store.life, [k for k, _, _ in entries],
                         known_life)

    # -- key table ------------------------------------------------------------
    out += _U32.pack(len(entries))
    tensor_descs: List[Tuple[int, str, Any]] = []   # (key_i, name, ct)
    opaque: List[Tuple[int, Any]] = []
    dotstores: List[Tuple[int, int, Any]] = []      # (key_i, type_id, value)
    for key_i, (key, kind, val) in enumerate(entries):
        _put_str(out, key)
        if kind == _KIND_TENSOR:
            out += bytes([_KIND_TENSOR])
            out += _U64.pack(int(val.lamport))
            for name, ct in val.chunks:
                tensor_descs.append((key_i, name, ct))
        elif kind == _KIND_DOTSTORE:
            out += bytes([_KIND_DOTSTORE])
            tid, cv = val
            dotstores.append((key_i, tid, cv))
        else:
            out += bytes([_KIND_OPAQUE])
            opaque.append((key_i, val))

    # -- opaque bodies ----------------------------------------------------------
    out += _U32.pack(len(opaque))
    for key_i, val in opaque:
        blob = pickle.dumps(val, protocol=4)
        out += _U32.pack(key_i)
        out += _U32.pack(len(blob))
        out += blob

    # -- dot-store bodies: dot columns + vv summary per causal key --------------
    out += _U32.pack(len(dotstores))
    for key_i, tid, cv in dotstores:
        out += _U32.pack(key_i)
        out += _U8.pack(tid)
        body = bytearray()
        _emit_dotstore(body, cv)
        if compress:
            # like the per-group column compression: one zlib stream,
            # CRC still covers the compressed bytes (no zero-copy)
            blob = zlib.compress(bytes(body))
            out += _U8.pack(1)
            out += _U32.pack(len(blob))
            out += blob
        else:
            out += _U8.pack(0)
            out += _U32.pack(len(body))
            _pad8(out)              # body starts 8-aligned: zero-copy
            out += body

    # -- tensor descriptors -------------------------------------------------------
    out += _U32.pack(len(tensor_descs))
    for key_i, name, ct in tensor_descs:
        out += _U32.pack(key_i)
        _put_str(out, name)
        out += _U32.pack(int(ct.shape[0]))

    # -- signature groups: stacked columns ----------------------------------------
    groups: Dict[Tuple[int, str, str], List[int]] = {}
    rows_by_desc: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    for desc_i, (_, _, ct) in enumerate(tensor_descs):
        idx, vals, vers = rows_of[desc_i]
        rows_by_desc.append((idx, vals, vers))
        sig = (int(ct.shape[1]), np.dtype(vals.dtype).str,
               np.dtype(vers.dtype).str)
        groups.setdefault(sig, []).append(desc_i)

    out += _U16.pack(len(groups))
    for (chunk_w, dstr, vstr), members in sorted(groups.items()):
        _put_str(out, dstr, width=_U16)
        _put_str(out, vstr, width=_U16)
        out += _U32.pack(chunk_w)
        out += _U32.pack(len(members))
        total = 0
        for desc_i in members:
            rows = int(rows_by_desc[desc_i][0].shape[0])
            out += _U32.pack(desc_i)
            out += _U32.pack(rows)
            total += rows
        out += _U32.pack(total)
        out += _U8.pack(1 if compress else 0)
        if compress:
            # per-group column compression: the three stacked columns,
            # laid out exactly as the plain format but relative to their
            # own buffer, deflated as one zlib stream. The frame CRC
            # still covers the compressed bytes, so corruption is caught
            # before inflate ever runs.
            col = bytearray()
            _emit_columns(col, members, rows_by_desc)
            blob = zlib.compress(bytes(col))
            out += _U32.pack(len(blob))
            out += blob
        else:
            _pad8(out)
            _emit_columns(out, members, rows_by_desc)

    # -- life table: (key, epoch, expiry) triples ---------------------------------
    out += _U32.pack(len(life_out))
    for key, (epoch, expiry) in life_out:
        _put_str(out, key)
        out += _LIFE.pack(int(epoch), float(expiry))
    return bytes(out)


def _emit_columns(out: bytearray, members, rows_by_desc) -> None:
    """The three stacked columns of one signature group, 8-aligned
    relative to ``out``'s start (the payload for the plain path, a fresh
    buffer for the compressed path)."""
    for desc_i in members:                           # chunk-index column
        out += np.ascontiguousarray(
            rows_by_desc[desc_i][0], dtype=np.int32).tobytes()
    _pad8(out)
    for desc_i in members:                           # versions column
        out += np.ascontiguousarray(rows_by_desc[desc_i][2]).tobytes()
    _pad8(out)
    for desc_i in members:                           # stacked values column
        out += np.ascontiguousarray(rows_by_desc[desc_i][1]).tobytes()
    _pad8(out)


_SHAPE_BY_CLS = {DotSetCols: dotcols.SHAPE_SET, DotFunCols: dotcols.SHAPE_FUN,
                 DotMapCols: dotcols.SHAPE_MAP}


def _emit_dotstore(body: bytearray, cv) -> None:
    """One causal value's dot-column body, 8-aligned relative to
    ``body``'s start (which the caller places 8-aligned in the payload,
    or at offset 0 of a zlib stream): a shared rid table, the context's
    dense vv column + sorted cloud column, then the store's dot column
    (and, for maps, the key table + per-key group offsets). Values are
    one pickled tuple — dots are the dominant bytes and stay raw."""
    S, C = cv.store, cv.ctx
    rids, (ms, mc) = dotcols._union_rids(S.rids, C.rids)
    body += _U16.pack(len(rids))
    for r in rids:
        _put_str(body, r)
    body += _U8.pack(_SHAPE_BY_CLS[type(S)])
    _pad8(body)
    body += np.ascontiguousarray(
        dotcols._dense_vv(len(rids), mc, C.vvcol)).tobytes()
    cloud = dotcols._remap(C.cloudcol, mc)
    body += _U32.pack(cloud.size)
    _pad8(body)
    body += np.ascontiguousarray(cloud).tobytes()
    if isinstance(S, DotMapCols):
        body += _U32.pack(len(S.map_keys))
        kblob = pickle.dumps(S.map_keys, protocol=4)
        body += _U32.pack(len(kblob))
        body += kblob
        body += S.shapes
        _pad8(body)
        body += np.ascontiguousarray(S.offsets, dtype=np.int64).tobytes()
    dots = dotcols._remap(S.packed, ms)
    body += _U64.pack(dots.size)
    _pad8(body)
    body += np.ascontiguousarray(dots).tobytes()
    if isinstance(S, DotSetCols):
        body += _U8.pack(0)
    else:
        body += _U8.pack(1)
        vblob = pickle.dumps(tuple(S.vals), protocol=4)
        body += _U32.pack(len(vblob))
        body += vblob


def _read_dotstore(cur: "_Cursor", tid: int):
    """Decode one dot-column body at the cursor into a causal CRDT
    value on the columnar representation (dot/offset/vv columns are
    zero-copy views when the body was not compressed)."""
    n_rids = cur.unpack(_U16)
    rids = tuple(cur.get_str() for _ in range(n_rids))
    shape = cur.unpack(_U8)
    vv = cur.array(np.int64, n_rids)
    n_cloud = cur.unpack(_U32)
    cloud = cur.array(np.int64, n_cloud)
    ctx = CausalContextCols(rids, vv, cloud)
    if shape == dotcols.SHAPE_MAP:
        n_keys = cur.unpack(_U32)
        map_keys = pickle.loads(cur.get_blob())
        shapes = bytes(cur.buf[cur.off:cur.off + n_keys])
        cur.off += n_keys
        offsets = cur.array(np.int64, n_keys + 1)
    n_dots = cur.unpack(_U64)
    dots = cur.array(np.int64, n_dots)
    if cur.unpack(_U8):
        vals_t = pickle.loads(cur.get_blob())
        vals = np.empty(len(vals_t), object)
        for j, v in enumerate(vals_t):
            vals[j] = v
    else:
        vals = np.full(n_dots, None, object)
    if shape == dotcols.SHAPE_SET:
        store = DotSetCols(rids, dots)
    elif shape == dotcols.SHAPE_FUN:
        store = DotFunCols(rids, dots, vals)
    else:
        store = DotMapCols(rids, map_keys, shapes, offsets, dots, vals)
    return CAUSAL_WIRE_TYPES[tid](store, ctx)


def store_body_is_empty(body) -> bool:
    """True iff a store payload carries nothing at all — no keys and no
    lifecycle entries. The all-filtered digest-response check: parsed
    structurally (counts), not by byte comparison, so it stays correct
    across body-format options (compression flags, life tables)."""
    view = memoryview(body)
    if len(view) < 4 or _U32.unpack_from(view, 0)[0]:
        return False                 # malformed-short or has keys
    # with zero keys the opaque/dot-store/descriptor/group tables are
    # empty and the life count sits at a fixed offset
    off = 4 + 4 + 4 + 4 + 2
    return len(view) < off + 4 or _U32.unpack_from(view, off)[0] == 0


class _DeviceGroup:
    """One signature group's decoded columns, uploaded to the device at
    decode time (``decode_store(..., to_device=True)``) so the resident
    scatter ingest (``kernels.resident._device_plan``) launches over
    jax.Array operands and stages zero extra bytes. ``members`` resolves
    the run-length list to ``(key, name, n_chunks, rows)`` so the ingest
    never needs the payload's descriptor tables."""

    __slots__ = ("chunk_w", "dstr", "vstr", "members", "idx_col",
                 "vals_dev", "vers_dev")

    def __init__(self, chunk_w, dstr, vstr, members, idx_col,
                 vals_dev, vers_dev):
        self.chunk_w = chunk_w
        self.dstr = dstr
        self.vstr = vstr
        self.members = members
        self.idx_col = idx_col
        self.vals_dev = vals_dev
        self.vers_dev = vers_dev


def decode_store(buf, to_device: bool = False) -> LatticeStore:
    """Open a stacked payload back into a :class:`LatticeStore`.

    Tensor values come back as :class:`SparseChunks` whose columns are
    zero-copy views into ``buf`` — hand the result straight to
    ``resident.join(decoded)`` and the store's join dispatches every
    tensor through the O(shipped-rows) gather/merge/scatter path.

    ``to_device=True`` additionally uploads each signature group's
    values/versions columns once (counted as host→device staging) and
    attaches the group records as the store's ``_device_cols`` — a
    resident receiver's scatter ingest then runs entirely over device
    operands, so the only host→device bytes of the whole round are the
    delta columns themselves.
    """
    cur = _Cursor(buf)
    n_keys = cur.unpack(_U32)
    keys: List[str] = []
    kinds: List[int] = []
    lamports: List[int] = []
    for _ in range(n_keys):
        keys.append(cur.get_str())
        kind = cur.unpack(_U8)
        kinds.append(kind)
        lamports.append(cur.unpack(_U64) if kind == _KIND_TENSOR else 0)

    values: Dict[int, Any] = {}
    tensor_chunks: Dict[int, Dict[str, Any]] = {
        i: {} for i, k in enumerate(kinds) if k == _KIND_TENSOR}

    n_opaque = cur.unpack(_U32)
    for _ in range(n_opaque):
        key_i = cur.unpack(_U32)
        values[key_i] = pickle.loads(cur.get_blob())

    n_dotstores = cur.unpack(_U32)
    for _ in range(n_dotstores):
        key_i = cur.unpack(_U32)
        tid = cur.unpack(_U8)
        if cur.unpack(_U8):          # per-body compression flag
            blob = cur.get_blob()
            values[key_i] = _read_dotstore(_Cursor(zlib.decompress(blob)),
                                           tid)
        else:
            blen = cur.unpack(_U32)
            cur.align8()
            start = cur.off
            values[key_i] = _read_dotstore(cur, tid)
            cur.off = start + blen   # defensive: body length is explicit

    n_descs = cur.unpack(_U32)
    descs: List[Tuple[int, str, int]] = []
    for _ in range(n_descs):
        key_i = cur.unpack(_U32)
        name = cur.get_str()
        n_chunks = cur.unpack(_U32)
        descs.append((key_i, name, n_chunks))

    n_groups = cur.unpack(_U16)
    dev_groups: List[_DeviceGroup] = []
    for _ in range(n_groups):
        dstr = cur.get_str(width=_U16)
        vstr = cur.get_str(width=_U16)
        chunk_w = cur.unpack(_U32)
        n_members = cur.unpack(_U32)
        members = [cur.unpack(_II) for _ in range(n_members)]
        total = cur.unpack(_U32)
        if cur.unpack(_U8):          # per-group compression flag
            blob = cur.get_blob()
            gcur = _Cursor(zlib.decompress(blob))
        else:
            gcur = cur
        idx_col = gcur.array(np.int32, total)
        vers_col = gcur.array(np.dtype(vstr), total)
        vals_col = gcur.array(np.dtype(dstr), total * chunk_w,
                              shape=(total, chunk_w))
        if gcur is cur:
            # consume the encoder's trailing column pad — the next group
            # header (or the life table) starts 8-aligned, and reading
            # it from inside the pad would silently yield zeros whenever
            # the values column's byte length is not a multiple of 8
            cur.align8()
        row = 0
        for desc_i, rows in members:
            key_i, name, n_chunks = descs[desc_i]
            tensor_chunks[key_i][name] = SparseChunks(
                n_chunks, idx_col[row:row + rows],
                vals_col[row:row + rows], vers_col[row:row + rows])
            row += rows
        if to_device:
            from ..kernels import ops
            ops.counters.count_h2d(vals_col, vers_col)
            import jax.numpy as jnp
            dev_groups.append(_DeviceGroup(
                chunk_w, dstr, vstr,
                [(keys[descs[d][0]], descs[d][1], descs[d][2], rows)
                 for d, rows in members],
                np.asarray(idx_col), jnp.asarray(vals_col),
                jnp.asarray(vers_col)))

    life: List[Tuple[str, Life]] = []
    n_life = cur.unpack(_U32)
    for _ in range(n_life):
        key = cur.get_str()
        epoch, expiry = cur.unpack(_LIFE)
        life.append((key, (int(epoch), float(expiry))))

    for key_i, chunks in tensor_chunks.items():
        values[key_i] = TensorState.of(chunks, lamport=lamports[key_i])
    store = LatticeStore(tuple(sorted((keys[i], v)
                                      for i, v in values.items())),
                         tuple(sorted(life)))
    if dev_groups:
        object.__setattr__(store, "_device_cols", tuple(dev_groups))
    return store


# ---------------------------------------------------------------------------
# Generic payload bodies (what frames carry)
# ---------------------------------------------------------------------------

def encode_value(value: Any, compress: bool = False) -> bytes:
    """Tagged payload body for any lattice value the engine ships: stores
    and bare TensorStates take the stacked columnar path; every other
    lattice (membership views, dot stores, counters…) rides opaque.
    ``compress`` forwards to :func:`encode_store`'s per-group column
    compression."""
    if isinstance(value, LatticeStore):
        return bytes([_TAG_STORE]) + encode_store(value, compress=compress)
    if isinstance(value, TensorState):
        wrapped = LatticeStore.key_delta(_SINGLE, value)
        return bytes([_TAG_TENSORSTATE]) + encode_store(wrapped,
                                                        compress=compress)
    return bytes([_TAG_OPAQUE]) + pickle.dumps(value, protocol=4)


def decode_value(buf, to_device: bool = False) -> Any:
    view = memoryview(buf)
    tag = view[0]
    if tag == _TAG_STORE:
        return decode_store(view[1:], to_device=to_device)
    if tag == _TAG_TENSORSTATE:
        # bare TensorStates unwrap from the one-key store, which would
        # drop the device columns with the wrapper — no to_device here
        store = decode_store(view[1:])
        return store.get(_SINGLE, TensorState)
    if tag == _TAG_OPAQUE:
        return pickle.loads(view[1:])
    raise ValueError(f"unknown payload tag {tag}")


# ---------------------------------------------------------------------------
# Top-k sparsified updates (sync.compression payloads)
# ---------------------------------------------------------------------------

def encode_topk(sparse: Any) -> bytes:
    """Body encoding for a ``TopKCompressor.compress`` result: per leaf,
    raw little-endian index/value columns (the dominant bytes); the
    pytree structure rides as a tiny pickled preamble."""
    import jax

    is_leaf = lambda t: isinstance(t, dict) and "idx" in t
    leaves, treedef = jax.tree_util.tree_flatten(sparse, is_leaf=is_leaf)
    tdef = pickle.dumps(treedef, protocol=4)
    out = bytearray()
    out += _U32.pack(len(tdef))
    out += tdef
    out += _U32.pack(len(leaves))
    for leaf in leaves:
        idx = np.ascontiguousarray(leaf["idx"], dtype=np.int32)
        vals = np.ascontiguousarray(leaf["vals"])
        shape = tuple(int(s) for s in leaf["shape"])
        out += _U8.pack(len(shape))
        for dim in shape:
            out += _U32.pack(dim)
        _put_str(out, np.dtype(vals.dtype).str, width=_U16)
        out += _U32.pack(int(idx.size))
        _pad8(out)
        out += idx.tobytes()
        _pad8(out)
        out += vals.tobytes()
        _pad8(out)
    return bytes(out)


def decode_topk(buf) -> Any:
    import jax

    cur = _Cursor(buf)
    treedef = pickle.loads(cur.get_blob())
    n_leaves = cur.unpack(_U32)
    leaves = []
    for _ in range(n_leaves):
        rank = cur.unpack(_U8)
        shape = tuple(cur.unpack(_U32) for _ in range(rank))
        dtype = np.dtype(cur.get_str(width=_U16))
        k = cur.unpack(_U32)
        idx = cur.array(np.int32, k)
        vals = cur.array(dtype, k)
        leaves.append({"idx": idx, "vals": vals, "shape": shape})
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Digest summaries (the 'what do you hold' half of request/response sync)
# ---------------------------------------------------------------------------

def encode_digest(digest) -> bytes:
    """Binary body of a :class:`~repro.core.digest.StoreDigest`: per
    (key, tensor) the dense chunk-version column, per opaque key the
    16-byte content hash. A :class:`LatticeStore` is accepted as a
    convenience and summarized first. The responder diffs the decoded
    digest against resident state (``encode_store(known_versions=...,
    known_opaque=...)``) to ship exactly the rows the sender lacks."""
    if isinstance(digest, LatticeStore):
        from ..core.digest import store_digest
        digest = store_digest(digest)
    out = bytearray()
    out += _U32.pack(len(digest.tensors))
    for (key, name), vers in digest.tensors.items():
        vers = np.asarray(vers)
        _put_str(out, key)
        _put_str(out, name)
        _put_str(out, np.dtype(vers.dtype).str, width=_U16)
        out += _U32.pack(len(vers))
        _pad8(out)
        out += np.ascontiguousarray(vers).tobytes()
    out += _U32.pack(len(digest.opaque))
    for key, h in digest.opaque.items():
        _put_str(out, key)
        out += _U8.pack(len(h))
        out += h
    out += _U32.pack(len(digest.life))
    for key, (epoch, expiry) in digest.life.items():
        _put_str(out, key)
        out += _LIFE.pack(int(epoch), float(expiry))
    # causal section: per dot-store key, the vv + cloud summary and the
    # flat store dot column. Always deflated — a digest is read once to
    # filter, never zero-copy ingested, and the sorted dot column is
    # delta-encoded first (per-replica dots are near-contiguous, so the
    # dominant column of a million-dot digest zlib-crushes to ~nothing).
    out += _U32.pack(len(digest.causal))
    for key, g in digest.causal.items():
        _put_str(out, key)
        inner = bytearray()
        inner += _U16.pack(len(g.rids))
        for r in g.rids:
            _put_str(inner, r)
        _pad8(inner)
        inner += np.ascontiguousarray(g.vvcol, dtype=np.int64).tobytes()
        inner += _U32.pack(g.cloudcol.size)
        _pad8(inner)
        inner += np.ascontiguousarray(g.cloudcol,
                                      dtype=np.int64).tobytes()
        inner += _U64.pack(g.dotcol.size)
        _pad8(inner)
        dots = np.asarray(g.dotcol, dtype=np.int64)
        if dots.size:
            deltas = np.empty_like(dots)
            deltas[0] = dots[0]
            np.subtract(dots[1:], dots[:-1], out=deltas[1:])
            inner += deltas.tobytes()
        blob = zlib.compress(bytes(inner))
        out += _U32.pack(len(blob))
        out += blob
    return bytes(out)


def decode_digest(buf) -> StoreDigest:
    cur = _Cursor(buf)
    out = StoreDigest()
    n_tensor = cur.unpack(_U32)
    for _ in range(n_tensor):
        key = cur.get_str()
        name = cur.get_str()
        vstr = cur.get_str(width=_U16)
        count = cur.unpack(_U32)
        out.tensors[(key, name)] = cur.array(np.dtype(vstr), count)
    n_opaque = cur.unpack(_U32)
    for _ in range(n_opaque):
        key = cur.get_str()
        hlen = cur.unpack(_U8)
        out.opaque[key] = bytes(cur.buf[cur.off:cur.off + hlen])
        cur.off += hlen
    n_life = cur.unpack(_U32)
    for _ in range(n_life):
        key = cur.get_str()
        epoch, expiry = cur.unpack(_LIFE)
        out.life[key] = (int(epoch), float(expiry))
    n_causal = cur.unpack(_U32)
    for _ in range(n_causal):
        key = cur.get_str()
        icur = _Cursor(zlib.decompress(cur.get_blob()))
        n_rids = icur.unpack(_U16)
        rids = tuple(icur.get_str() for _ in range(n_rids))
        vv = icur.array(np.int64, n_rids)
        n_cloud = icur.unpack(_U32)
        cloud = icur.array(np.int64, n_cloud)
        n_dots = icur.unpack(_U64)
        deltas = icur.array(np.int64, n_dots)
        dots = np.cumsum(deltas, dtype=np.int64) if n_dots else deltas
        out.causal[key] = CausalDigest(rids, vv, cloud, dots)
    return out
