"""Typed, versioned, checksummed binary envelopes for δ-wire traffic.

Every payload kind the :class:`~repro.core.propagation.Replica` engine
ships — store delta-intervals, full-state fallbacks, acks, digest
summaries, membership gossip, rebalance handoffs, top-k compression
payloads — travels as one frame::

    offset  size  field
    0       2     magic  0xD4 0x57  ("δW")
    2       1     wire-format version (see VERSION; decoders reject
                  frames from a newer major format instead of guessing)
    3       1     kind   (FRAME_KINDS)
    4       4     payload length, little-endian u32
    8       4     CRC-32 over header (with this field zeroed) + payload —
                  covering the header too, so a flipped kind/length byte
                  cannot silently misroute an otherwise-valid payload
    12      n     payload

The payload of delta/state/handoff frames is the :mod:`repro.wire.codec`
stacked store encoding; membership and other non-tensor lattices ride as
tagged opaque bodies. ``decode_frame`` validates magic, version, length,
and checksum before any byte of the payload is interpreted, and returns a
zero-copy ``memoryview`` of the payload so the codec's columnar arrays
can alias the frame buffer straight into the store's ingest path.

``FrameBytes`` (a ``bytes`` subclass carrying ``.kind``) is what the
encoder returns: the network simulator reads the attribute to classify
traffic for byte accounting (``NetStats``) without parsing the frame,
and ``len(frame)`` *is* the measured wire size — byte reports in the
benchmarks are frame lengths, not structural estimates.
"""

from __future__ import annotations

import struct
import zlib
from typing import Any, Optional, Tuple

MAGIC = b"\xd4W"
# v2: store bodies carry a key-lifecycle table (epoch, expiry per key —
# repro.lifecycle) and a per-group column-compression flag; digest bodies
# carry a life section; reap/reap-ack control frames added.
# v3: causal dot-store lattices ride as dot-column bodies (rid table +
# vv/cloud columns + packed dot column) instead of opaque pickle, and
# digest bodies carry a per-dot causal section (vv + cloud + store dot
# column per key), enabling exact missing-dot pull responses.
VERSION = 3

_HEADER = struct.Struct("<2sBBII")
HEADER_SIZE = _HEADER.size

# kind byte → the traffic-class name NetStats accounts under
FRAME_KINDS = {
    1: "delta",        # delta-interval / delta-group payload
    2: "state",        # full-state fallback payload
    3: "ack",          # cumulative ack (control traffic)
    4: "handoff",      # rebalance handoff push (payload traffic)
    5: "membership",   # cluster-view gossip payload
    6: "digest",       # anti-entropy pull request: chunk-version summary
    7: "topk",         # top-k sparsified update payload
    8: "digest-resp",  # pull response: rows the digest's owner lacks
    9: "reap",         # lifecycle: owner's reap proposal (control)
    10: "reap-ack",    # lifecycle: replica-set agreement vote (control)
}
_KIND_BYTES = {name: byte for byte, name in FRAME_KINDS.items()}


class FrameError(ValueError):
    """Raised when a frame fails structural validation (bad magic,
    unsupported version, truncation, length mismatch, or CRC failure)."""


class FrameBytes(bytes):
    """Encoded frame: raw bytes plus the traffic-class ``kind`` tag."""

    kind: str = "frame"

    def __new__(cls, data: bytes, kind: str) -> "FrameBytes":
        obj = super().__new__(cls, data)
        obj.kind = kind
        return obj


def _frame_crc(header_no_crc: bytes, payload) -> int:
    return zlib.crc32(payload, zlib.crc32(header_no_crc)) & 0xFFFFFFFF


def encode_frame(kind: str, payload: bytes) -> FrameBytes:
    """Wrap ``payload`` in a checksummed envelope of the given kind."""
    kind_byte = _KIND_BYTES.get(kind)
    if kind_byte is None:
        raise FrameError(f"unknown frame kind {kind!r}; "
                         f"have {sorted(_KIND_BYTES)}")
    bare = _HEADER.pack(MAGIC, VERSION, kind_byte, len(payload), 0)
    header = _HEADER.pack(MAGIC, VERSION, kind_byte, len(payload),
                          _frame_crc(bare, payload))
    return FrameBytes(header + payload, kind)


def decode_frame(buf) -> Tuple[str, memoryview]:
    """Validate and open a frame; returns ``(kind, payload_view)``.

    The returned payload is a zero-copy view into ``buf`` — the codec's
    column decoders alias it directly. Raises :class:`FrameError` on any
    structural defect; a corrupted frame is rejected before one payload
    byte is interpreted.
    """
    view = memoryview(buf)
    if len(view) < HEADER_SIZE:
        raise FrameError(f"truncated frame: {len(view)} bytes "
                         f"< {HEADER_SIZE}-byte header")
    magic, version, kind_byte, length, crc = _HEADER.unpack_from(view, 0)
    if magic != MAGIC:
        raise FrameError(f"bad magic {magic!r}")
    if version != VERSION:
        raise FrameError(f"unsupported wire version {version} "
                         f"(this decoder speaks {VERSION})")
    kind = FRAME_KINDS.get(kind_byte)
    if kind is None:
        raise FrameError(f"unknown frame kind byte {kind_byte}")
    payload = view[HEADER_SIZE:]
    if len(payload) != length:
        raise FrameError(f"length mismatch: header says {length}, "
                         f"frame carries {len(payload)}")
    bare = _HEADER.pack(magic, version, kind_byte, length, 0)
    if _frame_crc(bare, payload) != crc:
        raise FrameError("checksum mismatch: frame corrupted in flight")
    return kind, payload


def peek_kind(buf) -> Optional[str]:
    """The frame kind without validating the payload (None if not a
    frame) — cheap classification for stats/routing layers."""
    view = memoryview(buf)
    if len(view) < HEADER_SIZE or bytes(view[:2]) != MAGIC:
        return None
    return FRAME_KINDS.get(view[3])


class FrameStream:
    """Incremental frame decoder: feed byte chunks, collect whole frames.

    The frame header is self-delimiting (magic + length + CRC over header
    and payload), so one decoder serves every byte-stream shape the
    transports produce: TCP reads split at arbitrary points, several
    frames batched into one UDP datagram, or a reassembled oversized
    frame. ``feed`` appends bytes and returns every frame that completed,
    as :class:`FrameBytes` (so ``.kind`` drives stats without re-parsing).

    Corruption policy is *skip and resync*: a frame whose CRC fails — or
    bytes that are not a frame at all — are discarded up to the next
    magic, and decoding continues from there. A dropped frame is safe by
    construction (δ-joins are idempotent; digest-sync re-pulls anything a
    drop lost), so the stream never stalls on a damaged link. Counters:

    * ``frames``  — complete frames yielded;
    * ``corrupt`` — frames that parsed but failed CRC / structural check;
    * ``resyncs`` — times the scanner skipped garbage to find a magic;
    * ``skipped_bytes`` — total bytes discarded by resyncs.

    ``max_frame`` bounds the buffer: a header announcing a payload above
    it is treated as corruption (resync) instead of waiting on — and
    allocating for — bytes that may never arrive.
    """

    def __init__(self, max_frame: int = 64 * 1024 * 1024):
        self._buf = bytearray()
        self.max_frame = max_frame
        self.frames = 0
        self.corrupt = 0
        self.resyncs = 0
        self.skipped_bytes = 0

    @property
    def pending(self) -> int:
        """Bytes buffered awaiting a frame completion."""
        return len(self._buf)

    def reset(self) -> None:
        """Drop buffered bytes (a closed connection's partial frame)."""
        self._buf.clear()

    def _skip_past_magic(self) -> None:
        """Discard the bogus frame start at offset 0 and rescan."""
        del self._buf[:len(MAGIC)]
        self.skipped_bytes += len(MAGIC)
        self.resyncs += 1

    def feed(self, data) -> list:
        self._buf += data
        out = []
        while True:
            # align buffer start to the next magic
            idx = self._buf.find(MAGIC)
            if idx < 0:
                # no magic: discard all but a possible split-magic tail
                keep = (1 if self._buf
                        and self._buf[-1] == MAGIC[0] else 0)
                dropped = len(self._buf) - keep
                if dropped:
                    del self._buf[:dropped]
                    self.skipped_bytes += dropped
                    self.resyncs += 1
                return out
            if idx > 0:
                del self._buf[:idx]
                self.skipped_bytes += idx
                self.resyncs += 1
            if len(self._buf) < HEADER_SIZE:
                return out            # wait for the rest of the header
            magic, version, kind_byte, length, _crc = _HEADER.unpack_from(
                self._buf, 0)
            if (version != VERSION or kind_byte not in FRAME_KINDS
                    or length > self.max_frame):
                self.corrupt += 1     # structurally impossible header
                self._skip_past_magic()
                continue
            total = HEADER_SIZE + length
            if len(self._buf) < total:
                return out            # wait for the rest of the payload
            candidate = bytes(self._buf[:total])
            try:
                kind, _payload = decode_frame(candidate)
            except FrameError:
                self.corrupt += 1     # CRC failure: flip inside the frame
                self._skip_past_magic()
                continue
            del self._buf[:total]
            self.frames += 1
            out.append(FrameBytes(candidate, kind))


# ---------------------------------------------------------------------------
# Engine message codec: Replica tuples ⇄ frames
# ---------------------------------------------------------------------------

_DELTA_BASIC = struct.Struct("<BI")          # mode=0, payload len
_DELTA_CAUSAL = struct.Struct("<BQBI")       # mode=1, counter, ghost?, len
_ACK = struct.Struct("<Q")
_REAP = struct.Struct("<IdB")                # epoch, expiry, ok(+key utf8)


class WireCodec:
    """Encodes the propagation engine's messages as binary frames.

    Plug an instance into ``Replica(wire=WireCodec())`` and every message
    the engine ships — delta-intervals, full-state fallbacks, acks,
    handoffs, lifecycle reap votes — leaves as one :class:`FrameBytes`;
    ``on_receive`` feeds incoming frames back through :meth:`decode_msg`
    to recover the engine tuple, with store payloads decoded into sparse
    columnar form (ingest is O(shipped chunks)). Stateless and shareable
    across replicas.

    ``compress=True`` turns on per-group zlib compression of every store
    payload's stacked columns (``codec.encode_store(compress=...)``) —
    off by default because compressed columns cannot be zero-copy
    ingested; worth it on links where bytes dominate CPU.

    ``to_device=True`` decodes every incoming store payload with
    ``codec.decode_store(to_device=True)``: the stacked columns are
    uploaded once at decode time, so a device-resident receiver
    (``kernels.resident``) scatter-ingests them with zero extra staging.
    Composes with ``compress`` (columns inflate on host first).
    """

    def __init__(self, compress: bool = False, to_device: bool = False):
        self.compress = compress
        self.to_device = to_device

    def encode_msg(self, msg: Tuple, *, full_state: bool = False
                   ) -> Optional[FrameBytes]:
        from .codec import (encode_digest, encode_store, encode_value,
                            store_body_is_empty)

        mkind = msg[0]
        if mkind == "ack":
            return encode_frame("ack", _ACK.pack(int(msg[1])))
        if mkind in ("reap", "reap-ack"):
            key, epoch, expiry = msg[1], msg[2], msg[3]
            ok = int(msg[4]) if mkind == "reap-ack" else 0
            return encode_frame(mkind, _REAP.pack(int(epoch), float(expiry),
                                                  ok)
                                + key.encode("utf-8"))
        if mkind == "handoff":
            return encode_frame("handoff",
                                encode_value(msg[1], self.compress))
        if mkind == "digest":
            return encode_frame("digest", encode_digest(msg[1]))
        if mkind == "digest-resp":
            # (store, requester digest): the known-versions/known-opaque/
            # known-life filter runs AT ENCODE TIME — the response frame
            # is built straight from resident state and carries only the
            # rows the requester's digest provably lacks. When nothing
            # survives the filter there is no frame at all (None: the
            # engine's _post drops it), so a convergent mesh trades only
            # digests — and the emptiness check costs nothing beyond the
            # one encode pass that had to happen anyway.
            _, store, digest = msg
            body = encode_store(store, known_versions=digest.tensors,
                                known_opaque=digest.opaque,
                                known_life=digest.life,
                                known_causal=digest.causal,
                                compress=self.compress)
            if store_body_is_empty(body):
                return None
            return encode_frame("digest-resp", body)
        if mkind != "delta":  # pragma: no cover - engine ships no others
            raise FrameError(f"unframeable message kind {mkind!r}")
        if len(msg) == 2:                      # basic-mode delta-group
            payload = encode_value(msg[1], self.compress)
            body = _DELTA_BASIC.pack(0, len(payload)) + payload
        else:                                  # causal delta-interval
            _, d, n, ghost = msg
            payload = encode_value(d, self.compress)
            body = (_DELTA_CAUSAL.pack(1, int(n), int(ghost is not None),
                                       len(payload)) + payload)
            if ghost is not None:
                body += encode_value(ghost, self.compress)
        return encode_frame(self._payload_kind(msg[1], full_state), body)

    @staticmethod
    def _payload_kind(value: Any, full_state: bool) -> str:
        try:
            from ..sync.membership import ClusterState
        except Exception:  # pragma: no cover - partial installs
            ClusterState = ()  # type: ignore[assignment]
        if isinstance(value, ClusterState):
            return "membership"
        return "state" if full_state else "delta"

    def decode_msg(self, frame) -> Tuple:
        from .codec import decode_digest, decode_store, decode_value

        dev = self.to_device
        kind, payload = decode_frame(frame)
        if kind == "ack":
            return ("ack", _ACK.unpack_from(payload, 0)[0])
        if kind in ("reap", "reap-ack"):
            epoch, expiry, ok = _REAP.unpack_from(payload, 0)
            key = bytes(payload[_REAP.size:]).decode("utf-8")
            if kind == "reap":
                return ("reap", key, int(epoch), float(expiry))
            return ("reap-ack", key, int(epoch), float(expiry), int(ok))
        if kind == "handoff":
            return ("handoff", decode_value(payload, to_device=dev))
        if kind == "digest":
            return ("digest", decode_digest(payload))
        if kind == "digest-resp":
            return ("digest-resp", decode_store(payload, to_device=dev))
        if kind in ("delta", "state", "membership"):
            mode = payload[0]
            if mode == 0:
                _, plen = _DELTA_BASIC.unpack_from(payload, 0)
                off = _DELTA_BASIC.size
                return ("delta", decode_value(payload[off:off + plen],
                                              to_device=dev))
            _, n, has_ghost, plen = _DELTA_CAUSAL.unpack_from(payload, 0)
            off = _DELTA_CAUSAL.size
            d = decode_value(payload[off:off + plen], to_device=dev)
            ghost = (decode_value(payload[off + plen:]) if has_ghost
                     else None)
            return ("delta", d, n, ghost)
        raise FrameError(f"engine cannot route frame kind {kind!r}")
