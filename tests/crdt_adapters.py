"""Uniform op adapters over every δ-CRDT datatype, for property tests.

Each adapter exposes the datatype's bottom and a list of operations; every
operation carries BOTH forms required by the decomposition law of §4.1:

* ``delta(state, replica, *args)`` — the δ-mutator ``mᵟ`` (returns a delta),
* ``full(state, replica, *args)``  — the standard CRDT mutator ``m``
  (returns the full successor state),

so tests can check ``full(X) == X.join(delta(X))`` and drive random
executions that exercise concurrency (divergent replicas + random joins).
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Tuple

from repro.core import (AWORSet, AWORSetTombstone, DWFlag, EWFlag, GCounter,
                        GSet, LWWRegister, LWWSet, MVRegister, ORMap,
                        PNCounter, RWORSet, TwoPSet)

ELEMS = ["a", "b", "c", "d"]
KEYS = ["k1", "k2"]
REPLICAS = ["r0", "r1", "r2"]


@dataclass
class OpSpec:
    name: str
    make_args: Callable[[random.Random], tuple]
    delta: Callable[..., Any]
    full: Callable[..., Any]


@dataclass
class Adapter:
    name: str
    bottom: Any
    ops: List[OpSpec]


def _elem(rng: random.Random) -> tuple:
    return (rng.choice(ELEMS),)


def _ts_elem(rng: random.Random) -> tuple:
    return (rng.randint(1, 40), rng.choice(ELEMS))


ADAPTERS: Dict[str, Adapter] = {}


def _register(adapter: Adapter) -> None:
    ADAPTERS[adapter.name] = adapter


_register(Adapter(
    "gcounter", GCounter.bottom(),
    [OpSpec("inc", lambda rng: (rng.randint(1, 3),),
            lambda X, i, by: X.inc_delta(i, by),
            lambda X, i, by: X.inc_full(i, by))]))

_register(Adapter(
    "pncounter", PNCounter.bottom(),
    [OpSpec("inc", lambda rng: (rng.randint(1, 3),),
            lambda X, i, by: X.inc_delta(i, by),
            lambda X, i, by: X.inc_full(i, by)),
     OpSpec("dec", lambda rng: (rng.randint(1, 3),),
            lambda X, i, by: X.dec_delta(i, by),
            lambda X, i, by: X.dec_full(i, by))]))

_register(Adapter(
    "gset", GSet.bottom(),
    [OpSpec("add", _elem,
            lambda X, i, e: X.add_delta(e),
            lambda X, i, e: X.add_full(e))]))

_register(Adapter(
    "2pset", TwoPSet.bottom(),
    [OpSpec("add", _elem,
            lambda X, i, e: X.add_delta(e),
            lambda X, i, e: X.add_full(e)),
     OpSpec("rmv", _elem,
            lambda X, i, e: X.rmv_delta(e),
            lambda X, i, e: X.rmv_full(e))]))

_register(Adapter(
    "aworset_tomb", AWORSetTombstone.bottom(),
    [OpSpec("add", _elem,
            lambda X, i, e: X.add_delta(i, e),
            lambda X, i, e: X.add_full(i, e)),
     OpSpec("rmv", _elem,
            lambda X, i, e: X.rmv_delta(i, e),
            lambda X, i, e: X.rmv_full(i, e))]))

_register(Adapter(
    "aworset", AWORSet.bottom(),
    [OpSpec("add", _elem,
            lambda X, i, e: X.add_delta(i, e),
            lambda X, i, e: X.add_full(i, e)),
     OpSpec("rmv", _elem,
            lambda X, i, e: X.rmv_delta(i, e),
            lambda X, i, e: X.rmv_full(i, e))]))

_register(Adapter(
    "rworset", RWORSet.bottom(),
    [OpSpec("add", _elem,
            lambda X, i, e: X.add_delta(i, e),
            lambda X, i, e: X.add_full(i, e)),
     OpSpec("rmv", _elem,
            lambda X, i, e: X.rmv_delta(i, e),
            lambda X, i, e: X.rmv_full(i, e))]))

_register(Adapter(
    "mvreg", MVRegister.bottom(),
    [OpSpec("write", _elem,
            lambda X, i, v: X.write_delta(i, v),
            lambda X, i, v: X.write_full(i, v))]))

_register(Adapter(
    "lwwreg", LWWRegister.bottom(),
    [OpSpec("write", _ts_elem,
            lambda X, i, ts, v: X.write_delta(i, ts, v),
            lambda X, i, ts, v: X.write_full(i, ts, v))]))

_register(Adapter(
    "lwwset", LWWSet.bottom(),
    [OpSpec("add", _ts_elem,
            lambda X, i, ts, e: X.add_delta(i, ts, e),
            lambda X, i, ts, e: X.add_full(i, ts, e)),
     OpSpec("rmv", _ts_elem,
            lambda X, i, ts, e: X.rmv_delta(i, ts, e),
            lambda X, i, ts, e: X.rmv_full(i, ts, e))]))

_register(Adapter(
    "ewflag", EWFlag.bottom(),
    [OpSpec("enable", lambda rng: (),
            lambda X, i: X.enable_delta(i),
            lambda X, i: X.enable_full(i)),
     OpSpec("disable", lambda rng: (),
            lambda X, i: X.disable_delta(i),
            lambda X, i: X.disable_full(i))]))

_register(Adapter(
    "dwflag", DWFlag.bottom(),
    [OpSpec("enable", lambda rng: (),
            lambda X, i: X.enable_delta(i),
            lambda X, i: X.enable_full(i)),
     OpSpec("disable", lambda rng: (),
            lambda X, i: X.disable_delta(i),
            lambda X, i: X.disable_full(i))]))

_register(Adapter(
    "ormap", ORMap.bottom(),
    [OpSpec("set_add", lambda rng: (rng.choice(KEYS), rng.choice(ELEMS)),
            lambda X, i, k, e: X.apply_delta(i, k, AWORSet, "add_delta", e),
            lambda X, i, k, e: X.apply_full(i, k, AWORSet, "add_delta", e)),
     OpSpec("set_rmv", lambda rng: (rng.choice(KEYS), rng.choice(ELEMS)),
            lambda X, i, k, e: X.apply_delta(i, k, AWORSet, "rmv_delta", e),
            lambda X, i, k, e: X.apply_full(i, k, AWORSet, "rmv_delta", e)),
     OpSpec("key_rmv", lambda rng: (rng.choice(KEYS),),
            lambda X, i, k: X.rmv_delta(i, k),
            lambda X, i, k: X.rmv_full(i, k))]))


def random_reachable_states(adapter: Adapter, rng: random.Random,
                            n_ops: int = 12) -> List[Any]:
    """Drive a multi-replica execution; return the per-replica states.

    Each step either applies a delta-mutation at a random replica
    (X' = X ⊔ mᵟ(X), Def. 3) or joins one replica's state into another
    (full-state shipping), yielding realistic concurrent states.
    """
    states = {r: adapter.bottom for r in REPLICAS}
    for _ in range(n_ops):
        r = rng.choice(REPLICAS)
        if rng.random() < 0.75:
            op = rng.choice(adapter.ops)
            args = op.make_args(rng)
            d = op.delta(states[r], r, *args)
            states[r] = states[r].join(d)
        else:
            src = rng.choice(REPLICAS)
            states[r] = states[r].join(states[src])
    return list(states.values())
