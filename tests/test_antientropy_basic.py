"""Algorithm 1 (basic anti-entropy): eventual convergence (Prop. 1) under
the §2 network model — loss, duplication, reordering — in both transitive
and direct modes, with the ship-full-state-every-k policy covering loss."""

import random

import pytest
import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from crdt_adapters import ADAPTERS, random_reachable_states
from repro.core import (AWORSet, BasicNode, GCounter, NetConfig, Simulator,
                        converged, run_to_convergence)


def _mk_sim(n, loss=0.0, dup=0.0, seed=0, transitive=True,
            ship_state_every=None, bottom=None, topology="full"):
    sim = Simulator(NetConfig(loss=loss, dup=dup, seed=seed))
    ids = [f"n{k}" for k in range(n)]
    nodes = []
    for k, i in enumerate(ids):
        if topology == "full":
            neigh = [j for j in ids if j != i]
        elif topology == "ring":
            neigh = [ids[(k + 1) % n], ids[(k - 1) % n]]
        else:
            raise ValueError(topology)
        nodes.append(sim.add_node(BasicNode(
            i, bottom, neigh, transitive=transitive,
            ship_state_every=ship_state_every)))
    return sim, nodes


def test_counter_converges_reliable_network():
    sim, nodes = _mk_sim(4, bottom=GCounter.bottom())
    rng = random.Random(1)
    for _ in range(30):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.inc_delta(i))
    total = sum(n.X.value() for n in [nodes[0]]) if False else None
    expected = sum(nx.X._get(nx.id) for nx in nodes)
    run_to_convergence(sim, nodes, interval=1.0)
    assert converged(nodes)
    assert nodes[0].X.value() == 30 == expected + (30 - expected)


@pytest.mark.parametrize("transitive", [True, False])
def test_counter_converges_lossy_duplicating_network(transitive):
    # Algorithm 1 clears D after send even when the message drops, so under
    # loss convergence needs the periodic full-state fallback (paper §4:
    # "subsumed by a less frequent sending of the full state").
    sim, nodes = _mk_sim(4, loss=0.35, dup=0.2, seed=7,
                         transitive=transitive, ship_state_every=5,
                         bottom=GCounter.bottom())
    rng = random.Random(2)
    for _ in range(25):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.inc_delta(i))
    run_to_convergence(sim, nodes, interval=1.0)
    assert nodes[0].X.value() == 25


def test_transitive_mode_propagates_through_ring():
    """Direct mode on a ring cannot converge by deltas alone (no full-state
    shipping, no transitivity) — transitive mode must."""
    sim, nodes = _mk_sim(5, topology="ring", transitive=True,
                         bottom=AWORSet.bottom())
    nodes[0].operation(lambda X: X.add_delta(nodes[0].id, "only-at-n0"))
    run_to_convergence(sim, nodes, interval=1.0)
    assert all(n.X.elements() == {"only-at-n0"} for n in nodes)


def test_partition_heals():
    sim, nodes = _mk_sim(4, bottom=GCounter.bottom(), ship_state_every=4)
    sim.add_partition(0.0, 50.0, ["n0", "n1"], ["n2", "n3"])
    for n in nodes:
        n.operation(lambda X, i=n.id: X.inc_delta(i, 2))
    for n in nodes:
        sim.every(1.0, n.on_periodic)
    sim.run_until(40.0)
    assert not converged(nodes)  # still partitioned
    sim.run_until(400.0)
    assert converged(nodes)
    assert nodes[0].X.value() == 8


def test_crash_recovery_durable_state_survives():
    sim, nodes = _mk_sim(3, bottom=GCounter.bottom(), ship_state_every=3)
    nodes[0].operation(lambda X: X.inc_delta("n0", 5))
    sim.crash("n0", downtime=5.0)
    sim.run_until(10.0)
    assert nodes[0].X.value() == 5       # durable X survived
    assert nodes[0].D == GCounter.bottom()  # volatile D lost
    run_to_convergence(sim, nodes, interval=1.0)
    assert nodes[1].X.value() == 5


@pytest.mark.parametrize("name", ["gcounter", "aworset", "mvreg", "ormap"])
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_workload_converges(name, seed):
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    sim, nodes = _mk_sim(3, loss=0.2, seed=seed, ship_state_every=4,
                         bottom=ad.bottom)
    for _ in range(15):
        n = rng.choice(nodes)
        op = rng.choice(ad.ops)
        args = op.make_args(rng)
        n.operation(lambda X, i=n.id, op=op, args=args: op.delta(X, i, *args))
    run_to_convergence(sim, nodes, interval=1.0)
    assert converged(nodes)
