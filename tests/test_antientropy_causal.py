"""Algorithm 2 (causal anti-entropy): convergence under loss/dup/reorder
WITHOUT full-state-per-k fallback (acks + retransmission recover lost
deltas), the causal delta-merging condition / Prop. 2 correspondence
(ghost-checked: joining a delta-interval == joining the sender's full
state), delta GC, and crash/recovery with durable (X, c)."""

import random

import pytest
import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from crdt_adapters import ADAPTERS, random_reachable_states
from repro.core import (AWORSet, CausalNode, GCounter, MVRegister, NetConfig,
                        Simulator, converged, run_to_convergence,
                        structural_size)


def _mk(n, loss=0.0, dup=0.0, seed=0, bottom=None, ghost=True, fanout=1):
    sim = Simulator(NetConfig(loss=loss, dup=dup, seed=seed))
    ids = [f"n{k}" for k in range(n)]
    rng = random.Random(seed + 1)
    nodes = [sim.add_node(CausalNode(
        i, bottom, [j for j in ids if j != i], rng=rng,
        ghost_check=ghost, fanout=fanout)) for i in ids]
    return sim, nodes


def _assert_no_ghost_failures(nodes):
    fails = [f for n in nodes for f in n.ghost_failures]
    assert not fails, fails


def test_converges_under_heavy_loss_without_state_fallback():
    sim, nodes = _mk(4, loss=0.4, dup=0.25, seed=3, bottom=GCounter.bottom())
    rng = random.Random(5)
    for _ in range(40):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.inc_delta(i))
    run_to_convergence(sim, nodes, interval=1.0, max_time=30_000)
    assert nodes[0].X.value() == 40
    _assert_no_ghost_failures(nodes)


def test_prop2_correspondence_ghost_check():
    """Prop. 2: every delta-interval join equals the corresponding
    full-state join — checked at every delivery on a lossy network."""
    sim, nodes = _mk(5, loss=0.3, dup=0.2, seed=11, bottom=AWORSet.bottom())
    rng = random.Random(13)
    elems = ["a", "b", "c"]
    for step in range(60):
        n = rng.choice(nodes)
        if rng.random() < 0.7:
            e = rng.choice(elems)
            n.operation(lambda X, i=n.id, e=e: X.add_delta(i, e))
        else:
            e = rng.choice(elems)
            n.operation(lambda X, i=n.id, e=e: X.rmv_delta(i, e))
        sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=30_000)
    _assert_no_ghost_failures(nodes)


def test_causal_context_stays_compressed():
    """Under Algorithm 2 the OR-Set causal context must compress to a bare
    version vector at quiescence (§7.2): gap-free delivery per sender."""
    sim, nodes = _mk(3, loss=0.2, seed=17, bottom=AWORSet.bottom())
    rng = random.Random(19)
    for _ in range(30):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.add_delta(i, rng.choice("xyz")))
        sim.run_for(0.3)
    run_to_convergence(sim, nodes, interval=1.0, max_time=30_000)
    for n in nodes:
        assert n.X.ctx.cloud == frozenset(), n.X.ctx
    _assert_no_ghost_failures(nodes)


def test_delta_gc_bounds_buffer():
    sim, nodes = _mk(3, loss=0.0, seed=23, bottom=GCounter.bottom())
    rng = random.Random(23)
    for k in range(50):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.inc_delta(i))
        sim.run_for(2.0)  # anti-entropy keeps pace
    run_to_convergence(sim, nodes, interval=1.0)
    for n in nodes:
        n.gc_deltas()
        # acked-by-all prefix was collected: buffer ≪ number of ops
        assert len(n.D) < 50 / 2


def test_crash_recovery_full_state_fallback():
    """After a crash, (D, A) are lost but (X, c) are durable; the paper's
    fallback (receiver behind the GC horizon gets the full state) must
    restore convergence, and the durable counter must prevent sequence
    reuse (the ack-skipping hazard of §6.1)."""
    sim, nodes = _mk(3, loss=0.1, seed=29, bottom=GCounter.bottom())
    rng = random.Random(31)
    for _ in range(10):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.inc_delta(i))
        sim.run_for(1.0)
    c_before = nodes[0].c
    sim.crash("n0", downtime=3.0)
    sim.run_until(sim.time + 5.0)
    assert nodes[0].c == c_before       # durable c survived
    assert nodes[0].D == {}             # volatile lost
    for _ in range(10):
        n = rng.choice(nodes)
        n.operation(lambda X, i=n.id: X.inc_delta(i))
        sim.run_for(1.0)
    run_to_convergence(sim, nodes, interval=1.0, max_time=30_000)
    assert nodes[0].X.value() == 20
    _assert_no_ghost_failures(nodes)


def test_delta_messages_much_smaller_than_state():
    """§9 intuition at the protocol level: with a large OR-Set state, the
    per-round delta payloads are far smaller than full-state payloads."""
    bottom = AWORSet.bottom()
    sim, nodes = _mk(3, loss=0.0, seed=37, bottom=bottom, ghost=False)
    # grow a big set everywhere first
    for k in range(200):
        nodes[k % 3].operation(
            lambda X, i=nodes[k % 3].id, k=k: X.add_delta(i, f"e{k}"))
    run_to_convergence(sim, nodes, interval=1.0)
    sim.run_for(40.0)  # let acks settle and GC clear the delta buffers
    for n in nodes:
        n.gc_deltas()
    state_size = structural_size(nodes[0].X)
    sim.stats.bytes_by_kind.clear()
    sim.stats.by_kind.clear()
    # now a handful of fresh updates, shipped as delta-intervals
    for k in range(5):
        nodes[0].operation(lambda X: X.add_delta("n0", f"fresh{k}"))
    run_to_convergence(sim, nodes, interval=1.0)
    delta_msgs = sim.stats.by_kind.get("delta", 0)
    delta_bytes = sim.stats.bytes_by_kind.get("delta", 0)
    assert delta_msgs > 0
    avg_delta = delta_bytes / delta_msgs
    assert avg_delta < state_size / 10, (avg_delta, state_size)


@pytest.mark.parametrize("name", ["gcounter", "aworset", "rworset", "mvreg",
                                  "ormap", "lwwset"])
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_random_workload_causal_convergence(name, seed):
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    sim, nodes = _mk(3, loss=0.25, dup=0.15, seed=seed, bottom=ad.bottom)
    for _ in range(20):
        n = rng.choice(nodes)
        op = rng.choice(ad.ops)
        args = op.make_args(rng)
        n.operation(lambda X, i=n.id, op=op, args=args: op.delta(X, i, *args))
        if rng.random() < 0.5:
            sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    _assert_no_ghost_failures(nodes)
