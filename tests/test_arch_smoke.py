"""Per-architecture smoke tests (REDUCED configs): one forward/train step
on CPU asserting output shapes and finiteness, plus prefill→decode
consistency (decode over a prefilled cache must reproduce the full-seq
forward logits at each position)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_batch
from repro.models import (decode_step, forward, init_model, prefill,
                          train_loss)


def _seq_for(cfg):
    # SSD needs seq % chunk == 0; prefix mode needs room for the prefix
    if cfg.ssm is not None:
        return max(16, cfg.ssm.chunk * 2)
    if cfg.input_mode == "tokens+prefix":
        return cfg.prefix_len + 8
    return 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, reduced=True)
    params, _specs = init_model(cfg, jax.random.PRNGKey(0))
    b, s = 2, _seq_for(cfg)
    batch = smoke_batch(cfg, b=b, s=s)
    logits, aux = jax.jit(lambda p, x: forward(cfg, p, x, remat=False))(
        params, batch)
    s_out = s - (cfg.prefix_len if cfg.input_mode == "tokens+prefix" else 0) \
        + (cfg.prefix_len if cfg.input_mode == "tokens+prefix" else 0)
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    """One SGD step on the smoke batch must reduce the loss (gradients flow
    through every block type)."""
    cfg = get_config(arch, reduced=True)
    params, _ = init_model(cfg, jax.random.PRNGKey(1))
    batch = smoke_batch(cfg, b=2, s=_seq_for(cfg))

    loss_fn = lambda p: train_loss(cfg, p, batch, remat=False)
    loss0, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss0))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert bool(gnorm > 0), "no gradient signal"
    lr = 2e-2 / max(1e-6, float(gnorm))
    params2 = jax.tree_util.tree_map(
        lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    loss1 = jax.jit(lambda p: train_loss(cfg, p, batch, remat=False))(params2)
    assert float(loss1) < float(loss0), (float(loss0), float(loss1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_full_forward(arch):
    """Serve path correctness: logits from incremental decode equal the
    full-sequence forward logits (same params, same tokens)."""
    cfg = get_config(arch, reduced=True)
    if cfg.input_mode == "tokens+prefix":
        pytest.skip("prefix mode exercises decode via the text-only path")
    params, _ = init_model(cfg, jax.random.PRNGKey(2))
    b = 2
    s = _seq_for(cfg)
    batch = smoke_batch(cfg, b=b, s=s, train=False)

    full_logits, _aux = forward(cfg, params, dict(batch), remat=False)

    split = s // 2
    if cfg.ssm is not None:   # SSD prefill needs chunk-aligned length
        split = (split // cfg.ssm.chunk) * cfg.ssm.chunk or cfg.ssm.chunk
    if cfg.input_mode == "embeds":
        prompt = {"embeds": batch["embeds"][:, :split]}
        rest = [batch["embeds"][:, i:i + 1] for i in range(split, s)]
    else:
        prompt = {"tokens": batch["tokens"][:, :split]}
        rest = [batch["tokens"][:, i:i + 1] for i in range(split, s)]

    logits_p, caches = prefill(cfg, params, prompt, max_len=s)
    np.testing.assert_allclose(np.asarray(logits_p[:, 0]),
                               np.asarray(full_logits[:, split - 1]),
                               rtol=2e-2, atol=2e-2)
    for k, tok in enumerate(rest):
        pos = jnp.full((b, 1), split + k, jnp.int32)
        logits_d, caches = decode_step(cfg, params, tok, pos, caches)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]),
            np.asarray(full_logits[:, split + k]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode step {k} diverged from full forward")


def test_param_counts_sane():
    """Full-config parameter counts are in the published ballpark."""
    expect = {
        "mixtral-8x22b": (141e9, 0.35),
        "deepseek-v2-236b": (236e9, 0.35),
        "qwen2-1.5b": (1.5e9, 0.45),
        "qwen1.5-0.5b": (0.5e9, 0.45),
        "gemma2-27b": (27e9, 0.40),
        "mamba2-130m": (130e6, 0.45),
        "jamba-v0.1-52b": (52e9, 0.40),
        "stablelm-1.6b": (1.6e9, 0.45),
        "phi-3-vision-4.2b": (4.2e9, 0.45),
        "musicgen-large": (3.3e9, 0.75),
    }
    for arch, (want, tol) in expect.items():
        cfg = get_config(arch)
        total, active = cfg.param_counts()
        assert abs(total - want) / want < tol, (arch, total, want)
        assert active <= total
