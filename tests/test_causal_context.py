"""Causal-context compression (§7.2): the compressed representation
(version-vector prefix + dot cloud) must be semantically identical to an
explicit set of dots, and compress to a bare version vector under causally
consistent (gap-free) histories."""

import random

import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import CausalContext

RIDS = ["a", "b", "c"]


def _random_dots(rng, n):
    return [(rng.choice(RIDS), rng.randint(1, 12)) for _ in range(n)]


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_compressed_equals_model_set(seed):
    rng = random.Random(seed)
    dots = _random_dots(rng, rng.randint(0, 25))
    cc = CausalContext.from_dots(dots)
    model = set(dots)
    # contains() agrees with the model on all queried dots
    for i in RIDS:
        for k in range(1, 15):
            assert cc.contains((i, k)) == ((i, k) in model) or cc.contains((i, k)) == ((i, k) in model), (i, k)
    # ... and the reconstructed explicit dot set is exactly the model
    assert cc.dots() == frozenset(model)
    # max_for / next_dot agree with the model
    for i in RIDS:
        ks = [k for (j, k) in model if j == i]
        assert cc.max_for(i) == (max(ks) if ks else 0)
        assert cc.next_dot(i) == (i, (max(ks) if ks else 0) + 1)


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_join_is_union(seed):
    rng = random.Random(seed)
    d1 = _random_dots(rng, rng.randint(0, 15))
    d2 = _random_dots(rng, rng.randint(0, 15))
    a = CausalContext.from_dots(d1)
    b = CausalContext.from_dots(d2)
    assert a.join(b).dots() == frozenset(d1) | frozenset(d2)
    assert a.join(b) == b.join(a)
    assert a.join(a) == a
    assert a.join(CausalContext.bottom()) == a


@settings(max_examples=100, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_gap_free_history_compresses_to_version_vector(seed):
    """Under causal anti-entropy contexts are contiguous per replica
    (paper: 1 ≤ n ≤ max{k | (j,k) ∈ cᵢ} ⇒ (j,n) ∈ cᵢ) — the cloud must be
    empty and the whole context lives in the version vector."""
    rng = random.Random(seed)
    cc = CausalContext.bottom()
    counters = {i: 0 for i in RIDS}
    for _ in range(rng.randint(0, 30)):
        i = rng.choice(RIDS)
        counters[i] += 1
        cc = cc.add_dot((i, counters[i]))
    assert cc.cloud == frozenset()
    assert cc.vv_dict() == {i: n for i, n in counters.items() if n > 0}


def test_cloud_absorbed_when_gap_fills():
    cc = CausalContext.from_dots([("a", 1), ("a", 3), ("a", 4)])
    assert cc.vv_dict() == {"a": 1}
    assert cc.cloud == frozenset({("a", 3), ("a", 4)})
    cc2 = cc.add_dot(("a", 2))  # gap fills -> full absorption
    assert cc2.vv_dict() == {"a": 4}
    assert cc2.cloud == frozenset()


def test_representation_canonical_for_equality():
    """Equal dot sets must compare equal regardless of insertion order —
    needed because CRDT equality is structural."""
    import itertools
    dots = [("a", 2), ("a", 1), ("b", 1), ("a", 4)]
    reference = CausalContext.from_dots(dots)
    for perm in itertools.permutations(dots):
        assert CausalContext.from_dots(perm) == reference


@settings(max_examples=150, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_leq_is_the_lattice_order(seed):
    """The direct dominance check must equal the definitional partial
    order x ⊑ y ⇔ x ⊔ y = y, on arbitrary vv/cloud splits."""
    rng = random.Random(seed)
    a = CausalContext.from_dots(_random_dots(rng, rng.randint(0, 20)))
    b = CausalContext.from_dots(_random_dots(rng, rng.randint(0, 20)))
    for x, y in [(a, b), (b, a), (a, a.join(b)), (a.join(b), a)]:
        assert x.leq(y) == (y.join(x) == y), (x, y)
