"""Delta-interval checkpointing: snapshot ⊔ delta-log restore, atomicity
under crash (orphan temp files), idempotent re-restore, GC, and the
pytree bridge used for real model/optimizer state."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (DeltaCheckpointStore, pytree_from_state,
                              state_from_pytree)
from repro.core.tensor_lattice import TensorState, chunk_tensor


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer0": {"w": rng.normal(size=(8, 8)).astype(np.float32),
                   "b": rng.normal(size=(8,)).astype(np.float32)},
        "emb": rng.normal(size=(16, 4)).astype(np.float32),
    }


def test_snapshot_restore_roundtrip(tmp_path):
    store = DeltaCheckpointStore(str(tmp_path))
    state, spec = state_from_pytree(_params(), chunk_size=16, rank=0)
    store.save_snapshot(state, seq=0)
    restored, seq = store.restore()
    assert seq == 0
    assert restored == state
    back = pytree_from_state(restored, spec)
    for (pa, la), (pb, lb) in zip(
            jax.tree_util.tree_flatten_with_path(_params())[0][0:0] or [],
            []):
        pass
    assert np.allclose(back["layer0"]["w"], _params()["layer0"]["w"])
    assert np.allclose(back["emb"], _params()["emb"])


def test_delta_log_restore(tmp_path):
    store = DeltaCheckpointStore(str(tmp_path))
    state, spec = state_from_pytree(_params(), chunk_size=16, rank=0)
    store.save_snapshot(state, seq=0)
    # three incremental updates, each checkpointed as a delta only
    for k in range(1, 4):
        new_emb = np.full((16, 4), float(k), np.float32)
        delta = state.write_delta(0, "['emb']", new_emb)
        state = state.join(delta)
        store.append_delta(delta, seq=k)
    restored, seq = store.restore()
    assert seq == 3
    assert restored == state
    back = pytree_from_state(restored, spec)
    assert np.allclose(back["emb"], 3.0)


def test_delta_log_must_be_contiguous(tmp_path):
    """The on-disk causal delta-merging condition: no gaps in the log."""
    store = DeltaCheckpointStore(str(tmp_path))
    state, _ = state_from_pytree(_params(), chunk_size=16, rank=0)
    store.save_snapshot(state, seq=0)
    delta = state.write_delta(0, "['emb']", np.ones((16, 4), np.float32))
    with pytest.raises(AssertionError):
        store.append_delta(delta, seq=5)  # gap


def test_crash_leaves_consistent_prefix(tmp_path):
    store = DeltaCheckpointStore(str(tmp_path))
    state, _ = state_from_pytree(_params(), chunk_size=16, rank=0)
    store.save_snapshot(state, seq=0)
    d1 = state.write_delta(0, "['emb']", np.ones((16, 4), np.float32))
    store.append_delta(d1, seq=1)
    # simulate a crash mid-write: an orphan temp file appears
    with open(os.path.join(str(tmp_path), "junk.tmp"), "wb") as f:
        f.write(b"partial garbage")
    restored, seq = store.restore()
    assert seq == 1
    assert restored == state.join(d1)


def test_restore_is_idempotent(tmp_path):
    store = DeltaCheckpointStore(str(tmp_path))
    state, _ = state_from_pytree(_params(), chunk_size=16, rank=0)
    store.save_snapshot(state, seq=0)
    d1 = state.write_delta(0, "['emb']", np.ones((16, 4), np.float32))
    store.append_delta(d1, seq=1)
    r1, _ = store.restore()
    r2, _ = store.restore()
    assert r1 == r2
    # joining a restore into live state is harmless (idempotence)
    live = state.join(d1)
    assert live.join(r1) == live


def test_gc_keeps_restorability(tmp_path):
    store = DeltaCheckpointStore(str(tmp_path))
    state, _ = state_from_pytree(_params(), chunk_size=16, rank=0)
    store.save_snapshot(state, seq=0)
    for k in range(1, 4):
        delta = state.write_delta(0, "['emb']",
                                  np.full((16, 4), float(k), np.float32))
        state = state.join(delta)
        store.append_delta(delta, seq=k)
    store.save_snapshot(state, seq=4)   # consolidating snapshot
    store.gc(keep_snapshots=1)
    files = os.listdir(str(tmp_path))
    assert not any(f.startswith("delta-") for f in files)
    assert sum(f.startswith("snapshot-") for f in files) == 1
    restored, _ = store.restore()
    assert restored == state
