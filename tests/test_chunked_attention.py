"""Chunked (trace-time flash) attention == naive attention, across GQA,
windows, softcaps, and uneven block splits; and the whole-model forward
must be invariant to the attention implementation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_batch
from repro.models import forward, init_model
from repro.models.attention import _mha_chunked, _mha_core
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=1, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("window", [None, 48, 128])
@pytest.mark.parametrize("block", [32, 64, 256])
def test_chunked_matches_naive(window, block):
    cfg = _cfg(attn_block=block)
    rng = np.random.default_rng(0)
    b, s, H, KV, hd = 2, 256, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, KV, hd)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    naive = _mha_core(cfg, q, k, v, pos, pos, window)
    chunked = _mha_chunked(cfg, q, k, v, pos, pos, window, block)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


def test_chunked_with_softcap_and_query_scale():
    cfg = _cfg(attn_softcap=30.0, query_scale=0.125, attn_block=64)
    rng = np.random.default_rng(1)
    b, s = 1, 128
    q = jnp.asarray(rng.normal(size=(b, s, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, 2, 16)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    naive = _mha_core(cfg, q, k, v, pos, pos, None)
    chunked = _mha_chunked(cfg, q, k, v, pos, pos, None, 64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


def test_indivisible_length_falls_back():
    cfg = _cfg()
    rng = np.random.default_rng(2)
    b, s = 1, 100   # not divisible by 64
    q = jnp.asarray(rng.normal(size=(b, s, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, 2, 16)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out = _mha_chunked(cfg, q, k, v, pos, pos, None, 64)
    ref = _mha_core(cfg, q, k, v, pos, pos, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "gemma2-27b",
                                  "qwen2-1.5b"])
def test_model_forward_invariant_to_attn_impl(arch):
    cfg = get_config(arch, reduced=True)
    cfg_chunked = dataclasses.replace(cfg, attn_impl="chunked",
                                      attn_block=8)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, b=2, s=16, train=False)
    l0, _ = forward(cfg, params, batch, remat=False)
    l1, _ = forward(cfg_chunked, params, batch, remat=False)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=3e-4, atol=3e-4)
