"""Datatype-specific semantics from the paper: add-wins (§7), remove-wins,
multi-value register sibling semantics (§8), counter values, observed-remove
behaviour, ORMap composition (the Riak-DT-Map use case of §1)."""

from repro.core import (AWORSet, AWORSetTombstone, DWFlag, EWFlag, GCounter,
                        GSet, LWWRegister, LWWSet, MVRegister, ORMap,
                        PNCounter, RWORSet, TwoPSet)


# ---------------------------------------------------------------------------
# Counters
# ---------------------------------------------------------------------------

def test_gcounter_concurrent_increments_all_counted():
    a = GCounter.bottom().join(GCounter.bottom().inc_delta("a", 3))
    b = GCounter.bottom().join(GCounter.bottom().inc_delta("b", 4))
    assert a.join(b).value() == 7


def test_gcounter_duplicate_delta_is_idempotent():
    X = GCounter.bottom()
    d = X.inc_delta("a")
    X = X.join(d).join(d).join(d)  # re-delivered duplicates
    assert X.value() == 1


def test_pncounter():
    X = PNCounter.bottom()
    X = X.join(X.inc_delta("a", 5))
    X = X.join(X.dec_delta("b", 2))
    assert X.value() == 3


# ---------------------------------------------------------------------------
# Add-wins OR-Set — both versions agree on visible semantics
# ---------------------------------------------------------------------------

def _concurrent_add_rmv(cls):
    """Replicas a and b sync on {x}; then a removes x while b re-adds x."""
    base = cls.bottom()
    base = base.join(base.add_delta("a", "x"))
    ra = base
    rb = base
    ra = ra.join(ra.rmv_delta("a", "x"))      # remove at a
    rb = rb.join(rb.add_delta("b", "x"))      # concurrent add at b
    return ra.join(rb)


def test_aworset_add_wins_optimized():
    assert _concurrent_add_rmv(AWORSet).elements() == {"x"}


def test_aworset_add_wins_tombstone():
    assert _concurrent_add_rmv(AWORSetTombstone).elements() == {"x"}


def test_rworset_remove_wins():
    assert _concurrent_add_rmv(RWORSet).elements() == set()


def test_aworset_remove_only_affects_observed_adds():
    """Remove only affects causally preceding adds (paper §7)."""
    a = AWORSet.bottom()
    b = AWORSet.bottom()
    b = b.join(b.add_delta("b", "x"))
    # a never saw b's add; a's remove of x is a no-op delta
    d = a.rmv_delta("a", "x")
    assert a.join(d).join(b).elements() == {"x"}


def test_aworset_sequential_add_remove():
    X = AWORSet.bottom()
    X = X.join(X.add_delta("a", "x"))
    X = X.join(X.add_delta("a", "y"))
    X = X.join(X.rmv_delta("a", "x"))
    assert X.elements() == {"y"}
    # removed element's triple is gone from the store (optimized: shrinks)
    assert len(X.store.entries) == 1


def test_aworset_tombstone_state_grows_but_optimized_shrinks():
    t = AWORSetTombstone.bottom()
    o = AWORSet.bottom()
    for k in range(5):
        t = t.join(t.add_delta("a", f"e{k}"))
        o = o.join(o.add_delta("a", f"e{k}"))
    for k in range(5):
        t = t.join(t.rmv_delta("a", f"e{k}"))
        o = o.join(o.rmv_delta("a", f"e{k}"))
    assert t.elements() == o.elements() == set()
    assert len(t.s) == 5               # tombstone version retains all triples
    assert len(o.store.entries) == 0   # optimized version shrank to nothing
    # and the optimized causal context compressed into a bare version vector
    assert o.ctx.cloud == frozenset()
    assert o.ctx.vv_dict() == {"a": 5}


def test_reissued_tag_does_not_resurrect():
    """Adding again after removal issues a FRESH dot (from the causal
    context), so the old removal cannot cancel the new add."""
    X = AWORSet.bottom()
    X = X.join(X.add_delta("a", "x"))      # dot (a,1)
    X = X.join(X.rmv_delta("a", "x"))      # (a,1) covered
    X = X.join(X.add_delta("a", "x"))      # must use dot (a,2)
    assert X.elements() == {"x"}
    assert X.store.entries[0][0] == ("a", 2)


# ---------------------------------------------------------------------------
# Multi-value register (Fig. 4)
# ---------------------------------------------------------------------------

def test_mvreg_concurrent_writes_become_siblings():
    base = MVRegister.bottom()
    a = base.join(base.write_delta("a", 1))
    b = base.join(base.write_delta("b", 2))
    joined = a.join(b)
    assert joined.read() == {1, 2}
    # a later write at either replica overwrites both siblings
    final = joined.join(joined.write_delta("a", 3))
    assert final.read() == {3}


def test_mvreg_sequential_overwrite():
    X = MVRegister.bottom()
    X = X.join(X.write_delta("a", 10))
    X = X.join(X.write_delta("a", 11))
    assert X.read() == {11}
    assert len(X.store.entries) == 1


def test_mvreg_no_version_vectors_in_state():
    """§9: the optimized MVR carries scalar dots, not per-value version
    vectors — worst-case meta-data Õ(|I|), not Õ(|I|²)."""
    X = MVRegister.bottom()
    for r in [f"r{k}" for k in range(8)]:
        X = X.join(X.write_delta(r, r))  # 8 concurrent-ish writers
    for dot, _ in X.store.entries:
        assert isinstance(dot, tuple) and len(dot) == 2  # a single scalar tag


# ---------------------------------------------------------------------------
# LWW / flags / sets
# ---------------------------------------------------------------------------

def test_lww_register_highest_stamp_wins():
    a = LWWRegister.bottom().write_delta("a", 5, "va")
    b = LWWRegister.bottom().write_delta("b", 7, "vb")
    assert a.join(b).read() == "vb"
    assert b.join(a).read() == "vb"


def test_lww_register_tie_broken_by_replica_id():
    a = LWWRegister.bottom().write_delta("a", 5, "va")
    b = LWWRegister.bottom().write_delta("b", 5, "vb")
    assert a.join(b).read() == "vb"  # 'b' > 'a'


def test_lwwset():
    X = LWWSet.bottom()
    X = X.join(X.add_delta("a", 1, "x"))
    X = X.join(X.rmv_delta("a", 2, "x"))
    X = X.join(X.add_delta("b", 3, "y"))
    assert X.elements() == {"y"}


def test_2pset_remove_is_permanent():
    X = TwoPSet.bottom()
    X = X.join(X.add_delta("x"))
    X = X.join(X.rmv_delta("x"))
    X = X.join(X.add_delta("x"))
    assert X.elements() == set()


def test_flags():
    base = EWFlag.bottom()
    e = base.join(base.enable_delta("a"))
    d = base.join(base.disable_delta("b"))
    assert e.join(d).read() is True  # enable wins

    base = DWFlag.bottom()
    base = base.join(base.enable_delta("a"))
    e = base.join(base.enable_delta("a"))
    dd = base.join(base.disable_delta("b"))
    assert e.join(dd).read() is False  # disable wins


# ---------------------------------------------------------------------------
# ORMap composition
# ---------------------------------------------------------------------------

def test_ormap_embedded_sets():
    X = ORMap.bottom()
    X = X.join(X.apply_delta("a", "tags", AWORSet, "add_delta", "t1"))
    X = X.join(X.apply_delta("a", "tags", AWORSet, "add_delta", "t2"))
    X = X.join(X.apply_delta("b", "users", AWORSet, "add_delta", "u1"))
    assert X.keys() == {"tags", "users"}
    assert X.get_value("tags", AWORSet).elements() == {"t1", "t2"}
    assert X.get_value("users", AWORSet).elements() == {"u1"}


def test_ormap_key_removal_is_observed_remove():
    base = ORMap.bottom()
    base = base.join(base.apply_delta("a", "k", AWORSet, "add_delta", "v1"))
    ra = base.join(base.rmv_delta("a", "k"))              # remove key at a
    rb = base.join(base.apply_delta("b", "k", AWORSet, "add_delta", "v2"))
    joined = ra.join(rb)
    # add-wins inside the map: the concurrently-added element survives,
    # the observed one is gone
    assert joined.get_value("k", AWORSet).elements() == {"v2"}


def test_ormap_shared_context_keeps_dots_unique():
    X = ORMap.bottom()
    X = X.join(X.apply_delta("a", "k1", AWORSet, "add_delta", "v"))
    X = X.join(X.apply_delta("a", "k2", AWORSet, "add_delta", "v"))
    dots = X.store.all_dots()
    assert len(dots) == 2  # distinct dots across keys (shared context)
