"""Delta-state decomposition law (paper §4.1):

    m(X) = X ⊔ mᵟ(X)   for every mutator of every datatype,

checked on randomly-reached (including concurrent) states. Also checks the
paper's efficiency motivation: deltas are no larger than the full state the
standard mutator would ship."""

import random

import pytest
import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from crdt_adapters import ADAPTERS, REPLICAS, random_reachable_states
from repro.core import structural_size

ADAPTER_NAMES = sorted(ADAPTERS)


@pytest.mark.parametrize("name", ADAPTER_NAMES)
@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_decomposition_law(name, seed):
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    states = random_reachable_states(ad, rng, n_ops=12)
    X = rng.choice(states)
    r = rng.choice(REPLICAS)
    for op in ad.ops:
        args = op.make_args(rng)
        full_result = op.full(X, r, *args)
        delta = op.delta(X, r, *args)
        assert full_result == X.join(delta), (
            f"{name}.{op.name}: m(X) != X ⊔ mᵟ(X)")


@pytest.mark.parametrize("name", ADAPTER_NAMES)
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_delta_not_larger_than_state(name, seed):
    """size(mᵟ(X)) ≤ size(m(X)) — and ≪ on grown states for the paper's
    flagship examples (counter: one entry vs the whole map)."""
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    states = random_reachable_states(ad, rng, n_ops=14)
    X = rng.choice(states)
    r = rng.choice(REPLICAS)
    op = rng.choice(ad.ops)
    args = op.make_args(rng)
    # Constant slack: a delta's causal context is a (possibly uncompressed)
    # dot cloud while the grown state's context compresses to a version
    # vector (§7.2) — for constant-size datatypes (flags, registers) that
    # costs a few atoms; the claim is asymptotic, checked strictly below.
    assert structural_size(op.delta(X, r, *args)) <= \
        structural_size(op.full(X, r, *args)) + 4


def test_counter_delta_is_single_entry():
    """Fig. 2: incᵟ returns exactly one map entry regardless of |I|."""
    from repro.core import GCounter
    X = GCounter.bottom()
    for i in range(20):
        X = X.join(X.inc_delta(f"r{i}"))
    d = X.inc_delta("r7")
    assert len(d.entries) == 1
    assert len(X.entries) == 20
    assert X.join(d).value() == X.value() + 1
