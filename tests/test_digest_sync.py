"""Digest-driven request/response anti-entropy.

The load-bearing properties:

* a digest response contains **only** rows the requester provably lacks
  (version-dominated tensor rows and hash-equal opaque keys never ship),
  and joining it is join-equivalent to joining the responder's full state
  — the reason pull-sync preserves the causal merging condition;
* the ``known_versions`` / ``known_opaque`` filter applied at
  ``encode_store`` time produces exactly ``digest_diff``'s answer (the
  object-mode oracle), eliding fully-covered keys from the frame;
* replicas running pure pull (``digest-sync``) converge — object mode and
  wire mode, single-object and keyed, basic and causal — and a causal
  pure-pull replica's delta buffer stays bounded even though no acks flow;
* a reconnecting replica catches up for strictly (and massively) fewer
  measured bytes than the full-state fallback would ship;
* the hybrid (``bp+rr+digest-sync:k``) still pushes delta-intervals and
  keeps the ack/GC horizon advancing.
"""

import random

import numpy as np
import pytest

from repro.core import (Compose, DigestExchange, GCounter, GSet,
                        LatticeStore, NetConfig, POLICY_SPECS, Replica,
                        Simulator, StoreDigest, StoreReplica, converged,
                        digest_diff, make_policy, opaque_hash,
                        run_to_convergence, store_digest)
from repro.core.tensor_lattice import TensorState, chunk_tensor
from repro.wire import (WireCodec, decode_digest, decode_store,
                        encode_digest, encode_store)


def _tensor_store(n_keys=6, n_chunks=4, chunk=8, seed=0, version=1):
    rng = np.random.default_rng(seed)
    return LatticeStore.of({
        f"obj{i}": TensorState.of({"w": chunk_tensor(
            rng.normal(size=(n_chunks * chunk,)).astype(np.float32),
            chunk, version=version)})
        for i in range(n_keys)})


def _advance(store: LatticeStore, keys, rank=1, seed=9):
    """Rewrite one chunk on each of ``keys`` — the 'fresh rows' a stale
    peer is missing."""
    rng = np.random.default_rng(seed)
    out = store
    for k, key in enumerate(keys):
        cur = out.get(key, TensorState).as_dict()["w"]
        n_chunks, csz = cur.shape
        d = out.get(key, TensorState).write_delta(
            rank, "w", rng.normal(size=(1, csz)).astype(np.float32),
            chunk_idx=np.array([k % n_chunks]))
        out = out.join(LatticeStore.key_delta(key, d))
    return out


# ---------------------------------------------------------------------------
# Digest summaries and the diff
# ---------------------------------------------------------------------------

def test_store_digest_covers_tensor_and_opaque_keys():
    store = _tensor_store(2).join(LatticeStore.of(
        {"cnt": GCounter.bottom().inc_delta("r0")}))
    dig = store_digest(store)
    assert set(dig.tensors) == {("obj0", "w"), ("obj1", "w")}
    assert set(dig.opaque) == {"cnt"}
    for vers in dig.tensors.values():
        assert vers.shape == (4,) and np.all(vers == 1)


def test_digest_diff_ships_only_dominating_rows():
    stale = _tensor_store()
    fresh = _advance(stale, ["obj1", "obj4"])
    d = digest_diff(fresh, store_digest(stale))
    assert d.keys() == {"obj1", "obj4"}       # untouched keys elided whole
    stale_dig = store_digest(stale)
    for key in d.keys():
        ct = d.get(key).as_dict()["w"]
        assert ct.is_sparse and ct.idx.size == 1   # exactly the fresh row
        assert np.all(np.asarray(ct.vers)
                      > stale_dig.tensors[(key, "w")][ct.idx])
    # join equivalence to the full state — the merging-condition argument
    assert stale.join(d) == stale.join(fresh)


def test_digest_diff_is_symmetric_on_divergent_stores():
    base = _tensor_store()
    a = _advance(base, ["obj0"], rank=1, seed=1)
    b = _advance(base, ["obj5"], rank=2, seed=2)
    dab = digest_diff(a, store_digest(b))      # what b lacks, from a
    dba = digest_diff(b, store_digest(a))
    assert b.join(dab) == a.join(dba) == a.join(b)


def test_digest_diff_opaque_by_content_hash():
    a = LatticeStore.of({"cnt": GCounter.bottom().inc_delta("r0"),
                         "set": GSet.bottom().add_delta("x")})
    b = LatticeStore.of({"cnt": GCounter.bottom().inc_delta("r0")})
    d = digest_diff(a, store_digest(b))
    assert d.keys() == {"set"}                # hash-equal key never ships
    assert b.join(d) == b.join(a)
    # unknown key ships wholesale
    assert digest_diff(a, StoreDigest()).keys() == {"cnt", "set"}
    assert opaque_hash(a.get("cnt")) == opaque_hash(b.get("cnt"))


def test_digest_diff_requester_ahead_ships_nothing():
    stale = _tensor_store()
    fresh = _advance(stale, ["obj2"])
    assert digest_diff(stale, store_digest(fresh)) == LatticeStore.bottom()


# ---------------------------------------------------------------------------
# Encode-time known_versions / known_opaque filtering
# ---------------------------------------------------------------------------

def test_encode_store_known_versions_matches_digest_diff_oracle():
    stale = _tensor_store().join(LatticeStore.of(
        {"cnt": GCounter.bottom().inc_delta("r0")}))
    fresh = _advance(stale, ["obj0", "obj3"]).join(LatticeStore.of(
        {"cnt": GCounter.bottom().inc_delta("r1")}))
    dig = store_digest(stale)
    wire_delta = decode_store(encode_store(
        fresh, known_versions=dig.tensors, known_opaque=dig.opaque))
    assert wire_delta == digest_diff(fresh, dig)
    assert stale.join(wire_delta) == stale.join(fresh)
    # covered tensor keys are elided from the frame entirely
    assert "obj1" not in wire_delta.keys()


def test_encode_store_known_filter_is_off_by_default():
    store = _tensor_store(3)
    assert encode_store(store) == encode_store(store, known_versions=None)
    assert decode_store(encode_store(store)) == store


def test_encode_store_known_filter_handles_shorter_digest_column():
    """Rows beyond the digest's column length read as ⊥ and always ship
    (the requester's tensor is shorter than the responder's)."""
    store = _tensor_store(1, n_chunks=6)
    dig = store_digest(_tensor_store(1, n_chunks=4, version=2))
    dec = decode_store(encode_store(store, known_versions=dig.tensors,
                                    known_opaque=dig.opaque))
    ct = dec.get("obj0").as_dict()["w"]
    assert sorted(np.asarray(ct.idx).tolist()) == [4, 5]


def test_digest_frame_roundtrip_includes_opaque_hashes():
    store = _tensor_store(2).join(LatticeStore.of(
        {"cnt": GCounter.bottom().inc_delta("r0")}))
    dig = store_digest(store)
    assert decode_digest(encode_digest(dig)) == dig
    assert decode_digest(encode_digest(store)) == dig   # store convenience
    assert decode_digest(encode_digest(StoreDigest())) == StoreDigest()


def test_wirecodec_routes_digest_request_and_response():
    wc = WireCodec()
    stale = _tensor_store()
    fresh = _advance(stale, ["obj2"])
    dig = store_digest(stale)
    req = wc.encode_msg(("digest", dig))
    assert req.kind == "digest"
    kind, got = wc.decode_msg(req)
    assert kind == "digest" and got == dig
    resp = wc.encode_msg(("digest-resp", fresh, dig))
    assert resp.kind == "digest-resp"
    kind, delta = wc.decode_msg(resp)
    assert kind == "digest-resp"
    assert delta == digest_diff(fresh, dig)
    assert stale.join(delta) == stale.join(fresh)


# ---------------------------------------------------------------------------
# Engine: pure pull and hybrid exchanges
# ---------------------------------------------------------------------------

def _mesh(policy_spec, *, wire=None, causal=True, bottom=None, seed=3,
          loss=0.2, dup=0.1, keyed=False):
    sim = Simulator(NetConfig(loss=loss, dup=dup, seed=seed))
    ids = ["a", "b", "c"]
    if keyed:
        nodes = [sim.add_node(StoreReplica(
            i, [j for j in ids if j != i], causal=causal,
            policy=make_policy(policy_spec), rng=random.Random(seed + 1),
            wire=wire)) for i in ids]
    else:
        nodes = [sim.add_node(Replica(
            i, bottom, [j for j in ids if j != i], causal=causal,
            policy=make_policy(policy_spec), rng=random.Random(seed + 1),
            wire=wire)) for i in ids]
    return sim, nodes


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("wire", [None, WireCodec()])
def test_pure_pull_converges_single_object(causal, wire):
    sim, nodes = _mesh("digest-sync", wire=wire, causal=causal,
                       bottom=GSet.bottom())
    for k in range(12):
        nodes[k % 3].operation(lambda X, k=k: X.add_delta(f"e{k}"))
        sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    assert nodes[0].X.elements() == {f"e{k}" for k in range(12)}
    # the exchange really is pull-shaped: zero push payloads on the wire
    for kind in ("delta", "state"):
        assert sim.stats.bytes_by_kind.get(kind, 0) == 0
    assert sim.stats.bytes_by_kind.get("digest", 0) > 0
    assert sim.stats.bytes_by_kind.get("digest-resp", 0) > 0


def test_pure_pull_converges_keyed_tensor_store_over_wire():
    sim, nodes = _mesh("digest-sync", wire=WireCodec(), keyed=True,
                       loss=0.15, dup=0.0)
    rng = np.random.default_rng(0)
    for s in range(9):
        nodes[s % 3].update(f"obj{s}", TensorState, "write_delta", s % 3,
                            "w", rng.normal(size=(24,)).astype(np.float32),
                            None, 8)
        sim.run_for(0.5)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)


def test_causal_pure_pull_buffer_stays_bounded_without_acks():
    """No pushes ⇒ no acks ⇒ the ack-driven GC horizon never moves; the
    engine clears the (unused) buffer each pull round instead."""
    sim, nodes = _mesh("digest-sync", causal=True, bottom=GCounter.bottom(),
                       loss=0.0, dup=0.0)
    for k in range(40):
        nodes[0].operation(lambda X: X.inc_delta("a"))
        for n in nodes:
            n.on_periodic()
        sim.run_for(2.0)
        assert all(len(n.entries) <= 1 for n in nodes)
    assert converged(nodes)
    assert nodes[0].X.value() == 40


def test_hybrid_pushes_and_gc_horizon_advances():
    """digest-sync:k composed with push policies: push rounds ship
    intervals and acks flow, so buffered entries still get GC'd."""
    sim, nodes = _mesh("bp+rr+digest-sync:5", causal=True,
                       bottom=GSet.bottom(), loss=0.1, dup=0.0)
    for k in range(15):
        nodes[k % 3].operation(lambda X, k=k: X.add_delta(f"e{k}"))
        sim.run_for(0.6)
    run_to_convergence(sim, nodes, interval=1.0, max_time=60_000)
    assert converged(nodes)
    assert sim.stats.bytes_by_kind.get("ack", 0) > 0     # pushes acked
    for n in nodes:
        n.gc_deltas()
    assert all(len(n.entries) < 15 for n in nodes)       # horizon moved


def test_read_only_replica_catches_up_via_pull():
    """The read-heavy replica story: a node that never writes (and is
    never pushed to) still converges by pulling."""
    sim = Simulator(NetConfig(loss=0.0, seed=4))
    writer = sim.add_node(Replica("w", GSet.bottom(), [], causal=True))
    reader = sim.add_node(Replica("r", GSet.bottom(), ["w"], causal=True,
                                  policy=make_policy("digest-sync"),
                                  rng=random.Random(1)))
    for k in range(5):
        writer.operation(lambda X, k=k: X.add_delta(f"e{k}"))
    reader.on_periodic()        # digest → w, response → r
    sim.run_for(5.0)
    assert reader.X == writer.X


def test_reconnect_catchup_bytes_beat_full_state():
    """A stale replica pulls its missing rows for far fewer measured
    bytes than one full-state frame (the push fallback it replaces)."""
    from repro.wire import encode_frame, encode_value

    wire = WireCodec()
    stale_store = _tensor_store(n_keys=16, n_chunks=8, chunk=64)
    fresh_store = _advance(stale_store, ["obj3", "obj11"])
    sim = Simulator(NetConfig(loss=0.0, seed=8))
    stale = sim.add_node(StoreReplica(
        "stale", ["peer"], causal=True, wire=wire,
        policy=make_policy("digest-sync"), rng=random.Random(2)))
    peer = sim.add_node(StoreReplica(
        "peer", ["stale"], causal=True, wire=wire,
        policy=make_policy("digest-sync"), rng=random.Random(2)))
    stale.X = stale_store
    peer.X = fresh_store
    stale.on_periodic()
    sim.run_for(5.0)
    assert stale.X == peer.X
    catchup = sim.stats.pull_bytes()
    full = len(encode_frame("state", encode_value(fresh_store)))
    assert 0 < catchup < 0.25 * full


def test_sharded_pull_responses_respect_destination_shard():
    """Composed with ShardByKey, a digest response carries only keys the
    requester replicates — pull traffic shards like push traffic."""
    from repro.sync import KeyOwnership, ShardByKey

    ids = ["w0", "w1", "w2"]
    ownership = KeyOwnership(ids, replication=1)
    sim = Simulator(NetConfig(loss=0.0, seed=6))
    nodes = {i: sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=Compose(make_policy("digest-sync"), ShardByKey(ownership)),
        rng=random.Random(5), ownership=ownership, wire=WireCodec()))
        for i in ids}
    keys = [f"k{s:02d}" for s in range(12)]
    for key in keys:
        owner = ownership.owner(key)
        nodes[owner].update(key, GCounter, "inc_delta", owner)
    for _ in range(8):
        for n in nodes.values():
            n.on_periodic()
        sim.run_for(3.0)
    for i in ids:
        held = nodes[i].keys()
        owned = {k for k in keys if ownership.replicates(i, k)}
        assert owned <= held
        assert all(k in owned for k in held if k in keys), (
            f"{i} pulled keys outside its shard: {held - owned}")


def test_pull_round_cadence_and_policy_parsing():
    p = make_policy("digest-sync")
    assert isinstance(p, DigestExchange) and p.every == 1 and p.pure_pull
    h = make_policy("digest-sync:4")
    assert h.every == 4 and not h.pure_pull
    combo = make_policy("bp+rr+digest-sync:4")
    assert combo.pull_exchange and not combo.pure_pull
    assert "digest-sync" in POLICY_SPECS
    with pytest.raises(ValueError):
        DigestExchange(0)

    class _R:
        rounds = 0
    r = _R()
    hits = [k for k in range(1, 9) if (setattr(r, "rounds", k)
                                       or h.pull_round(r))]
    assert hits == [4, 8]


def test_basic_sent_watermarks_reset_on_crash():
    """Volatile per-destination broadcast watermarks do not survive a
    crash (the buffer is gone too — nothing left to mark shipped)."""
    sim = Simulator(NetConfig(seed=0))
    r = sim.add_node(Replica("a", GSet.bottom(), ["b", "c"], causal=False,
                             fanout=1, rng=random.Random(1)))
    r.operation(lambda X: X.add_delta("e0"))
    r.on_periodic()
    assert r._basic_sent
    r.crash_and_recover()
    assert r._basic_sent == {} and r.entries == {}


def test_converged_mesh_trades_only_digest_frames():
    """Once converged, pull rounds cost digest requests only: a peer
    whose digest covers the responder gets no (empty) response frame,
    in both wire and object modes."""
    for wire in (WireCodec(), None):
        sim, nodes = _mesh("digest-sync", wire=wire, causal=True,
                           bottom=GSet.bottom(), loss=0.0, dup=0.0)
        nodes[0].operation(lambda X: X.add_delta("e0"))
        run_to_convergence(sim, nodes, interval=1.0)
        assert converged(nodes)
        sim.run_for(10.0)    # drain straggler pre-convergence requests
        sim.stats.bytes_by_kind.clear()
        for n in nodes:
            n.on_periodic()
        sim.run_for(5.0)
        assert sim.stats.bytes_by_kind.get("digest", 0) > 0
        assert sim.stats.bytes_by_kind.get("digest-resp", 0) == 0, wire


def test_opaque_hash_is_representation_independent():
    """Equal frozenset-backed values built in different orders must hash
    equal, or converged replicas re-ship the value every pull round."""
    a = GSet.bottom()
    for e in [f"e{k}" for k in range(12)]:
        a = a.join(GSet.bottom().add_delta(e))
    b = GSet.bottom()
    for e in [f"e{k}" for k in reversed(range(12))]:
        b = b.join(GSet.bottom().add_delta(e))
    assert a == b and opaque_hash(a) == opaque_hash(b)
    from repro.core import AWORSet
    s1 = AWORSet.bottom().add_delta("r0", "x").join(
        AWORSet.bottom().add_delta("r1", "y"))
    s2 = AWORSet.bottom().add_delta("r1", "y").join(
        AWORSet.bottom().add_delta("r0", "x"))
    assert s1 == s2 and opaque_hash(s1) == opaque_hash(s2)
    assert opaque_hash(a) != opaque_hash(a.join(GSet.bottom()
                                                .add_delta("extra")))


def test_converged_multielement_mesh_sends_no_responses():
    """The e2e version: replicas converge on a 12-element set assembled
    in different orders on each node; post-convergence pull rounds must
    ship digests only (hash-equal opaque values never re-ship)."""
    sim, nodes = _mesh("digest-sync", wire=WireCodec(), causal=True,
                       bottom=GSet.bottom(), loss=0.0, dup=0.0)
    for k in range(12):
        nodes[k % 3].operation(lambda X, k=k: X.add_delta(f"e{k}"))
        sim.run_for(1.0)
    run_to_convergence(sim, nodes, interval=1.0)
    assert converged(nodes)
    sim.run_for(10.0)        # drain straggler pre-convergence requests
    sim.stats.bytes_by_kind.clear()
    for n in nodes:
        n.on_periodic()
    sim.run_for(5.0)
    assert sim.stats.bytes_by_kind.get("digest", 0) > 0
    assert sim.stats.bytes_by_kind.get("digest-resp", 0) == 0


def test_digest_budget_compose_does_not_trim_pull_responses():
    """Regression: responses used to pass through policy.finalize, so a
    composed DigestBudget re-trimmed every response to the same
    top-energy chunks and pure pull never converged (no full-state
    rounds to rescue the tail). restrict_pull exempts responses."""
    sim = Simulator(NetConfig(loss=0.0, seed=12))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ("a", "b") if j != i], causal=False,
        policy=make_policy("digest:64+digest-sync"),
        rng=random.Random(4))) for i in ("a", "b")]
    nodes[0].X = _tensor_store(n_keys=4)     # ~4 keys × 4 chunks × 32B
    for _ in range(6):
        for n in nodes:
            n.on_periodic()
        sim.run_for(3.0)
    assert converged(nodes)
    assert nodes[1].keys() == {f"obj{i}" for i in range(4)}
