"""Distribution-layer unit tests: greedy sharding assignment with
divisibility fallbacks, layout factoring, HLO collective parsing, roofline
arithmetic. These run without the 512-device dry-run (mesh mocked)."""

from types import SimpleNamespace

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.hlo import collective_bytes, collective_count
from repro.dist.roofline import roofline
from repro.dist.shardings import MeshRules, spec_for
from repro.models.config import LayerSpec, layout_groups


def _rules(pod=False):
    shape = {"pod": 2, "data": 16, "model": 16} if pod else \
        {"data": 16, "model": 16}
    mesh = SimpleNamespace(shape=shape)
    fsdp = [("pod", "data"), ("data",)] if pod else [("data",)]
    return MeshRules(mesh=mesh,
                     batch_axes=("pod", "data") if pod else ("data",),
                     candidates={
                         "vocab": [("model",)], "embed": fsdp,
                         "mlp": [("model",)], "heads": [("model",)],
                         "kv": [("model",)], "expert": [("model",)],
                         "lora": [], "layers": [],
                     })


def test_greedy_assignment_one_axis_per_tensor():
    r = _rules()
    # deepseek expert weight [160, 5120, 1536]: expert wins the model axis,
    # embed gets data, mlp must fall back to replicated (model taken)
    assert spec_for((160, 5120, 1536), ("expert", "embed", "mlp"), r) == \
        P("model", "data")


def test_divisibility_fallback_replicates():
    r = _rules()
    # mixtral has 8 experts on a 16-way model axis: not divisible -> the
    # expert dim replicates and mlp gets the model axis instead
    assert spec_for((8, 6144, 16384), ("expert", "embed", "mlp"), r) == \
        P(None, "data", "model")
    assert any("expert" in f for f in r.fallbacks)


def test_multi_pod_fsdp_spans_pod_and_data():
    r = _rules(pod=True)
    assert spec_for((5120, 1536), ("embed", "mlp"), r) == \
        P(("pod", "data"), "model")
    # dim not divisible by pod*data falls back to data-only FSDP
    assert spec_for((48, 128), ("embed", "mlp"), r) == P("data", "model")


def test_trailing_nones_trimmed():
    r = _rules()
    assert spec_for((1024,), ("lora",), r) == P()


# ---------------------------------------------------------------------------
# Layout factoring
# ---------------------------------------------------------------------------

def test_layout_groups_homogeneous():
    layout = tuple(LayerSpec() for _ in range(56))
    assert layout_groups(layout) == [((LayerSpec(),), 56)]


def test_layout_groups_alternating_period2():
    lo = LayerSpec(window=4096)
    gl = LayerSpec(window=None)
    layout = tuple(lo if i % 2 == 0 else gl for i in range(46))
    groups = layout_groups(layout)
    assert groups == [((lo, gl), 23)]


def test_layout_groups_period8_jamba():
    layout = tuple(
        LayerSpec(kind=("attn" if i % 8 == 4 else "ssm"),
                  mlp=("moe" if i % 2 == 1 else "dense"))
        for i in range(32))
    groups = layout_groups(layout)
    assert len(groups) == 1 and groups[0][1] == 4
    assert len(groups[0][0]) == 8


def test_layout_groups_runs_fallback_deepseek():
    dense = LayerSpec(kind="mla", mlp="dense")
    moe = LayerSpec(kind="mla", mlp="moe")
    layout = (dense,) + tuple(moe for _ in range(59))
    groups = layout_groups(layout)
    assert groups == [((dense,), 1), ((moe,), 59)]


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

HLO = """
  %ag = bf16[16,512,128]{2,1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={1}
  %ar = f32[1024,1024]{1,0} all-reduce(%y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = bf16[64,128]{1,0} reduce-scatter(%z), replica_groups=[2,256]<=[512], dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %agd = bf16[4,4]{1,0} all-gather-done(%ag)
"""


def test_collective_bytes_ring_costs():
    total, per_kind = collective_bytes(HLO, 512)
    ag = 16 * 512 * 128 * 2 * (16 - 1) / 16          # result·(G-1)/G
    ar = 1024 * 1024 * 4 * 2 * (4 - 1) / 4           # 2·size·(G-1)/G
    rs = 64 * 128 * 2 * (256 - 1)                    # result·(G-1)
    cp = 8 * 128 * 2
    assert per_kind["all-gather"] == pytest.approx(ag)
    assert per_kind["all-reduce"] == pytest.approx(ar)
    assert per_kind["reduce-scatter"] == pytest.approx(rs)
    assert per_kind["collective-permute"] == pytest.approx(cp)
    assert total == pytest.approx(ag + ar + rs + cp)


def test_collective_count_ignores_done():
    counts = collective_count(HLO)
    assert counts == {"all-gather": 1, "all-reduce": 1,
                      "reduce-scatter": 1, "collective-permute": 1}


# ---------------------------------------------------------------------------
# Roofline arithmetic
# ---------------------------------------------------------------------------

def test_roofline_bound_selection():
    rep = roofline("a", "s", "16x16", 256,
                   {"flops": 197e12 * 0.5, "bytes accessed": 819e9 * 2.0},
                   wire_bytes=50e9 * 0.1, per_kind={},
                   model_flops_total=197e12 * 0.5 * 256 * 0.8,
                   tokens=1)
    assert rep.bound == "memory"
    assert rep.compute_s == pytest.approx(0.5)
    assert rep.memory_s == pytest.approx(2.0)
    assert rep.collective_s == pytest.approx(0.1)
    assert rep.useful_frac == pytest.approx(0.8)
    # roofline fraction: useful compute time / bound time
    assert rep.roofline_frac == pytest.approx(0.5 * 0.8 / 2.0)
