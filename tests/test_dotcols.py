"""Columnar dot-store fast path (repro.core.dotcols) vs the object oracle.

The columnar representation must be *bit-identical in meaning* to the
frozenset/dataclass path in :mod:`repro.core.dots`: every driver here
builds causally-consistent replica states (each replica mints only its
own rid on its own state — dots are globally unique 𝕀 × ℕ tags, the
invariant the flat-membership join relies on), then checks

* causal_join_cols ≡ the paper-shaped object join (and the mixed-
  representation dispatch in ``dots.causal_join``),
* the dot-column wire encoding round-trips (plain and compressed),
* the per-dot digest exchange is join-equivalent to full-state
  shipping and never ships a dot the requester's context contains,
* the jitted containment kernel agrees with the numpy path.

Drivers are plain functions over a seed so the hypothesis suite
(test_dotcols_properties) can wrap the exact same bodies; the seeds
pinned here keep the properties exercised when hypothesis is absent.
"""

import random

import numpy as np
import pytest

from repro.core import dotcols as dc
from repro.core.crdts import AWORSet, EWFlag, MVRegister, ORMap, RWORSet
from repro.core.digest import digest_diff, store_digest
from repro.core.dots import (CausalContext, DotFun, DotMap, DotSet,
                             _DOTS_MATERIALIZE_LIMIT, _normalize,
                             causal_join)
from repro.core.store import LatticeStore
from repro.wire.codec import (decode_digest, decode_store, encode_digest,
                              encode_store, store_body_is_empty)

SEEDS = list(range(12))


# ---------------------------------------------------------------------------
# Causally-consistent state generation (the property domain)
# ---------------------------------------------------------------------------

def _mut_set(v, rid, rng):
    if rng.random() < 0.72 or not v.elements():
        return v.join(v.add_delta(rid, rng.randrange(20)))
    return v.join(v.rmv_delta(rid, rng.choice(sorted(v.elements()))))


def _mut_map(m, rid, rng):
    k = "k%d" % rng.randrange(8)
    roll = rng.random()
    if roll < 0.45:
        return m.join(m.apply_delta(rid, k, AWORSet, "add_delta",
                                    rng.randrange(9)))
    if roll < 0.8:
        return m.join(m.apply_delta(rid, k, MVRegister, "write_delta",
                                    rng.randrange(9)))
    return m.join(m.rmv_delta(rid, k))


def _mut_flag(f, rid, rng):
    return f.join(f.enable_delta(rid) if rng.random() < 0.6
                  else f.disable_delta(rid))


_SYSTEMS = [(AWORSet, _mut_set), (ORMap, _mut_map), (EWFlag, _mut_flag)]


def replica_states(cls, mutate, n_reps, n_steps, rng):
    """Divergent replicas of ONE system: replica ``i`` mints only rid
    ``i`` on its own state (so every dot is globally unique — its own
    context always covers its own past mints), with random pairwise
    joins standing in for anti-entropy."""
    states = [cls.bottom() for _ in range(n_reps)]
    for _ in range(n_steps):
        i = rng.randrange(n_reps)
        if rng.random() < 0.7:
            states[i] = mutate(states[i], "r%d" % i, rng)
        else:
            states[i] = states[i].join(states[rng.randrange(n_reps)])
    return states


def _divergent_pair(seed):
    rng = random.Random(seed)
    cls, mutate = _SYSTEMS[seed % len(_SYSTEMS)]
    states = replica_states(cls, mutate, 4, rng.randrange(2, 60), rng)
    x, y = rng.sample(states, 2)
    return x, y


def _to_cols(v):
    return type(v)(dc.store_to_cols(v.store), dc.ctx_to_cols(v.ctx))


# ---------------------------------------------------------------------------
# Drivers (shared with test_dotcols_properties)
# ---------------------------------------------------------------------------

def check_join_equivalence(seed):
    """Columnar join ≡ object join, for every pairing of representations."""
    x, y = _divergent_pair(seed)
    so, co = causal_join(x.store, x.ctx, y.store, y.ctx)
    xs, xc = dc.store_to_cols(x.store), dc.ctx_to_cols(x.ctx)
    ys, yc = dc.store_to_cols(y.store), dc.ctx_to_cols(y.ctx)
    sc, cc = dc.causal_join_cols(xs, xc, ys, yc)
    assert sc.to_obj() == so and cc.to_obj() == co
    assert sc == so and cc == co            # cross-representation __eq__
    # dispatch through dots.causal_join, mixed representations both ways
    for sa, ca, sb, cb in [(xs, xc, y.store, y.ctx),
                           (x.store, x.ctx, ys, yc)]:
        sm, cm = causal_join(sa, ca, sb, cb)
        assert sm == so and cm == co
    # CRDT-level joins agree regardless of representation
    assert _to_cols(x).join(y) == x.join(y)


def check_wire_roundtrip(seed):
    """decode(encode(store)) == store, and causal values come back
    columnar (plain and zlib-compressed bodies)."""
    x, _ = _divergent_pair(seed)
    st = LatticeStore.of({"v": x})
    for compress in (False, True):
        out = decode_store(encode_store(st, compress=compress))
        assert out == st
        got = out.as_dict()["v"]
        assert got == x and type(got) is type(x)
        assert dc.is_columnar(got.store) and dc.is_columnar(got.ctx)


def check_digest_sync(seed):
    """Per-dot digest exchange ships a join-equivalent sub-delta and
    never a dot the requester's context contains (Def. 6: the response
    joined at the requester equals joining the responder's full state)."""
    x, y = _divergent_pair(seed)
    so, co = causal_join(x.store, x.ctx, y.store, y.ctx)
    full = type(x)(so, co)
    dg = store_digest(LatticeStore.of({"v": x}))
    dg = decode_digest(encode_digest(dg))          # over the wire
    assert "v" in dg.causal
    body = encode_store(LatticeStore.of({"v": full}), known_causal=dg.causal)
    if store_body_is_empty(body):
        got = x                                    # requester lacked nothing
    else:
        ship = decode_store(body).as_dict()["v"]
        for d in ship.store.all_dots():
            assert not x.ctx.contains(d), \
                f"response shipped dot {d} the requester already saw"
        got = x.join(ship)
    assert got == full
    # the object-path responder (digest_diff) is the oracle of the same
    # exchange — both must land the requester on the identical state
    dif = dict(digest_diff(LatticeStore.of({"v": full}), dg).entries)
    got_obj = x.join(dif["v"]) if "v" in dif else x
    assert got_obj == full


def check_missing_mask_parity(seed):
    """The jitted containment kernel == the numpy sorted-merge path."""
    rng = random.Random(seed)
    rids = ("a", "b", "c")
    vv = np.array([rng.randrange(0, 10) for _ in rids], np.int64)
    cloud = np.array(sorted({dc.pack_dot(rids, (rng.choice(rids),
                                                rng.randrange(1, 20)))
                             for _ in range(rng.randrange(0, 6))}), np.int64)
    dots_q = np.array(sorted({dc.pack_dot(rids, (rng.choice(rids),
                                                 rng.randrange(1, 20)))
                              for _ in range(rng.randrange(1, 30))}), np.int64)
    m_np = dc.missing_mask(vv, cloud, dots_q, backend="numpy")
    m_jx = dc.missing_mask(vv, cloud, dots_q, backend="jax")
    assert np.array_equal(m_np, np.asarray(m_jx))
    # ... and both agree with the object-model contains()
    cc = dc.CausalContextCols(tuple(rids), vv, cloud).to_obj()
    for packed, miss in zip(dots_q.tolist(), m_np.tolist()):
        d = (rids[packed >> dc.SEQ_BITS], packed & dc.SEQ_MASK)
        assert miss == (not cc.contains(d))


def check_context_parity(seed):
    """CausalContextCols mirrors CausalContext query-for-query."""
    rng = random.Random(seed)
    rids = ["a", "b", "c"]
    dots = [(rng.choice(rids), rng.randint(1, 12))
            for _ in range(rng.randint(0, 25))]
    cc = CausalContext.from_dots(dots)
    cv = dc.ctx_to_cols(cc)
    assert cv.to_obj() == cc and cv == cc and cc == cv
    assert hash(cv) == hash(cc)
    for i in rids + ["z"]:
        assert cv.max_for(i) == cc.max_for(i)
        assert cv.next_dot(i) == cc.next_dot(i)
        for k in range(1, 15):
            assert cv.contains((i, k)) == cc.contains((i, k))
    other = CausalContext.from_dots(
        [(rng.choice(rids), rng.randint(1, 12))
         for _ in range(rng.randint(0, 25))])
    ov = dc.ctx_to_cols(other)
    assert cv.join(ov).to_obj() == cc.join(other)
    assert cv.leq(ov) == cc.leq(other)
    assert ov.leq(cv) == other.leq(cc)


def check_add_dots_fast_path(seed):
    """The contiguous-append fast path in add_dots is indistinguishable
    from the generic normalize path."""
    rng = random.Random(seed)
    rids = ["a", "b", "c"]
    base = CausalContext.from_dots(
        [(rng.choice(rids), rng.randint(1, 8))
         for _ in range(rng.randint(0, 15))])
    batch = []
    probe = dict(base.vv)
    for _ in range(rng.randint(1, 10)):
        i = rng.choice(rids)
        if rng.random() < 0.7:                 # contiguous extension
            probe[i] = probe.get(i, 0) + 1
            batch.append((i, probe[i]))
        else:                                  # arbitrary (may gap)
            batch.append((i, rng.randint(1, 14)))
    got = base.add_dots(batch)
    vv = dict(base.vv)
    cloud = set(base.cloud)
    for d in batch:
        if d[1] > vv.get(d[0], 0):
            cloud.add(d)
    assert got == _normalize(vv, cloud)


# ---------------------------------------------------------------------------
# Seed-pinned instantiations (pass with or without hypothesis installed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_join_equivalence(seed):
    check_join_equivalence(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_wire_roundtrip(seed):
    check_wire_roundtrip(seed)


@pytest.mark.parametrize("seed", SEEDS)
def test_digest_sync(seed):
    check_digest_sync(seed)


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_missing_mask_parity(seed):
    check_missing_mask_parity(seed)


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_context_parity(seed):
    check_context_parity(seed)


@pytest.mark.parametrize("seed", SEEDS[:6])
def test_add_dots_fast_path(seed):
    check_add_dots_fast_path(seed)


# ---------------------------------------------------------------------------
# Deterministic unit checks
# ---------------------------------------------------------------------------

def test_leq_matches_lattice_definition():
    """leq must equal the definitional order other.join(self) == other —
    including across vv/cloud splits of the same dot set."""
    cases = [
        CausalContext.bottom(),
        CausalContext.from_dots([("a", 1)]),
        CausalContext.from_dots([("a", 1), ("a", 2), ("b", 1)]),
        CausalContext.from_dots([("a", 1), ("a", 3)]),          # cloud gap
        CausalContext.from_dots([("a", 3), ("b", 5)]),          # pure cloud
        CausalContext.from_dots([("a", 1), ("a", 2), ("a", 4), ("b", 2)]),
    ]
    for s in cases:
        for o in cases:
            assert s.leq(o) == (o.join(s) == o), (s, o)


def test_dots_materialize_guard():
    """dots() is a test/debug helper: materializing a huge context must
    trip the guard instead of silently allocating O(history)."""
    big = CausalContext(vv=(("r0", _DOTS_MATERIALIZE_LIMIT + 1),))
    with pytest.raises(AssertionError, match="test/debug"):
        big.dots()
    # small contexts still materialize fine
    assert CausalContext.from_dots([("a", 1), ("a", 2)]).dots() == \
        frozenset([("a", 1), ("a", 2)])


def test_normalize_cols_matches_object_normalize():
    rng = random.Random(5)
    rids = ("a", "b", "c")
    for _ in range(30):
        vv_map = {i: rng.randrange(0, 6) for i in rids}
        cloud = {(rng.choice(rids), rng.randrange(1, 15))
                 for _ in range(rng.randrange(0, 10))}
        oracle = _normalize(dict(vv_map), set(cloud))
        vvcol = np.array([vv_map[i] for i in rids], np.int64)
        packed = np.array([dc.pack_dot(rids, d) for d in sorted(cloud)],
                          np.int64)
        nvv, ncloud = dc._normalize_cols(vvcol, packed)
        got = dc.CausalContextCols(rids, nvv, ncloud).to_obj()
        assert got == oracle


def test_digest_wire_roundtrip_causal_section():
    v = AWORSet.bottom()
    for e in ("x", "y"):
        v = v.join(v.add_delta("r1", e))
    v = v.join(v.rmv_delta("r1", "x"))
    dg = store_digest(LatticeStore.of({"v": v}))
    out = decode_digest(encode_digest(dg))
    assert out == dg
    g = out.causal["v"]
    # the per-dot section carries the store's live dots exactly
    assert set(g.dotcol.tolist()) == \
        {dc.pack_dot(g.rids, d) for d in v.store.all_dots()}


def test_ormap_columnar_keyed_access():
    m = ORMap.bottom()
    m = m.join(m.apply_delta("r1", "k1", AWORSet, "add_delta", 1))
    m = m.join(m.apply_delta("r1", "k2", MVRegister, "write_delta", 7))
    mv = _to_cols(m)
    assert mv == m
    assert mv.get_value("k2", MVRegister) == m.get_value("k2", MVRegister)
    assert mv.get_value("zz", AWORSet) == m.get_value("zz", AWORSet)
    # mutating through the columnar map lands on the same state
    assert mv.join(mv.apply_delta("r2", "k1", AWORSet, "add_delta", 2)) \
        == m.join(m.apply_delta("r2", "k1", AWORSet, "add_delta", 2))


def test_nested_ormap_stays_on_object_path():
    """Nested DotMap shapes are outside the columnar model: conversion
    declines (returns None) and every layer falls back to objects."""
    inner = ORMap.bottom()
    inner = inner.join(inner.apply_delta("r1", "i", AWORSet,
                                         "add_delta", 1))
    outer = ORMap.bottom().join(
        ORMap(DotMap.of({"o": inner.store}), inner.ctx))
    assert dc.store_to_cols(outer.store) is None
    assert dc.value_to_cols(outer) is None
    # digest/wire still handle it (opaque fallback), round-tripping exactly
    st = LatticeStore.of({"nested": outer})
    assert decode_store(encode_store(st)) == st
    dg = store_digest(st)
    assert "nested" in dg.opaque and "nested" not in dg.causal
