"""Property-test sweep over the columnar dot-store drivers.

Wraps the seed-driven drivers from :mod:`tests.test_dotcols` in
hypothesis, so the columnar/object equivalence, wire round-trip, and
per-dot digest properties are searched over a much wider seed space
than the pinned parametrizations. The driver bodies are identical —
anything hypothesis finds here is reproducible by adding the failing
seed to ``test_dotcols.SEEDS``.
"""

import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from test_dotcols import (check_add_dots_fast_path, check_context_parity,
                          check_digest_sync, check_join_equivalence,
                          check_missing_mask_parity, check_wire_roundtrip)

_seed = st.integers(0, 2**32 - 1)


@settings(max_examples=60, deadline=None)
@given(seed=_seed)
def test_join_equivalence_property(seed):
    check_join_equivalence(seed)


@settings(max_examples=40, deadline=None)
@given(seed=_seed)
def test_wire_roundtrip_property(seed):
    check_wire_roundtrip(seed)


@settings(max_examples=60, deadline=None)
@given(seed=_seed)
def test_digest_sync_property(seed):
    check_digest_sync(seed)


@settings(max_examples=40, deadline=None)
@given(seed=_seed)
def test_missing_mask_parity_property(seed):
    check_missing_mask_parity(seed)


@settings(max_examples=40, deadline=None)
@given(seed=_seed)
def test_context_parity_property(seed):
    check_context_parity(seed)


@settings(max_examples=60, deadline=None)
@given(seed=_seed)
def test_add_dots_fast_path_property(seed):
    check_add_dots_fast_path(seed)
