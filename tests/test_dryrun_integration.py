"""Launch-path integration: lower + compile train/prefill/decode steps on a
real (2×4) multi-device mesh with the full sharding machinery — the same
code path as the 512-device production dry-run, at test scale. Subprocess
keeps the fake devices out of the test session."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.shapes import ShapeCase
    from repro.dist import make_rules
    from repro.launch.dryrun import _cell_costs, _lower_and_compile

    arch = os.environ["TEST_ARCH"]
    step = os.environ["TEST_STEP"]
    cfg = get_config(arch, reduced=True)
    if os.environ.get("TEST_MOE_LOCAL") == "1":
        cfg = dataclasses.replace(cfg, moe_impl="local")
    seq = cfg.ssm.chunk * 2 if cfg.ssm is not None else 32
    if cfg.input_mode == "tokens+prefix":
        seq = max(seq, cfg.prefix_len + 16)
    case = ShapeCase("t", seq, 8, step)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh)
    lowered, compiled = _lower_and_compile(cfg, case, mesh, False, rules)
    costs = _cell_costs(compiled, 8)
    assert costs["flops"] > 0
    mem = compiled.memory_analysis()
    print("OK", costs["flops"], costs["wire"])
""")


def _run(arch, step, moe_local=False):
    env = dict(os.environ)
    env.update({"TEST_ARCH": arch, "TEST_STEP": step,
                "PYTHONPATH": "src",
                "TEST_MOE_LOCAL": "1" if moe_local else "0"})
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.parametrize("arch,step", [
    ("qwen2-1.5b", "train"),          # GQA + bias + tied embeddings
    ("gemma2-27b", "prefill"),        # alternating windows + softcaps
    ("mamba2-130m", "train"),         # SSD, no attention
    ("jamba-v0.1-52b", "decode"),     # hybrid caches (ssm + kv + moe)
    ("deepseek-v2-236b", "decode"),   # MLA latent cache
])
def test_lower_and_compile_small_mesh(arch, step):
    _run(arch, step)


def test_moe_local_lowers_on_mesh():
    _run("mixtral-8x22b", "train", moe_local=True)
