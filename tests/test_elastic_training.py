"""Elasticity + fault tolerance end-to-end: a pod crashes mid-training and
recovers (durable (X, c), volatile (D, A) lost — the paper's crash model);
the surviving pods keep making progress (no barrier), and after recovery
Algorithm 2's full-state fallback re-synchronizes everyone. Also the
cross-pod-bytes HLO parser used by EXPERIMENTS.md §Perf cell 3."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NetConfig, Simulator, converged, run_to_convergence
from repro.dist.hlo import cross_pod_bytes
from repro.sync import DeltaSyncPod


def _mk_pods(n_pods, sim):
    ids = [f"pod{k}" for k in range(n_pods)]

    def local_update(params, round_idx, pod_id):
        k = int(pod_id[3:])
        target = {"w": jnp.full((4,), float(k + 1))}
        return jax.tree_util.tree_map(lambda p, t: p + 0.5 * (t - p),
                                      params, target)

    return [sim.add_node(DeltaSyncPod(
        i, [j for j in ids if j != i], {"w": jnp.zeros((4,), jnp.float32)},
        local_update, num_pods=n_pods, rng=random.Random(7 + k)))
        for k, i in enumerate(ids)]


def test_pod_crash_and_recovery_rejoins_training():
    sim = Simulator(NetConfig(loss=0.25, dup=0.1, seed=3))
    pods = _mk_pods(3, sim)

    # round 0: everyone
    for p in pods:
        p.do_round()
    sim.run_for(3.0)

    # pod2 crashes; training does NOT barrier on it
    sim.crash("pod2", downtime=20.0)
    for rnd in range(1, 3):
        for p in pods:
            if p.alive:                  # straggler/offline pods are skipped
                p.do_round()
        sim.run_for(3.0)
    # progress without pod2: the survivors completed all 3 rounds while
    # pod2 is still at 1 (gossip delivery lags are fine — convergence is
    # checked below)
    assert pods[0].round_idx == 3 and pods[1].round_idx == 3
    assert pods[2].round_idx == 1

    sim.run_until(sim.time + 25.0)       # pod2 recovers (durable X kept,
    assert pods[2].alive                 # volatile D/A lost)
    assert pods[2].D == {}
    for p in pods:
        p.do_round()                     # pod2 rejoins with a fresh round
    run_to_convergence(sim, pods, interval=1.0, max_time=30_000)
    assert converged(pods)
    # every contribution from every pod (including pre-crash pod2) merged
    producers = {dot[0] for dot, _ in pods[0].X.dots}
    assert producers == {"pod0", "pod1", "pod2"}
    ps = [p.params() for p in pods]
    for q in ps[1:]:
        np.testing.assert_allclose(np.asarray(ps[0]["w"]),
                                   np.asarray(q["w"]), rtol=1e-6)


def test_scale_up_mid_run_is_just_another_replica():
    """Elastic scale-up: a new pod attaches mid-run, receives the full
    state via Algorithm 2's fallback (empty ack map → full state), and
    contributes from then on."""
    sim = Simulator(NetConfig(loss=0.2, seed=11))
    pods = _mk_pods(2, sim)
    for rnd in range(2):
        for p in pods:
            p.do_round()
        sim.run_for(3.0)

    # attach pod2 (registered with the same num_pods scaling for exactness)
    newcomer = DeltaSyncPod(
        "pod2", ["pod0", "pod1"], {"w": jnp.zeros((4,), jnp.float32)},
        pods[0].local_update_fn, num_pods=2, rng=random.Random(42))
    sim.add_node(newcomer)
    for p in pods:
        p.neighbors.append("pod2")
    run_to_convergence(sim, pods + [newcomer], interval=1.0,
                       max_time=30_000)
    assert newcomer.X == pods[0].X       # caught up via full-state fallback


# ---------------------------------------------------------------------------
# cross-pod byte accounting (§Perf cell 3 parser)
# ---------------------------------------------------------------------------

HLO = """
  %a = bf16[64,128]{1,0} all-reduce(%x), replica_groups=[16,32]<=[32,16]T(1,0), to_apply=%add
  %b = bf16[64,128]{1,0} all-reduce(%y), replica_groups=[2,256]<=[512], to_apply=%add
  %c = bf16[64,128]{1,0} all-gather(%z), replica_groups={{0,1},{2,3}}, dimensions={0}
"""


def test_cross_pod_bytes_membership_aware():
    # %a: iota [16,32]<=[32,16]T(1,0): groups stride across the 256-device
    #     pod boundary → pod-spanning
    # %b: contiguous 256-blocks → entirely within one pod each
    # %c: tiny groups {0,1},{2,3} → within pod 0
    total = cross_pod_bytes(HLO, 512, 256)
    size = 64 * 128 * 2
    want_a = 2 * size * (32 - 1) / 32
    assert abs(total - want_a) < 1e-6, (total, want_a)
