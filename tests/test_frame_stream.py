"""FrameStream: incremental frame decoding over arbitrary byte chunks.

The transports never see tidy one-frame reads — TCP splits frames at
segment boundaries, UDP batches several frames into one datagram, and a
damaged link flips bits mid-stream. The decoder's contract:

* any chunking of a valid byte stream yields exactly the same frames;
* garbage and CRC failures are skipped to the next magic (resync) and
  decoding continues — a corrupt frame never takes later frames with it;
* a length field above ``max_frame`` is corruption, not an allocation;
* a truncated tail is held, not dropped, until the rest arrives.
"""

import pytest

from repro.wire import FrameStream, encode_frame
from repro.wire.frames import HEADER_SIZE, MAGIC


def _frames(n=5, kind="delta"):
    return [encode_frame(kind, bytes([65 + i]) * (10 + 7 * i))
            for i in range(n)]


def test_byte_by_byte_feed():
    frames = _frames()
    stream = FrameStream()
    got = []
    for b in b"".join(frames):
        got.extend(stream.feed(bytes([b])))
    assert [bytes(f) for f in got] == [bytes(f) for f in frames]
    assert [f.kind for f in got] == ["delta"] * len(frames)
    assert stream.frames == len(frames)
    assert stream.corrupt == stream.resyncs == stream.skipped_bytes == 0
    assert stream.pending == 0


@pytest.mark.parametrize("chunk", [1, 2, 3, 7, 64, 10_000])
def test_any_chunking_yields_same_frames(chunk):
    blob = b"".join(_frames(6))
    stream = FrameStream()
    got = []
    for i in range(0, len(blob), chunk):
        got.extend(stream.feed(blob[i:i + chunk]))
    assert len(got) == 6 and stream.frames == 6


def test_concatenated_frames_in_one_feed():
    frames = _frames(4, kind="ack")
    got = FrameStream().feed(b"".join(frames))
    assert [bytes(f) for f in got] == [bytes(f) for f in frames]


def test_garbage_prefix_resyncs_to_first_frame():
    fr = encode_frame("digest", b"payload")
    junk = b"\x00\xffnoise bytes here\xd4"   # ends with half a magic
    stream = FrameStream()
    got = stream.feed(junk + fr)
    assert [bytes(f) for f in got] == [bytes(fr)]
    assert stream.resyncs >= 1
    assert stream.skipped_bytes == len(junk)


def test_midstream_bit_flip_skips_one_frame_keeps_the_rest():
    frames = _frames(5)
    blob = bytearray(b"".join(frames))
    # flip a payload bit inside frame 2
    off = sum(len(f) for f in frames[:2]) + HEADER_SIZE + 3
    blob[off] ^= 0x40
    stream = FrameStream()
    got = stream.feed(bytes(blob))
    survivors = [bytes(f) for i, f in enumerate(frames) if i != 2]
    assert [bytes(f) for f in got] == survivors
    assert stream.corrupt == 1 and stream.frames == 4
    assert stream.resyncs >= 1


def test_header_bit_flip_also_resyncs():
    frames = _frames(3)
    blob = bytearray(b"".join(frames))
    blob[len(frames[0]) + 2] ^= 0x01         # frame 1's version byte
    got = FrameStream().feed(bytes(blob))
    assert [bytes(f) for f in got] == [bytes(frames[0]), bytes(frames[2])]


def test_oversized_length_field_is_corruption_not_allocation():
    fr = encode_frame("state", b"z" * 50)
    huge = bytearray(fr)
    huge[4:8] = (2**31).to_bytes(4, "little")   # length field → 2 GiB
    stream = FrameStream(max_frame=1024)
    tail = encode_frame("ack", b"ok")
    got = stream.feed(bytes(huge) + tail)
    assert [bytes(f) for f in got] == [bytes(tail)]
    assert stream.corrupt == 1
    assert stream.pending < 1024              # nothing buffered waiting


def test_truncated_tail_is_held_then_completed():
    fr = encode_frame("delta", b"q" * 200)
    stream = FrameStream()
    assert stream.feed(fr[:HEADER_SIZE + 50]) == []
    assert stream.pending == HEADER_SIZE + 50
    got = stream.feed(fr[HEADER_SIZE + 50:])
    assert [bytes(f) for f in got] == [bytes(fr)]
    assert stream.pending == 0


def test_magic_split_across_feeds():
    fr = encode_frame("topk", b"body")
    stream = FrameStream()
    # garbage, then the first magic byte alone at a feed boundary
    assert stream.feed(b"junk" + MAGIC[:1]) == []
    got = stream.feed(MAGIC[1:] + bytes(fr)[2:])
    assert [bytes(f) for f in got] == [bytes(fr)]


def test_reset_drops_partial_state():
    fr = encode_frame("delta", b"w" * 100)
    stream = FrameStream()
    stream.feed(fr[:30])
    stream.reset()
    assert stream.pending == 0
    # a fresh frame decodes cleanly afterwards
    assert len(stream.feed(bytes(fr))) == 1
