"""delta_join / chunk_digest Pallas kernels vs oracles + lattice-law checks
of the kernel itself (the kernel IS the join, so it must satisfy the join
laws), plus integration with the TensorState lattice."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # only the property sweep needs hypothesis (dev dependency)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops


def _mk(n, chunk, dtype, seed):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(n, chunk)).astype(np.float32)
    vers = rng.integers(0, 50, size=(n,)).astype(np.int32)
    return jnp.asarray(vals, dtype), jnp.asarray(vers)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,chunk,bn", [
    (256, 128, 128), (1024, 256, 256), (64, 512, 64), (8, 128, 8),
])
def test_delta_join_matches_ref(dtype, n, chunk, bn):
    av, avers = _mk(n, chunk, dtype, 0)
    bv, bvers = _mk(n, chunk, dtype, 1)
    ov, overs = ops.delta_join(av, avers, bv, bvers, block_n=bn,
                               interpret=True)
    rv, rvers = ops.delta_join_ref(av, avers, bv, bvers)
    np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))


if HAVE_HYPOTHESIS:
    _property = lambda f: settings(max_examples=20, deadline=None)(
        given(seed=st.integers(0, 2**31 - 1))(f))
else:
    _property = pytest.mark.skip(
        reason="dev dependency — pip install -r requirements-dev.txt")


@_property
def test_delta_join_kernel_is_a_join(seed=0):
    """Kernel-level lattice laws: idempotent / commutative / associative.
    (Ties must carry equal values, as the TensorState lattice guarantees.)"""
    rng = np.random.default_rng(seed)
    n, chunk = 64, 128
    # versions drawn so that equal versions ⇒ equal values (the lattice
    # precondition): derive each chunk's values from its version
    vers = rng.integers(0, 6, size=(3, n)).astype(np.int32)
    vals = vers[..., None].astype(np.float32) * np.ones((1, 1, chunk),
                                                        np.float32)
    a, b, c = [(jnp.asarray(vals[i]), jnp.asarray(vers[i])) for i in range(3)]

    def J(x, y):
        return ops.delta_join(x[0], x[1], y[0], y[1], block_n=n,
                              interpret=True)

    def eq(x, y):
        return (np.array_equal(np.asarray(x[0]), np.asarray(y[0]))
                and np.array_equal(np.asarray(x[1]), np.asarray(y[1])))

    assert eq(J(a, a), a)                      # idempotent
    assert eq(J(a, b), J(b, a))                # commutative
    assert eq(J(J(a, b), c), J(a, J(b, c)))    # associative


@pytest.mark.parametrize("n,chunk,bn", [
    (100, 128, 32),    # n not a multiple of the block
    (7, 128, 8),       # n smaller than the block
    (1000, 128, 256),  # large ragged tail
    (13, 256, 13),     # bn == n exactly (no padding)
])
def test_delta_join_ragged_chunk_counts_match_ref(n, chunk, bn):
    """Chunk counts that are NOT multiples of the block size: the kernel
    zero-pads to the block boundary (⊥ versions) and slices back."""
    av, avers = _mk(n, chunk, jnp.float32, 2)
    bv, bvers = _mk(n, chunk, jnp.float32, 3)
    ov, overs = ops.delta_join(av, avers, bv, bvers, block_n=bn,
                               interpret=True)
    rv, rvers = ops.delta_join_ref(av, avers, bv, bvers)
    assert ov.shape == (n, chunk) and overs.shape == (n,)
    np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))


@pytest.mark.parametrize("sizes", [
    [4, 4, 4],                 # uniform — one stacked launch
    [1, 3, 7, 13, 5],          # ragged segment lengths
    [8],                       # single segment
])
def test_batched_delta_join_interpret_parity_with_ref(sizes):
    """Stacked multi-segment launch == per-segment oracle, on CPU in
    interpret mode (the satellite's interpret-mode parity check)."""
    segs = []
    for i, n in enumerate(sizes):
        av, avers = _mk(n, 128, jnp.float32, 10 + i)
        bv, bvers = _mk(n, 128, jnp.float32, 50 + i)
        segs.append((av, avers, bv, bvers))
    outs = ops.batched_delta_join(segs, block_n=8, interpret=True)
    refs = ops.batched_delta_join_ref(segs)
    assert len(outs) == len(segs)
    for (ov, overs), (rv, rvers), (av, _, _, _) in zip(outs, refs, segs):
        assert ov.shape == av.shape
        np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))


def test_batched_delta_join_groups_mixed_signatures():
    """Segments with different chunk widths / dtypes cannot share a
    launch; grouping must still return per-segment results in order."""
    segs = []
    for i, (n, chunk, dt) in enumerate([(4, 128, jnp.float32),
                                        (6, 256, jnp.float32),
                                        (4, 128, jnp.bfloat16),
                                        (10, 128, jnp.float32)]):
        av, avers = _mk(n, chunk, dt, 20 + i)
        bv, bvers = _mk(n, chunk, dt, 80 + i)
        segs.append((av, avers, bv, bvers))
    outs = ops.batched_delta_join(segs, interpret=True)
    refs = ops.batched_delta_join_ref(segs)
    for (ov, overs), (rv, rvers) in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))


@pytest.mark.parametrize("n,chunk,bn", [(256, 128, 128), (32, 256, 32),
                                        (100, 128, 32), (5, 128, 8)])
def test_chunk_digest_matches_ref(n, chunk, bn):
    x, _ = _mk(n, chunk, jnp.float32, 7)
    ma, ss = ops.chunk_digest(x, block_n=bn, interpret=True)
    rma, rss = ops.chunk_digest_ref(x)
    np.testing.assert_allclose(np.asarray(ma), np.asarray(rma), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(rss), rtol=1e-5)


def test_kernel_join_equals_tensorstate_join():
    """End-to-end: the Pallas join produces exactly the TensorState join."""
    from repro.core.tensor_lattice import (ChunkedTensor, TensorState,
                                           chunk_tensor)
    rng = np.random.default_rng(3)
    n, chunk = 16, 128
    a_vals = rng.normal(size=(n, chunk)).astype(np.float32)
    b_vals = rng.normal(size=(n, chunk)).astype(np.float32)
    a_vers = rng.integers(0, 5, size=(n,)).astype(np.int32)
    b_vers = rng.integers(0, 5, size=(n,)).astype(np.int32)
    # ties must agree (lattice precondition)
    tie = a_vers == b_vers
    b_vals[tie] = a_vals[tie]

    A = TensorState.of({"w": ChunkedTensor(jnp.asarray(a_vals),
                                           jnp.asarray(a_vers))})
    B = TensorState.of({"w": ChunkedTensor(jnp.asarray(b_vals),
                                           jnp.asarray(b_vers))})
    lattice_join = A.join(B).as_dict()["w"]
    kv, kvers = ops.delta_join(jnp.asarray(a_vals), jnp.asarray(a_vers),
                               jnp.asarray(b_vals), jnp.asarray(b_vers),
                               block_n=n, interpret=True)
    np.testing.assert_array_equal(np.asarray(lattice_join.values),
                                  np.asarray(kv))
    np.testing.assert_array_equal(np.asarray(lattice_join.versions),
                                  np.asarray(kvers))
