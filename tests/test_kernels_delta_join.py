"""delta_join / chunk_digest Pallas kernels vs oracles + lattice-law checks
of the kernel itself (the kernel IS the join, so it must satisfy the join
laws), plus integration with the TensorState lattice."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:        # only the property sweep needs hypothesis (dev dependency)
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops


def _mk(n, chunk, dtype, seed):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(n, chunk)).astype(np.float32)
    vers = rng.integers(0, 50, size=(n,)).astype(np.int32)
    return jnp.asarray(vals, dtype), jnp.asarray(vers)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,chunk,bn", [
    (256, 128, 128), (1024, 256, 256), (64, 512, 64), (8, 128, 8),
])
def test_delta_join_matches_ref(dtype, n, chunk, bn):
    av, avers = _mk(n, chunk, dtype, 0)
    bv, bvers = _mk(n, chunk, dtype, 1)
    ov, overs = ops.delta_join(av, avers, bv, bvers, block_n=bn,
                               interpret=True)
    rv, rvers = ops.delta_join_ref(av, avers, bv, bvers)
    np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))


if HAVE_HYPOTHESIS:
    _property = lambda f: settings(max_examples=20, deadline=None)(
        given(seed=st.integers(0, 2**31 - 1))(f))
else:
    _property = pytest.mark.skip(
        reason="dev dependency — pip install -r requirements-dev.txt")


@_property
def test_delta_join_kernel_is_a_join(seed=0):
    """Kernel-level lattice laws: idempotent / commutative / associative.
    (Ties must carry equal values, as the TensorState lattice guarantees.)"""
    rng = np.random.default_rng(seed)
    n, chunk = 64, 128
    # versions drawn so that equal versions ⇒ equal values (the lattice
    # precondition): derive each chunk's values from its version
    vers = rng.integers(0, 6, size=(3, n)).astype(np.int32)
    vals = vers[..., None].astype(np.float32) * np.ones((1, 1, chunk),
                                                        np.float32)
    a, b, c = [(jnp.asarray(vals[i]), jnp.asarray(vers[i])) for i in range(3)]

    def J(x, y):
        return ops.delta_join(x[0], x[1], y[0], y[1], block_n=n,
                              interpret=True)

    def eq(x, y):
        return (np.array_equal(np.asarray(x[0]), np.asarray(y[0]))
                and np.array_equal(np.asarray(x[1]), np.asarray(y[1])))

    assert eq(J(a, a), a)                      # idempotent
    assert eq(J(a, b), J(b, a))                # commutative
    assert eq(J(J(a, b), c), J(a, J(b, c)))    # associative


@pytest.mark.parametrize("n,chunk,bn", [
    (100, 128, 32),    # n not a multiple of the block
    (7, 128, 8),       # n smaller than the block
    (1000, 128, 256),  # large ragged tail
    (13, 256, 13),     # bn == n exactly (no padding)
])
def test_delta_join_ragged_chunk_counts_match_ref(n, chunk, bn):
    """Chunk counts that are NOT multiples of the block size: the kernel
    zero-pads to the block boundary (⊥ versions) and slices back."""
    av, avers = _mk(n, chunk, jnp.float32, 2)
    bv, bvers = _mk(n, chunk, jnp.float32, 3)
    ov, overs = ops.delta_join(av, avers, bv, bvers, block_n=bn,
                               interpret=True)
    rv, rvers = ops.delta_join_ref(av, avers, bv, bvers)
    assert ov.shape == (n, chunk) and overs.shape == (n,)
    np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))


@pytest.mark.parametrize("sizes", [
    [4, 4, 4],                 # uniform — one stacked launch
    [1, 3, 7, 13, 5],          # ragged segment lengths
    [8],                       # single segment
])
def test_batched_delta_join_interpret_parity_with_ref(sizes):
    """Stacked multi-segment launch == per-segment oracle, on CPU in
    interpret mode (the satellite's interpret-mode parity check)."""
    segs = []
    for i, n in enumerate(sizes):
        av, avers = _mk(n, 128, jnp.float32, 10 + i)
        bv, bvers = _mk(n, 128, jnp.float32, 50 + i)
        segs.append((av, avers, bv, bvers))
    outs = ops.batched_delta_join(segs, block_n=8, interpret=True)
    refs = ops.batched_delta_join_ref(segs)
    assert len(outs) == len(segs)
    for (ov, overs), (rv, rvers), (av, _, _, _) in zip(outs, refs, segs):
        assert ov.shape == av.shape
        np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))


def test_batched_delta_join_groups_mixed_signatures():
    """Segments with different chunk widths / dtypes cannot share a
    launch; grouping must still return per-segment results in order."""
    segs = []
    for i, (n, chunk, dt) in enumerate([(4, 128, jnp.float32),
                                        (6, 256, jnp.float32),
                                        (4, 128, jnp.bfloat16),
                                        (10, 128, jnp.float32)]):
        av, avers = _mk(n, chunk, dt, 20 + i)
        bv, bvers = _mk(n, chunk, dt, 80 + i)
        segs.append((av, avers, bv, bvers))
    outs = ops.batched_delta_join(segs, interpret=True)
    refs = ops.batched_delta_join_ref(segs)
    for (ov, overs), (rv, rvers) in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
        np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))


@pytest.mark.parametrize("n,chunk,bn", [(256, 128, 128), (32, 256, 32),
                                        (100, 128, 32), (5, 128, 8)])
def test_chunk_digest_matches_ref(n, chunk, bn):
    x, _ = _mk(n, chunk, jnp.float32, 7)
    ma, ss = ops.chunk_digest(x, block_n=bn, interpret=True)
    rma, rss = ops.chunk_digest_ref(x)
    np.testing.assert_allclose(np.asarray(ma), np.asarray(rma), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(rss), rtol=1e-5)


def test_kernel_join_equals_tensorstate_join():
    """End-to-end: the Pallas join produces exactly the TensorState join."""
    from repro.core.tensor_lattice import (ChunkedTensor, TensorState,
                                           chunk_tensor)
    rng = np.random.default_rng(3)
    n, chunk = 16, 128
    a_vals = rng.normal(size=(n, chunk)).astype(np.float32)
    b_vals = rng.normal(size=(n, chunk)).astype(np.float32)
    a_vers = rng.integers(0, 5, size=(n,)).astype(np.int32)
    b_vers = rng.integers(0, 5, size=(n,)).astype(np.int32)
    # ties must agree (lattice precondition)
    tie = a_vers == b_vers
    b_vals[tie] = a_vals[tie]

    A = TensorState.of({"w": ChunkedTensor(jnp.asarray(a_vals),
                                           jnp.asarray(a_vers))})
    B = TensorState.of({"w": ChunkedTensor(jnp.asarray(b_vals),
                                           jnp.asarray(b_vers))})
    lattice_join = A.join(B).as_dict()["w"]
    kv, kvers = ops.delta_join(jnp.asarray(a_vals), jnp.asarray(a_vers),
                               jnp.asarray(b_vals), jnp.asarray(b_vers),
                               block_n=n, interpret=True)
    np.testing.assert_array_equal(np.asarray(lattice_join.values),
                                  np.asarray(kv))
    np.testing.assert_array_equal(np.asarray(lattice_join.versions),
                                  np.asarray(kvers))


# ---------------------------------------------------------------------------
# Fused join+digest and scatter-ingest (the resident-store kernels)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,chunk,bn", [
    (64, 128, 32), (100, 128, 32),   # ragged row count
    (7, 256, 8), (13, 128, 13),
])
def test_fused_join_digest_matches_ref(dtype, n, chunk, bn):
    av, avers = _mk(n, chunk, dtype, 30)
    bv, bvers = _mk(n, chunk, dtype, 31)
    ov, overs, ma, ss = ops.fused_join_digest(av, avers, bv, bvers,
                                              block_n=bn, interpret=True)
    rv, rvers, rma, rss = ops.fused_join_digest_ref(av, avers, bv, bvers)
    np.testing.assert_array_equal(np.asarray(ov), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(overs), np.asarray(rvers))
    np.testing.assert_allclose(np.asarray(ma), np.asarray(rma), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ss), np.asarray(rss), rtol=1e-5)


def test_fused_join_digest_auto_dispatch_matches_interpret():
    """interpret=None (the hot-path default: XLA oracle on CPU) computes
    exactly what the interpret-mode Pallas kernel computes."""
    av, avers = _mk(24, 128, jnp.float32, 32)
    bv, bvers = _mk(24, 128, jnp.float32, 33)
    auto = ops.fused_join_digest(av, avers, bv, bvers)
    pallas = ops.fused_join_digest(av, avers, bv, bvers, interpret=True)
    for x, y in zip(auto, pallas):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5)


def _mk_scatter(n, r, chunk, seed, vdtype=np.float32):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=(n, chunk)).astype(vdtype))
    vers = jnp.asarray(rng.integers(0, 50, size=(n,)).astype(np.int32))
    ma, ss = ops.chunk_digest_ref(vals)
    idx = np.sort(rng.choice(n, size=r, replace=False)).astype(np.int32)
    d_vals = jnp.asarray(rng.normal(size=(r, chunk)).astype(vdtype))
    d_vers = jnp.asarray(rng.integers(0, 80, size=(r,)).astype(np.int32))
    return vals, vers, ma, ss, jnp.asarray(idx), d_vals, d_vers


@pytest.mark.parametrize("n,r,chunk", [
    (32, 5, 128), (64, 64, 128),     # full coverage
    (17, 3, 256), (8, 1, 128),
])
def test_scatter_join_matches_ref(n, r, chunk):
    args = _mk_scatter(n, r, chunk, 40)
    outs = ops.scatter_join(*args, interpret=True)
    refs = ops.scatter_join_ref(*args)
    for x, y in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5)


def test_scatter_join_preserves_untouched_rows():
    """Rows not listed in idx come back bit-identical (the aliased
    in-place contract of the resident columns)."""
    vals, vers, ma, ss, idx, d_vals, d_vers = _mk_scatter(40, 4, 128, 41)
    ov, overs, oma, oss = ops.scatter_join(vals, vers, ma, ss, idx,
                                           d_vals, d_vers, interpret=True)
    touched = set(np.asarray(idx).tolist())
    keep = np.array([i for i in range(40) if i not in touched])
    np.testing.assert_array_equal(np.asarray(ov)[keep],
                                  np.asarray(vals)[keep])
    np.testing.assert_array_equal(np.asarray(overs)[keep],
                                  np.asarray(vers)[keep])
    np.testing.assert_array_equal(np.asarray(oma)[keep],
                                  np.asarray(ma)[keep])
    np.testing.assert_array_equal(np.asarray(oss)[keep],
                                  np.asarray(ss)[keep])


def test_scatter_join_empty_idx_is_a_launch_free_noop():
    vals, vers, ma, ss, _, _, _ = _mk_scatter(16, 2, 128, 42)
    empty = jnp.zeros((0,), jnp.int32)
    snap = ops.counters.snapshot()
    outs = ops.scatter_join(vals, vers, ma, ss, empty,
                            jnp.zeros((0, 128), vals.dtype),
                            jnp.zeros((0,), vers.dtype))
    assert ops.counters.since(snap)["launches"] == 0
    assert outs[0] is vals and outs[1] is vers


def test_scatter_join_auto_dispatch_matches_interpret():
    args = _mk_scatter(30, 6, 128, 43)
    auto = ops.scatter_join(*args)
    pallas = ops.scatter_join(*args, interpret=True)
    for x, y in zip(auto, pallas):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5)


def test_counters_count_launches_and_numpy_staging_only():
    """One wrapper call = one launch; numpy operands count their nbytes
    as host→device staging, device-resident jax.Arrays count zero."""
    av_np = np.random.default_rng(44).normal(size=(8, 128)) \
        .astype(np.float32)
    avers_np = np.ones((8,), np.int32)
    snap = ops.counters.snapshot()
    ops.fused_join_digest(av_np, avers_np, av_np, avers_np)
    d = ops.counters.since(snap)
    assert d["launches"] == 1
    assert d["h2d_bytes"] == 2 * (av_np.nbytes + avers_np.nbytes)
    av, avers = jnp.asarray(av_np), jnp.asarray(avers_np)
    snap = ops.counters.snapshot()
    ops.fused_join_digest(av, avers, av, avers)
    d = ops.counters.since(snap)
    assert d["launches"] == 1 and d["h2d_bytes"] == 0
