"""Pallas flash-attention kernels vs the pure-jnp oracle: shape/dtype
sweeps (GQA ratios, windows, softcaps, ring caches), interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

jax.config.update("jax_platforms", "cpu")


def _mk(b, h, kv, sq, sk, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, sq, hd)).astype(np.float32), dtype)
    k = jnp.asarray(rng.normal(size=(b, kv, sk, hd)).astype(np.float32), dtype)
    v = jnp.asarray(rng.normal(size=(b, kv, sk, hd)).astype(np.float32), dtype)
    return q, k, v


TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,hd,bq,bk", [
    (1, 4, 4, 256, 64, 128, 128),    # MHA
    (2, 8, 2, 256, 64, 128, 128),    # GQA 4:1
    (1, 4, 1, 512, 128, 128, 128),   # MQA
    (1, 2, 2, 128, 32, 64, 64),      # small blocks
])
def test_flash_fwd_matches_ref(dtype, b, h, kv, s, hd, bq, bk):
    q, k, v = _mk(b, h, kv, s, s, hd, dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk,
                              interpret=True)
    want = ops.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               **TOLS[dtype])


@pytest.mark.parametrize("window", [32, 128, 1000])
def test_flash_fwd_sliding_window(window):
    q, k, v = _mk(1, 4, 2, 256, 256, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, window=window, block_q=64,
                              block_k=64, interpret=True)
    want = ops.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_fwd_softcap():
    q, k, v = _mk(1, 2, 2, 128, 128, 64, jnp.float32, seed=3)
    out = ops.flash_attention(q, k, v, softcap=30.0, block_q=64,
                              block_k=64, interpret=True)
    want = ops.attention_ref(q, k, v, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_fwd_scale_override():
    q, k, v = _mk(1, 2, 2, 128, 128, 64, jnp.float32, seed=4)
    out = ops.flash_attention(q, k, v, scale=0.0825, block_q=64,
                              block_k=64, interpret=True)
    want = ops.attention_ref(q, k, v, scale=0.0825)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Decode kernel (ring caches, partial fills, windows)
# ---------------------------------------------------------------------------

def _mk_cache(b, kv, C, hd, filled, dtype, seed=0, ring_window=None):
    """Cache with `filled` tokens written; ring semantics if window."""
    rng = np.random.default_rng(seed)
    k = np.zeros((b, kv, C, hd), np.float32)
    v = np.zeros((b, kv, C, hd), np.float32)
    pos = np.full((b, C), -1, np.int32)
    for t in range(filled):
        slot = t % C
        k[:, :, slot] = rng.normal(size=(b, kv, hd))
        v[:, :, slot] = rng.normal(size=(b, kv, hd))
        pos[:, slot] = t
    return (jnp.asarray(k, dtype), jnp.asarray(v, dtype),
            jnp.asarray(pos))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,C,hd,filled", [
    (2, 4, 4, 256, 64, 256),    # full cache
    (2, 8, 2, 256, 64, 100),    # partially filled (invalid slots masked)
    (1, 4, 1, 512, 128, 300),
])
def test_flash_decode_matches_ref(dtype, b, h, kv, C, hd, filled):
    k, v, kpos = _mk_cache(b, kv, C, hd, filled, dtype)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, h, 1, hd)).astype(np.float32), dtype)
    qpos = jnp.full((b, 1), filled, jnp.int32)
    out = ops.flash_decode(q, k, v, qpos, kpos, block_k=128, interpret=True)
    want = ops.decode_ref(q, k, v, qpos, kpos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOLS[dtype])


def test_flash_decode_ring_cache_with_window():
    """SWA ring buffer: 300 tokens through a 128-slot ring, window 128."""
    b, h, kv, C, hd = 1, 4, 2, 128, 64
    k, v, kpos = _mk_cache(b, kv, C, hd, filled=300, dtype=jnp.float32,
                           ring_window=128)
    q = jnp.asarray(np.random.default_rng(2).normal(size=(b, h, 1, hd)),
                    jnp.float32)
    qpos = jnp.full((b, 1), 300, jnp.int32)
    out = ops.flash_decode(q, k, v, qpos, kpos, window=128, block_k=64,
                           interpret=True)
    want = ops.decode_ref(q, k, v, qpos, kpos, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_empty_cache_rows_are_zero():
    b, h, kv, C, hd = 1, 2, 2, 128, 64
    k, v, kpos = _mk_cache(b, kv, C, hd, filled=0, dtype=jnp.float32)
    q = jnp.ones((b, h, 1, hd), jnp.float32)
    qpos = jnp.zeros((b, 1), jnp.int32)
    out = ops.flash_decode(q, k, v, qpos, kpos, interpret=True)
    assert np.allclose(np.asarray(out), 0.0)
