"""Join-semilattice laws for every datatype (paper §3: join is designed to
be commutative, associative, and idempotent; mutators are inflations)."""

import random

import pytest
import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from crdt_adapters import ADAPTERS, REPLICAS, random_reachable_states

ADAPTER_NAMES = sorted(ADAPTERS)


@pytest.mark.parametrize("name", ADAPTER_NAMES)
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_join_laws(name, seed):
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    a, b, c = random_reachable_states(ad, rng, n_ops=12)

    # idempotence, commutativity, associativity
    assert a.join(a) == a
    assert a.join(b) == b.join(a)
    assert a.join(b).join(c) == a.join(b.join(c))

    # bottom is the identity
    assert a.join(ad.bottom) == a
    assert ad.bottom.join(a) == a


@pytest.mark.parametrize("name", ADAPTER_NAMES)
@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_inflation_and_partial_order(name, seed):
    """X ⊑ X ⊔ mᵟ(X) — the join-with-delta transition inflates (Def. 3),
    and ``leq`` derived from join is a partial order on reachable states."""
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    a, b, _ = random_reachable_states(ad, rng, n_ops=10)

    r = rng.choice(REPLICAS)
    op = rng.choice(ad.ops)
    args = op.make_args(rng)
    d = op.delta(a, r, *args)
    a2 = a.join(d)
    assert a.leq(a2)

    # partial order sanity
    assert a.leq(a)
    j = a.join(b)
    assert a.leq(j) and b.leq(j)
    if a.leq(b) and b.leq(a):
        assert a == b


@pytest.mark.parametrize("name", ADAPTER_NAMES)
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_join_is_lub(name, seed):
    """⊔ is the *least* upper bound: any common upper bound u of {a, b}
    dominates a ⊔ b."""
    ad = ADAPTERS[name]
    rng = random.Random(seed)
    a, b, c = random_reachable_states(ad, rng, n_ops=10)
    u = a.join(b).join(c)  # some upper bound of a and b
    assert a.join(b).leq(u)
