"""Key lifecycle subsystem: expiry lattice, acked reaper GC, read
replicas.

The load-bearing properties:

* the per-key ``(epoch, expiry)`` lifecycle component keeps
  ``LatticeStore`` a join-semilattice (lex product of a chain with the
  value lattice): joins stay idempotent/commutative/associative, a
  tombstone (bumped epoch, no value) ⊥-absorbs every straggler delta of
  the reaped incarnation in either join order, and a touch only ever
  extends the expiry;
* digests and wire frames carry lifecycle state end to end — pull-sync
  propagates tombstones and expiry extensions, never resurrects a reaped
  key, and the encode-time filter still matches the ``digest_diff``
  oracle;
* the reaper only commits with the whole write replica set's acks (a
  partitioned member blocks the reap until it can vote), and a straggler
  that replays pre-reap deltas converges to the reaped state;
* read replicas subscribe to a hot key's gossip via digest pull without
  joining its write set, its push traffic, or its reap quorum;
* every per-peer map — engine bookkeeping and reaper ack sets alike —
  is pruned for departed peers through one registry.
"""

import random

import numpy as np
import pytest

from repro.core import (Compose, GCounter, GSet, LatticeStore, MVRegister,
                        NetConfig, Simulator, StoreDigest, StoreReplica,
                        digest_diff, make_policy, store_digest)
from repro.core.tensor_lattice import TensorState, chunk_tensor
from repro.lifecycle import (LIFE_BOTTOM, NO_EXPIRY, ReaperProtocol,
                             expired, touch)
from repro.sync import KeyOwnership, ShardByKey
from repro.wire import (WireCodec, decode_digest, decode_store,
                        encode_digest, encode_frame, encode_store,
                        encode_value, store_body_is_empty)


# ---------------------------------------------------------------------------
# The lifecycle lattice inside LatticeStore
# ---------------------------------------------------------------------------

def _counter_store(**vals):
    out = LatticeStore.bottom()
    for key, n in vals.items():
        out = out.join(LatticeStore.bottom().apply_delta(
            key, GCounter, "inc_delta", "r", n))
    return out


def _sample_stores():
    """A small mixed family: values, expiries, tombstones, revivals."""
    v = _counter_store(a=3, b=1)
    return [
        LatticeStore.bottom(),
        v,
        v.join(LatticeStore.life_delta("a", (0, 5.0))),
        LatticeStore.life_delta("a", (1, 7.0)),             # tombstone
        LatticeStore.life_delta("b", (2, 1.0)),
        _counter_store(a=9).join(LatticeStore.life_delta("a", (1, 9.0))),
        LatticeStore.life_delta("c", (0, 3.0)),             # expiry only
    ]


def test_lifecycle_store_lattice_laws():
    S = _sample_stores()
    for x in S:
        assert x.join(x) == x
        for y in S:
            assert x.join(y) == y.join(x)
            assert x.leq(x.join(y)) and y.leq(x.join(y))
            for z in S:
                assert x.join(y).join(z) == x.join(y.join(z))


def test_tombstone_absorbs_straggler_both_orders():
    v = _counter_store(a=5)
    tomb = LatticeStore.life_delta("a", (1, 2.0))
    s = v.join(tomb)
    assert s.tombstoned("a") and s.get("a", GCounter).value() == 0
    assert s.join(v) == s and v.join(s) == s
    # a tombstone also absorbs *fresh* epoch-0 writes (normal writes
    # cannot resurrect; revival is an explicit epoch bump)
    late = _counter_store(a=100)
    assert s.join(late) == s


def test_touch_extends_never_shrinks():
    life = (0, 10.0)
    assert touch(life, 5.0, 2.0) == (0, 10.0)       # 7 < 10: no shrink
    assert touch(life, 9.0, 4.0) == (0, 13.0)
    s = LatticeStore.life_delta("k", (0, 10.0))
    assert s.join(LatticeStore.life_delta("k", (0, 6.0))).life_of("k") \
        == (0, 10.0)


def test_revival_is_a_new_incarnation_above_the_tombstone():
    tomb = LatticeStore.life_delta("a", (1, 2.0))
    # with_life STAMPS the delta's epoch (a join would treat the value
    # as epoch-0 and absorb it — which is exactly the straggler rule)
    revived = _counter_store(a=7).with_life("a", (2, 30.0))
    s = tomb.join(revived)
    assert not s.tombstoned("a")
    assert s.get("a", GCounter).value() == 7
    # a late reap commit against epoch 1 (tombstone to epoch 2 carries
    # no value) cannot kill the revival — equal epochs join values
    late_commit = LatticeStore.life_delta("a", (2, 2.0))
    assert s.join(late_commit).get("a", GCounter).value() == 7


def test_lifecycle_leq_eq_and_decompose():
    v = _counter_store(a=3)
    t = v.join(LatticeStore.life_delta("a", (1, 4.0)))
    assert v.leq(t) and not t.leq(v)
    assert LatticeStore.bottom().leq(t)
    assert t != LatticeStore.bottom() and t != v
    big = t.join(_counter_store(b=2)).join(
        LatticeStore.life_delta("b", (0, 9.0)))
    atoms = big.decompose()
    rejoined = LatticeStore.bottom()
    for a in atoms:
        assert a.leq(big)
        rejoined = rejoined.join(a)
    assert rejoined == big


def test_restrict_and_all_keys_carry_tombstones():
    s = _counter_store(a=1).join(LatticeStore.life_delta("t", (1, 0.0)))
    assert s.all_keys() == {"a", "t"}
    assert s.keys() == {"a"}
    kept = s.restrict(["t"])
    assert kept.tombstoned("t") and kept.all_keys() == {"t"}
    assert s.restrict(["a"]).life == ()


def test_expired_predicate():
    assert not expired(LIFE_BOTTOM, 1e9)            # no TTL ⇒ immortal
    assert expired((0, 5.0), 5.0) and not expired((0, 5.0), 4.9)


def test_tensor_stores_with_matching_epochs_still_batch_join():
    rng = np.random.default_rng(0)
    mk = lambda seed: LatticeStore.of(
        {f"k{i}": TensorState.of({"w": chunk_tensor(
            np.random.default_rng(seed + i).normal(size=(32,))
            .astype(np.float32), 8, version=seed + 1)})
         for i in range(4)},
        life={f"k{i}": (0, 50.0) for i in range(4)})
    a, b = mk(1), mk(5)
    joined = a.join(b)
    oracle = a.join(b, batched=False)
    assert joined == oracle
    assert joined.life_of("k0") == (0, 50.0)


# ---------------------------------------------------------------------------
# Digest + wire carry lifecycle state
# ---------------------------------------------------------------------------

def test_store_digest_and_frames_carry_life():
    s = _counter_store(a=2).join(
        LatticeStore.life_delta("a", (0, 9.0))).join(
        LatticeStore.life_delta("t", (3, 1.0)))
    dg = store_digest(s)
    assert dg.life == {"a": (0, 9.0), "t": (3, 1.0)}
    assert decode_digest(encode_digest(dg)) == dg
    rt = decode_store(encode_store(s))
    assert rt == s and rt.tombstoned("t")


def test_digest_diff_epoch_rules():
    fresh = _counter_store(a=4)
    # requester tombstoned past the responder: nothing ships
    req = StoreDigest(life={"a": (1, 2.0)})
    d = digest_diff(fresh, req)
    assert d == LatticeStore.bottom()
    # requester behind an epoch: the key ships wholesale, with its life
    revived = fresh.with_life("a", (1, 8.0))
    d2 = digest_diff(revived, StoreDigest(life={"a": (0, 5.0)}))
    assert d2.get("a", GCounter).value() == 4
    assert d2.life_of("a") == (1, 8.0)
    # same epoch, only a fresher expiry: just the life entry ships
    d3 = digest_diff(fresh.join(LatticeStore.life_delta("a", (0, 9.0))),
                     store_digest(fresh))
    assert d3.keys() == frozenset() and d3.life_of("a") == (0, 9.0)


def test_shipped_values_carry_epoch_stamp_even_when_life_dominated():
    """Regression (found by the random-schedule property): requester
    holds a tombstone (3, 5.0) for 'b'; responder holds a *value* at
    epoch 3 whose life (3, -inf) is lex-dominated, so the life entry
    itself is filtered from the diff — but the value must still ship
    with an epoch stamp, or it joins at epoch 0 and the requester's own
    tombstone absorbs the very rows it asked for."""
    requester = LatticeStore.life_delta("b", (3, 5.0))
    responder = _counter_store(b=6).with_life("b", (3, NO_EXPIRY))
    dg = store_digest(requester)
    d = digest_diff(responder, dg)
    assert d.life_of("b")[0] == 3
    assert requester.join(d) == requester.join(responder)
    assert requester.join(d).get("b", GCounter).value() == 6
    wire_d = decode_store(encode_store(
        responder, known_versions=dg.tensors, known_opaque=dg.opaque,
        known_life=dg.life))
    assert requester.join(wire_d) == requester.join(responder)


def test_digest_diff_join_equivalence_with_lifecycle():
    """requester ⊔ diff == requester ⊔ responder, across epoch skews."""
    base = _counter_store(a=3, b=2)
    variants = [
        base,
        base.join(LatticeStore.life_delta("a", (0, 5.0))),
        base.join(LatticeStore.life_delta("a", (1, 5.0))),
        base.join(LatticeStore.life_delta("b", (2, 1.0))).join(
            _counter_store(c=1)),
        LatticeStore.life_delta("a", (4, 0.0)),
    ]
    for requester in variants:
        for responder in variants:
            d = digest_diff(responder, store_digest(requester))
            assert requester.join(d) == requester.join(responder), \
                (requester, responder, d)


def test_wire_digest_response_filter_matches_oracle_with_life():
    stores = [
        _counter_store(a=3).join(LatticeStore.life_delta("a", (1, 2.0))),
        _counter_store(a=1, b=5).join(
            LatticeStore.life_delta("b", (0, 7.0))),
        LatticeStore.life_delta("a", (2, 0.0)),
    ]
    for requester in stores:
        for responder in stores:
            dg = store_digest(requester)
            body = encode_store(responder, known_versions=dg.tensors,
                                known_opaque=dg.opaque, known_life=dg.life)
            decoded = decode_store(body)
            assert requester.join(decoded) == requester.join(responder)
            if store_body_is_empty(body):
                assert digest_diff(responder, dg) == LatticeStore.bottom()


def test_life_only_response_is_not_dropped_as_empty():
    responder = LatticeStore.life_delta("k", (1, 3.0))
    requester_digest = store_digest(_counter_store(k=2))
    wire = WireCodec()
    frame = wire.encode_msg(("digest-resp", responder, requester_digest))
    assert frame is not None
    kind, *rest = wire.decode_msg(frame)
    assert kind == "digest-resp" and rest[0].tombstoned("k")
    # and a fully-covered response still yields no frame at all
    same = _counter_store(k=2)
    assert wire.encode_msg(("digest-resp", same,
                            store_digest(same))) is None


def test_unaligned_columns_do_not_swallow_life_table():
    """Regression (review finding): the plain-path decoder never skipped
    the trailing 8-byte column pad, so with a values column whose byte
    length is not a multiple of 8 (here: chunk width 1 float32, 3 rows
    = 12B) the life-table count was read from pad zeros and every
    tombstone/expiry silently vanished in transit — and in multi-group
    payloads the next group header desynced the same way."""
    s = LatticeStore.of(
        {"k": TensorState.of({"a": chunk_tensor(
            np.arange(3, dtype=np.float32), 1, version=1)}),
         # second signature group (different chunk width), also unaligned
         "m": TensorState.of({"b": chunk_tensor(
            np.arange(9, dtype=np.float32), 3, version=2)})},
        life={"gone": (1, 50.0), "k": (0, 9.0)})
    rt = decode_store(encode_store(s))
    assert rt == s
    assert rt.tombstoned("gone") and rt.life_of("k") == (0, 9.0)


def test_reap_frames_roundtrip():
    wire = WireCodec()
    for msg in [("reap", "sess/0", 2, 17.5),
                ("reap-ack", "sess/0", 2, 17.5, 1),
                ("reap-ack", "κλειδί", 0, float("-inf"), 0)]:
        frame = wire.encode_msg(msg)
        assert frame.kind == msg[0]
        assert wire.decode_msg(frame) == msg


# ---------------------------------------------------------------------------
# Per-group column compression (WireCodec(compress=True))
# ---------------------------------------------------------------------------

def _compressible_store(n_keys=8, n_chunks=8, chunk=64):
    rng = np.random.default_rng(0)
    return LatticeStore.of({
        f"k{i}": TensorState.of({"w": chunk_tensor(
            rng.integers(0, 4, size=(n_chunks * chunk,))
            .astype(np.float32), chunk, version=1)})
        for i in range(n_keys)})


def test_compressed_store_roundtrip_identity():
    s = _compressible_store().join(LatticeStore.life_delta("k0", (0, 5.0)))
    plain = encode_store(s)
    packed = encode_store(s, compress=True)
    assert decode_store(packed) == decode_store(plain) == s


def test_compressed_frame_smaller_and_crc_protected():
    from repro.wire import FrameError, decode_frame
    s = _compressible_store()
    plain = encode_frame("state", encode_value(s))
    packed = encode_frame("state", encode_value(s, True))
    assert len(packed) < len(plain)
    flipped = bytearray(packed)
    flipped[len(flipped) // 2] ^= 0x40       # corrupt the deflate stream
    with pytest.raises(FrameError, match="checksum"):
        decode_frame(bytes(flipped))


def test_wirecodec_compress_flag_is_end_to_end():
    s = _compressible_store()
    frame = WireCodec(compress=True).encode_msg(("handoff", s))
    assert WireCodec().decode_msg(frame)[1] == s     # self-describing


# ---------------------------------------------------------------------------
# The reaper protocol
# ---------------------------------------------------------------------------

def _mesh(wire=None, replication=2, ttl=5.0, loss=0.1, seed=3,
          read_replication=None, n=3):
    ids = [f"gw{k}" for k in range(n)]
    ownership = KeyOwnership(ids, replication=replication,
                             read_replication=read_replication)
    sim = Simulator(NetConfig(loss=loss, seed=seed))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=Compose(make_policy("bp+rr+digest-sync:4"),
                       ShardByKey(ownership)),
        rng=random.Random(seed + k), ownership=ownership, wire=wire,
        ttl=ttl)) for k, i in enumerate(ids)]
    reapers = [ReaperProtocol(node, ownership, grace=1.0, retry=2.0)
               for node in nodes]
    for node in nodes:
        sim.every(1.0, node.on_periodic)
        sim.every(7.0, node.gc_deltas)
    return sim, nodes, reapers, ownership


@pytest.mark.parametrize("wire", [None, WireCodec(), WireCodec(True)],
                         ids=["object", "wire", "wire-z"])
def test_reaper_drops_expired_keys_with_full_quorum(wire):
    sim, nodes, reapers, ownership = _mesh(wire=wire)
    by_id = {n.id: n for n in nodes}
    keys = [f"sess{i}" for i in range(8)]
    for i, key in enumerate(keys):
        nodes[i % 3].update(key, MVRegister, "write_delta",
                            nodes[i % 3].id, "done")
    sim.run_for(3.0)
    # keep sess0 alive with touches while everything else expires
    for t in range(25):
        nodes[0].update("sess0", MVRegister, "write_delta", "gw0", f"w{t}")
        sim.run_for(1.0)
    for key in keys[1:]:
        for w in ownership.owners(key):
            st = by_id[w].X
            assert st.tombstoned(key), (key, w, st.life_of(key))
    for w in ownership.owners("sess0"):
        st = by_id[w].X
        assert not st.tombstoned("sess0")
        assert st.get("sess0", MVRegister).read()  # value intact
    assert sum(r.reaped for r in reapers) >= len(keys) - 1


def test_partitioned_member_blocks_reap_until_heal():
    sim, nodes, reapers, ownership = _mesh(loss=0.0, seed=11)
    by_id = {n.id: n for n in nodes}
    nodes[0].update("cold", MVRegister, "write_delta", "gw0", "x")
    sim.run_for(3.0)
    owners = ownership.owners("cold")
    blocked = owners[1]
    sim.add_partition(sim.time, sim.time + 20.0, [blocked],
                      [i for i in by_id if i != blocked])
    sim.run_for(18.0)            # expiry long past; quorum cannot form
    assert not by_id[owners[0]].X.tombstoned("cold")
    sim.run_for(30.0)            # heal → acks → commit → gossip
    for w in owners:
        assert by_id[w].X.tombstoned("cold")


@pytest.mark.parametrize("wire", [None, WireCodec()],
                         ids=["object", "wire"])
def test_straggler_replay_never_resurrects(wire):
    sim, nodes, reapers, ownership = _mesh(wire=wire, loss=0.0, seed=17)
    by_id = {n.id: n for n in nodes}
    owners = ownership.owners("ghost")
    straggler = [i for i in by_id if i not in owners][0]
    ingress = by_id[straggler]
    ingress.update("ghost", MVRegister, "write_delta", straggler, "alive")
    sim.run_for(3.0)             # delta reaches the owners
    pre_reap = ingress.X.restrict(["ghost"])
    assert pre_reap.keys() == {"ghost"}
    sim.run_for(30.0)            # expiry passes, owners reap
    primary = by_id[owners[0]]
    assert primary.X.tombstoned("ghost")
    # replay the pre-reap delta straight into every owner (dup/loss model:
    # an arbitrarily late retransmission)
    for w in owners:
        node = by_id[w]
        node.on_receive(straggler, wire.encode_msg(("handoff", pre_reap))
                        if wire else ("handoff", pre_reap))
        assert node.X.tombstoned("ghost"), "straggler replay resurrected"
        assert node.X.get("ghost", MVRegister).read() == frozenset()


def test_touched_key_cancels_inflight_proposal():
    sim, nodes, reapers, ownership = _mesh(loss=0.0, seed=23, ttl=4.0)
    by_id = {n.id: n for n in nodes}
    owners = ownership.owners("busy")
    primary = by_id[owners[0]]
    primary.update("busy", MVRegister, "write_delta", primary.id, "v0")
    sim.run_for(5.5)             # expiry passing; proposals start
    primary.update("busy", MVRegister, "write_delta", primary.id, "v1")
    sim.run_for(2.0)
    assert not primary.X.tombstoned("busy")     # touch cancelled the reap
    sim.run_for(30.0)
    assert primary.X.tombstoned("busy")         # …until it expired again


def test_crash_resets_proposals_but_reap_still_happens():
    sim, nodes, reapers, ownership = _mesh(loss=0.0, seed=29)
    by_id = {n.id: n for n in nodes}
    owners = ownership.owners("crashkey")
    primary = by_id[owners[0]]
    primary.update("crashkey", MVRegister, "write_delta", primary.id, "x")
    sim.run_for(7.0)             # expiry near/past, proposal in flight
    sim.crash(primary.id, 3.0)
    assert primary.reaper.pending_keys() in ({"crashkey"}, frozenset())
    sim.run_for(5.0)
    assert primary.reaper.pending_keys() == frozenset() or primary.alive
    sim.run_for(30.0)
    for w in owners:
        assert by_id[w].X.tombstoned("crashkey")


def test_departed_peer_leaves_quorum_and_registry():
    """The single per-peer registry: a departed worker's reaper acks,
    engine watermarks and ack maps all clear in prune_departed — and the
    quorum re-derives, so the reap completes without the dead peer."""
    ids = ["gw0", "gw1", "gw2"]
    live = set(ids)
    ownership = KeyOwnership(lambda: sorted(live), replication=3)
    sim = Simulator(NetConfig(loss=0.0, seed=31))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=make_policy("bp+rr"), rng=random.Random(31 + k),
        ownership=ownership, ttl=4.0)) for k, i in enumerate(ids)]
    reapers = {n.id: ReaperProtocol(n, ownership, grace=0.5, retry=1.0)
               for n in nodes}
    for n in nodes:
        sim.every(1.0, n.on_periodic)
    by_id = {n.id: n for n in nodes}
    primary_id = ownership.owner("doomed")
    primary = by_id[primary_id]
    dead = [i for i in ids if i != primary_id][0]
    sim.nodes[dead].alive = False            # silent forever
    primary.update("doomed", MVRegister, "write_delta", primary_id, "x")
    sim.run_for(10.0)                        # proposal stuck on dead peer
    assert not primary.X.tombstoned("doomed")
    prop_acks = reapers[primary_id]._pending["doomed"].acks
    # fill per-peer state for the dead peer, then depart it
    primary._basic_sent[dead] = 7
    live.discard(dead)
    primary.neighbors = [j for j in primary.neighbors if j != dead]
    primary.prune_departed()
    assert dead not in primary.A and dead not in primary._known
    assert dead not in primary._basic_sent
    assert all(key[0] != dead for key in primary._inflight)
    assert dead not in prop_acks
    sim.run_for(10.0)                        # quorum re-derived: commits
    assert primary.X.tombstoned("doomed")


def test_foreign_ingress_copies_are_evicted():
    sim, nodes, reapers, ownership = _mesh(loss=0.0, seed=37)
    by_id = {n.id: n for n in nodes}
    owners = ownership.owners("fkey")
    foreign = [i for i in by_id if i not in owners][0]
    by_id[foreign].update("fkey", MVRegister, "write_delta", foreign, "x")
    sim.run_for(3.0)
    assert by_id[foreign].X.get("fkey") is not None
    sim.run_for(30.0)
    assert by_id[foreign].X.get("fkey") is None
    assert "fkey" not in by_id[foreign].X.all_keys()     # fully shed
    assert by_id[foreign].reaper.evicted >= 1
    for w in owners:
        assert by_id[w].X.tombstoned("fkey")             # quorum reaped


# ---------------------------------------------------------------------------
# Read replicas
# ---------------------------------------------------------------------------

def test_commit_and_foreign_eviction_in_one_step_keep_the_tombstone():
    """Regression (review finding): step() used to restrict its loop-entry
    store snapshot back into X after evicting foreign copies, silently
    discarding a tombstone committed earlier in the same step."""
    ids = ["n0", "n1"]
    ownership = KeyOwnership(ids, replication=1)
    sim = Simulator(NetConfig(loss=0.0, seed=1))
    node = sim.add_node(StoreReplica("n0", ["n1"], causal=True,
                                     ownership=ownership, ttl=2.0))
    sim.add_node(StoreReplica("n1", ["n0"], causal=True,
                              ownership=ownership, ttl=2.0))
    reaper = ReaperProtocol(node, ownership, grace=0.0, retry=1.0)
    mine = next(k for k in (f"k{i}" for i in range(99))
                if ownership.owner(k) == "n0")
    foreign = next(k for k in (f"k{i}" for i in range(99))
                   if ownership.owner(k) == "n1")
    node.update(mine, MVRegister, "write_delta", "n0", "x")
    node.update(foreign, MVRegister, "write_delta", "n0", "y")
    sim.run_for(5.0)                 # both past expiry
    reaper.step()                    # replication=1: commit is immediate
    assert node.X.tombstoned(mine), "commit lost to the eviction snapshot"
    assert foreign not in node.X.all_keys()
    assert reaper.reaped == 1 and reaper.evicted == 1


def test_nacked_proposal_keeps_retry_throttle():
    """Regression (review finding): a nack used to pop the proposal, and
    the next step rebuilt it with a fresh retransmit clock — reap frames
    then went out every round instead of every `retry` seconds."""
    ids = ["n0", "n1"]
    ownership = KeyOwnership(ids, replication=2)
    sim = Simulator(NetConfig(loss=0.0, seed=1, min_delay=0.01,
                              max_delay=0.05))
    a = sim.add_node(StoreReplica("n0", ["n1"], causal=True,
                                  ownership=ownership, ttl=1.0))
    b = sim.add_node(StoreReplica("n1", ["n0"], causal=True,
                                  ownership=ownership, ttl=1.0))
    primary = a if ownership.owner("k") == "n0" else b
    other = b if primary is a else a
    reaper = ReaperProtocol(primary, ownership, grace=0.0, retry=10.0)
    primary.update("k", MVRegister, "write_delta", primary.id, "x")
    sim.run_for(2.0)                 # expired at the proposer…
    # …but the member holds a fresher expiry, so it keeps nacking
    other.X = other.X.join(LatticeStore.life_delta("k", (0, 1e9)))
    for _ in range(6):
        reaper.step()
        sim.run_for(0.2)
    sent = sim.stats.by_kind.get("reap", 0)
    assert sent <= 2, f"{sent} reap frames in 6 steps under retry=10"
    assert not primary.X.tombstoned("k")
    own = KeyOwnership(["a", "b", "c", "d"], replication=2,
                       read_replication=3)
    owners = own.owners("k")
    readers = own.readers("k")
    assert len(owners) == 2 and len(readers) == 3
    assert set(owners) < set(readers)
    outside = (set("abcd") - set(readers)).pop()
    assert not own.reads(outside, "k")
    own.subscribe(outside, "k")
    assert own.reads(outside, "k") and outside not in own.owners("k")
    own.unsubscribe(outside, "k")
    assert not own.reads(outside, "k")
    with pytest.raises(ValueError):
        KeyOwnership(["a"], replication=2, read_replication=1)


def test_read_replica_converges_via_pull_without_write_set():
    """A subscriber pulls a hot key's rows through digest-sync, serves
    them locally, never buffers/forwards the key, never joins the reap
    quorum — and the tombstone still reaches it through pull."""
    sim, nodes, reapers, ownership = _mesh(loss=0.0, seed=41, n=4,
                                           wire=WireCodec())
    by_id = {n.id: n for n in nodes}
    owners = ownership.owners("hot")
    reader_id = [i for i in by_id if i not in owners][0]
    reader = by_id[reader_id]
    ownership.subscribe(reader_id, "hot")
    writer = by_id[owners[0]]
    for t in range(8):
        writer.update("hot", MVRegister, "write_delta", writer.id, f"v{t}")
        sim.run_for(1.0)
    sim.run_for(8.0)                 # a pull round lands (every:4 cadence)
    assert reader.X.get("hot", MVRegister).read() == frozenset({"v7"})
    # the reader never buffers the hot key (it is not in the write set),
    # so its push rounds cannot forward it
    assert all("hot" not in e.delta.all_keys()
               for e in reader.entries.values()
               if isinstance(e.delta, LatticeStore))
    # reap quorum = the write set only; the reader holding the value
    # must not block the reap
    sim.run_for(40.0)
    for w in owners:
        assert by_id[w].X.tombstoned("hot")
    sim.run_for(20.0)                # tombstone reaches the reader by pull
    assert reader.X.tombstoned("hot") or reader.X.get("hot") is None


# ---------------------------------------------------------------------------
# Randomized write/expire/reap schedules (the property-test driver; the
# hypothesis wrapper lives in test_lifecycle_properties.py — this module
# pre-validates the body over fixed seeds so the property holds even
# where hypothesis is not installed)
# ---------------------------------------------------------------------------

def run_lifecycle_schedule(seed: int, wire: bool = False) -> None:
    """Random stores, write/expire/reap schedules, and straggler delta
    replays under loss/dup/partition/crash: a reaped key is never
    resurrected and live keys are untouched."""
    rng = random.Random(seed)
    ids = ["n0", "n1", "n2"]
    ownership = KeyOwnership(ids, replication=2)
    codec = WireCodec() if wire else None
    sim = Simulator(NetConfig(loss=rng.choice([0.0, 0.15, 0.3]), dup=0.1,
                              seed=seed))
    nodes = [sim.add_node(StoreReplica(
        i, [j for j in ids if j != i], causal=True,
        policy=Compose(make_policy("bp+rr+digest-sync:4"),
                       ShardByKey(ownership)),
        rng=random.Random(seed + k), ownership=ownership, wire=codec,
        ttl=6.0)) for k, i in enumerate(ids)]
    for node in nodes:
        ReaperProtocol(node, ownership, grace=1.0, retry=1.5)
        sim.every(1.0, node.on_periodic)
        sim.every(5.0, node.gc_deltas)
    by_id = {n.id: n for n in nodes}
    keys = [f"k{i}" for i in range(5)]
    keep_alive = set(rng.sample(keys, 2))
    captured = []        # pre-reap single-key deltas for straggler replay

    def replay():
        if not captured:
            return
        d = rng.choice(captured)
        dst = rng.choice(nodes)
        msg = ("handoff", d)
        dst.on_receive(rng.choice(ids),
                       codec.encode_msg(msg) if codec else msg)

    for t in range(35):
        node = rng.choice([n for n in nodes if n.alive])
        key = rng.choice(keys)
        node.update(key, GCounter, "inc_delta", node.id, 1)
        captured.append(node.X.restrict([key]))
        for ka in keep_alive:
            toucher = rng.choice([n for n in nodes if n.alive])
            toucher.update(ka, GCounter, "inc_delta", toucher.id, 1)
        if rng.random() < 0.10:
            cut = rng.choice(ids)
            sim.add_partition(sim.time, sim.time + rng.uniform(2.0, 5.0),
                              [cut], [i for i in ids if i != cut])
        if rng.random() < 0.08:
            sim.crash(rng.choice(ids), rng.uniform(1.0, 3.0))
        if rng.random() < 0.25:
            replay()
        sim.run_for(rng.uniform(0.5, 1.5))
        # live keys untouched: nothing that is still being written may
        # ever be tombstoned, anywhere
        for ka in keep_alive:
            for n in nodes:
                assert not n.X.tombstoned(ka), (seed, t, ka, n.id)

    sim.run_for(60.0)        # everything expires; partitions healed; reap
    for key in keys:
        for w in ownership.owners(key):
            st = by_id[w].X
            assert st.tombstoned(key), (seed, key, w, st.life_of(key))
        replay()
    sim.run_for(20.0)        # straggler replays after the reaps…
    for key in keys:
        for w in ownership.owners(key):
            st = by_id[w].X
            assert st.tombstoned(key), (seed, key, w, "resurrected")
            assert st.get(key, GCounter).value() == 0


@pytest.mark.parametrize("seed,wire", [(0, False), (1, True), (2, False),
                                       (3, True)])
def test_lifecycle_schedule_seed_sweep(seed, wire):
    run_lifecycle_schedule(seed, wire)


def test_ttl_write_stamps_and_revives():
    node = StoreReplica("n0", [], ttl=10.0)
    sim = Simulator(NetConfig(seed=1))
    sim.add_node(node)
    node.update("k", GCounter, "inc_delta", "n0", 1)
    assert node.X.life_of("k") == (0, 10.0)
    node.X = node.X.join(LatticeStore.life_delta("k", (1, 10.0)))
    assert node.X.tombstoned("k")
    node.update("k", GCounter, "inc_delta", "n0", 5)
    assert node.X.life_of("k")[0] == 2           # new incarnation
    assert node.X.get("k", GCounter).value() == 5
