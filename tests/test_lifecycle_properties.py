"""Property tests (hypothesis) for the key lifecycle subsystem:

* **no resurrection, live keys untouched** — for random stores, random
  write/expire/reap schedules, and straggler delta replays under
  loss/duplication/partition/crash, a reaped key is never resurrected
  (at any write-set member, in object or wire mode) and keys still being
  written are never tombstoned (the schedule driver asserts both; it
  lives in ``test_lifecycle.py`` so fixed-seed sweeps validate the body
  where hypothesis is not installed);
* the lifecycle store lattice laws hold on randomly generated stores
  (random values × random (epoch, expiry) components): join stays
  idempotent/commutative/associative, restriction and decomposition stay
  faithful;
* digest exchange stays join-equivalent to full state across random
  epoch/expiry skews (the Def. 6 argument with lifecycle in play).
"""

import random

import pytest
import pytest as _pytest
_pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import GCounter, LatticeStore, digest_diff, store_digest
from test_lifecycle import run_lifecycle_schedule

KEYS = ("a", "b", "c")


@st.composite
def lifecycle_stores(draw):
    out = LatticeStore.bottom()
    for key in KEYS:
        if draw(st.booleans()):
            out = out.join(LatticeStore.bottom().apply_delta(
                key, GCounter, "inc_delta", draw(st.sampled_from("xyz")),
                draw(st.integers(1, 9))))
        epoch = draw(st.integers(0, 3))
        expiry = draw(st.sampled_from([float("-inf"), 0.0, 5.0, 50.0]))
        life = (epoch, expiry)
        if life != (0, float("-inf")):
            if epoch and draw(st.booleans()):
                out = out.with_life(key, life)    # value in this epoch
            else:
                out = out.join(LatticeStore.life_delta(key, life))
    return out


@settings(max_examples=60, deadline=None)
@given(x=lifecycle_stores(), y=lifecycle_stores(), z=lifecycle_stores())
def test_lifecycle_store_lattice_laws_random(x, y, z):
    assert x.join(x) == x
    assert x.join(y) == y.join(x)
    assert x.join(y).join(z) == x.join(y.join(z))
    assert x.leq(x.join(y)) and y.leq(x.join(y))


@settings(max_examples=60, deadline=None)
@given(x=lifecycle_stores())
def test_lifecycle_decompose_faithful_random(x):
    rejoined = LatticeStore.bottom()
    for atom in x.decompose():
        assert atom.leq(x)
        rejoined = rejoined.join(atom)
    assert rejoined == x


@settings(max_examples=60, deadline=None)
@given(requester=lifecycle_stores(), responder=lifecycle_stores())
def test_digest_exchange_join_equivalent_with_lifecycle(requester,
                                                        responder):
    d = digest_diff(responder, store_digest(requester))
    assert requester.join(d) == requester.join(responder)
    # and the diff never resurrects: a requester-side tombstone stays
    for key in KEYS:
        if requester.tombstoned(key) \
                and responder.life_of(key)[0] < requester.life_of(key)[0]:
            assert requester.join(d).tombstoned(key)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), wire=st.booleans())
def test_reaped_keys_never_resurrect_random_schedules(seed, wire):
    run_lifecycle_schedule(seed, wire)
