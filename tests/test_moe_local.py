"""shard_map local-dispatch MoE == global-dispatch MoE (numerically), on a
real multi-device mesh. Runs in a subprocess so the 8 fake host devices
don't leak into the rest of the test session."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models import ModelConfig, MoESpec
    from repro.models.moe import apply_moe, init_moe
    from repro.models.hints import activation_rules

    EP = bool(int(os.environ["TEST_EP"]))
    # EP regime: E=8 divisible by model=4; TP regime: E=3 (indivisible)
    E = 8 if EP else 3
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab=17,
                      moe=MoESpec(num_experts=E, top_k=2, expert_d_ff=64,
                                  num_shared_experts=1, shared_d_ff=32,
                                  capacity_factor=float(E)),  # dropless
                      dtype="float32", moe_impl="local")

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = {"tokens": "data", "batch": "data"}
    p, _ = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

    y_ref, aux_ref = apply_moe(p, dataclasses.replace(cfg,
                                                      moe_impl="global"),
                               x)

    with mesh, activation_rules(mesh, rules):
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        y, aux = jax.jit(lambda p, x: apply_moe(p, cfg, x))(p, xs)

    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    # aux differs by per-shard averaging of mean-probs; must be close on
    # iid data and exactly equal when data shards are balanced
    assert abs(float(aux) - float(aux_ref)) < 0.4, (aux, aux_ref)
    print("OK", float(aux), float(aux_ref))
""")


@pytest.mark.parametrize("ep", [1, 0], ids=["expert-parallel", "tensor-parallel"])
def test_local_moe_matches_global(ep):
    env = dict(os.environ)
    env["TEST_EP"] = str(ep)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
